"""Production mesh definitions.

Single pod : (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

`make_production_mesh` is a function (never a module-level constant) so
importing this module touches no jax device state — device counts are
locked on first backend init, and only launch/dryrun.py (which sets
XLA_FLAGS before any import) may build the 512-way host-platform mesh.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the jax version has them
    (jax.sharding.AxisType appeared after 0.4.x; Auto is the old default)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, *, pod: int = 0):
    """Small mesh for subprocess sharding tests (host-platform devices)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def make_serving_mesh(model: int, *, devices=None):
    """A (data=1, model=N) mesh for tensor-parallel serving — the shape
    `InferenceEngine.build(mesh=...)` shard-maps the unified step over.

    Unlike `make_mesh`, this uses the FIRST `model` devices rather than
    all of them, so a --xla_force_host_platform_device_count=8 test
    process can build 1/2/4-way serving meshes side by side. N == 1 is
    deliberately legal: it runs the same shard_map path (psum over one
    device is the identity), so every mesh size exercises one code
    path."""
    import numpy as np

    devices = list(jax.devices()) if devices is None else list(devices)
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    if len(devices) < model:
        raise ValueError(
            f"serving mesh needs {model} devices, have {len(devices)}: on "
            f"CPU run under XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={model} (tests/test_tp_serving.py does exactly this)")
    arr = np.asarray(devices[:model], dtype=object).reshape(1, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
PEAK_OPS_INT8 = 394e12        # OP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s per link
ICI_LINKS = 4                 # v5e: 4 ICI links per chip (2D torus x2 dirs)
VMEM_BYTES = 16 * 2 ** 20     # ~16 MiB/core wired scratchpad
HBM_BYTES = 16 * 2 ** 30      # 16 GiB HBM per v5e chip
PCIE_BW = 16e9                # B/s host<->device (PCIe gen3 x16 effective)
DISPATCH_S = 30e-6            # fixed host->device launch latency per dispatch
