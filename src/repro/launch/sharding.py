"""Parameter / batch / cache sharding rules (logical -> PartitionSpec).

Rules are (path-regex, trailing-dim logical names). The first match wins;
leading scan-stack dims get None. Logical names:

  data   — FSDP-style weight sharding axis (within-pod)
  model  — tensor-parallel axis
  batch  — activation batch axis: ("pod","data") on multi-pod meshes
  expert — expert-parallel: "model" when num_experts divides it, else None

GQA note: kv-head dims whose size doesn't divide the model axis rely on
GSPMD's implicit padding (musicgen H=24, gemma2 kv=8); the waste shows up
honestly in the roofline's MODEL_FLOPS/HLO_FLOPS ratio.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.runtime.shardctx import resolve_axis

# (regex over "/"-joined path, spec names for the TRAILING dims)
_RULES = [
    (r"embed$", ("model", None)),
    (r"lm_head$", ("data", "model")),
    (r"/(wq|wk|wv)(/values)?$", ("data", "model")),
    (r"/wo(/values)?$", ("model", "data")),
    (r"/(gate|up)(/values)?$", ("data", "model")),
    (r"/down(/values)?$", ("model", "data")),
    (r"router(/values)?$", (None, None)),
    # mamba1
    (r"/in_proj(/values)?$", ("data", "model")),
    (r"/dt_in(/values)?$", ("model", None)),
    (r"/bc_proj(/values)?$", ("model", None)),
    (r"/dt_proj(/values)?$", (None, "model")),
    (r"/out_proj(/values)?$", ("model", "data")),
    (r"/conv_w$", ("model", None)),
    (r"/A_log$", ("model", None)),      # trimmed to ndim for mamba2 (nh,)
    (r"/(D|dt_bias)$", ("model",)),
    # mamba2
    (r"/zx_proj(/values)?$", ("data", "model")),
    (r"/bc_in(/values)?$", ("data", None)),
    (r"/dt_lin(/values)?$", ("data", "model")),
    # low-rank factors (ITERA): w1 R-dim over model, w2 N-dim over model;
    # the (B, R) intermediate all-gathers (R << N — the collective win).
    (r"/w1/values$", ("data", "model")),
    (r"/w1/scale$", (None, "model")),
    (r"/w2/values$", (None, "model")),
    (r"/w2/scale$", (None, None)),
    # quantized dense scales: per-output-column -> follow the N dim
    (r"/(wq|wk|wv|gate|up|lm_head)/scale$", (None, "model")),
    (r"/(wo|down|out_proj|in_proj|zx_proj|dt_lin)/scale$", (None, "data")),
]

_EXPERT_RULES_EP = [
    (r"experts/(up|gate)(/values)?$", ("model", "data", None)),
    (r"experts/down(/values)?$", ("model", None, "data")),
    (r"experts/\w+/scale$", ("model", None, None)),
]
_EXPERT_RULES_TP = [
    (r"experts/(up|gate)(/values)?$", (None, "data", "model")),
    (r"experts/down(/values)?$", (None, "model", "data")),
    (r"experts/(up|gate)/scale$", (None, None, "model")),
    (r"experts/down/scale$", (None, None, "data")),
]


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _resolve(names, mesh, ndim):
    names = list(names)
    if len(names) > ndim:            # e.g. A_log rule on mamba2's (nh,)
        names = names[-ndim:]
    names = [None] * (ndim - len(names)) + names
    phys = []
    for n in names:
        ax = resolve_axis(n, mesh)
        phys.append(ax)
    return P(*phys)


def _divisible(dim, axis, mesh):
    if axis is None:
        return True
    size = (np.prod([mesh.shape[a] for a in axis]) if isinstance(axis, tuple)
            else mesh.shape[axis])
    return dim % size == 0


def spec_for(path: str, leaf, mesh, cfg=None) -> P:
    """PartitionSpec for one param leaf."""
    ndim = getattr(leaf, "ndim", 0)
    if ndim == 0:
        return P()
    rules = list(_RULES)
    if cfg is not None and cfg.moe is not None:
        ep = cfg.moe.num_experts % mesh.shape["model"] == 0
        rules = (_EXPERT_RULES_EP if ep else _EXPERT_RULES_TP) + rules
    for pat, names in rules:
        if re.search(pat, path):
            spec = _resolve(names, mesh, ndim)
            # drop any axis that does not divide (replicate instead),
            # except GQA head dims where GSPMD padding is intended.
            fixed = []
            for dim, ax in zip(leaf.shape, list(spec) + [None] * ndim):
                fixed.append(ax if _divisible(dim, ax, mesh) or _is_head_dim(
                    path, ax) else None)
            return P(*fixed[:ndim])
    return P(*([None] * ndim))


def _is_head_dim(path: str, axis) -> bool:
    return axis == "model" and re.search(r"/(wq|wk|wv|wo)", path) is not None


def param_shardings(params, mesh, cfg=None):
    """NamedSharding pytree mirroring `params`."""
    def visit(path, leaf):
        return NamedSharding(mesh, spec_for(path_str(path), leaf, mesh, cfg))

    return jax.tree_util.tree_map_with_path(visit, params)


def batch_shardings(batch, mesh, *, shard_batch_dim=True):
    def visit(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        names = ["batch" if shard_batch_dim else None] + \
            [None] * (leaf.ndim - 1)
        if shard_batch_dim and not _divisible(
                leaf.shape[0], resolve_axis("batch", mesh), mesh):
            names[0] = None
        return NamedSharding(mesh, _resolve(names, mesh, leaf.ndim))

    return jax.tree_util.tree_map(visit, batch)


def cache_shardings(cache, mesh, *, batch: int):
    """Decode-cache shardings. Leaf layouts (leading L/G stack dim):
      kv k/v   (L, B, S, Hk, hd)   -> (None, batch, None, model, None)
      ssm h    (L, B, ..., ...)    -> (None, batch, model-ish...)
    When B doesn't divide the batch axes (long_500k B=1), shard the
    *sequence* dim of KV caches over "data" instead (SP decode)."""
    data_ok = _divisible(batch, resolve_axis("batch", mesh), mesh)

    def visit(path, leaf):
        p = path_str(path)
        nd = leaf.ndim
        if nd == 5:                 # stacked kv cache (L, B, S, Hk, hd)
            L, B, S, Hk, hd = leaf.shape
            spec = [None, None, None, None, None]
            if data_ok:
                spec[1] = resolve_axis("batch", mesh)
            # model axis: kv heads when they divide, else the sequence dim
            # (GSPMD then computes decode softmax as a flash-decode-style
            # sharded partial reduction). in_shardings demand exact
            # divisibility — no padding on inputs.
            if _divisible(Hk, "model", mesh):
                spec[3] = "model"
                if not data_ok and _divisible(S, resolve_axis("data", mesh),
                                              mesh):
                    spec[2] = resolve_axis("data", mesh)   # SP decode
            else:
                ax = ("model" if data_ok
                      else tuple(a for a in ("data", "model")
                                 if a in mesh.axis_names))
                if _divisible(S, ax, mesh):
                    spec[2] = ax
            return NamedSharding(mesh, P(*spec))
        if "conv" in p and nd == 4:                   # (L, B, k-1, di)
            names = [None, "batch" if data_ok else None, None, "model"]
        elif nd >= 3:                                 # ssm state (L, B, ...)
            names = [None, "batch" if data_ok else None, "model"] + \
                [None] * (nd - 3)
            if not _divisible(leaf.shape[2], resolve_axis("model", mesh),
                              mesh):
                names[2] = None
        else:
            names = [None] * nd
        return NamedSharding(mesh, _resolve(names, mesh, nd))

    return jax.tree_util.tree_map_with_path(visit, cache)


# ---------------------------------------------------------------------------
# shard_map tensor parallelism (serving).
#
# These rules are deliberately DIFFERENT from the GSPMD `_RULES` above:
# shard_map hands each device a literal array slice, so there is no
# implicit padding (GQA heads must divide exactly — `check_tp_geometry`
# raises instead) and the slice axis must keep per-shard compute
# *numerically* equal to a column/row block of the reference matmul.
# N-sites (wq/wk/wv/gate/up: replicated input, sliced output columns)
# are bit-exact per shard. K-sites (wo/down: sliced input features,
# full output) produce partial sums the layer boundary psums restore.
# For ITERA low-rank cascades that means w1 must NOT be R-sharded here
# (the GSPMD rules R-shard it and let the compiler all-gather the
# (B, R) intermediate): on an N-site the whole w1 is replicated and
# only w2's output columns are sliced — bit-exact, the cascade's
# intermediate activation quantization sees identical tensors on every
# shard. On a K-site w1's input rows are sliced; its per-column scale
# (1, R) stays replicated and w2 is replicated, and the cascade's
# activation requant then runs over local features only — numerically
# close but not bit-equal, which is why the TP identity tests compress
# N-sites only.

_TP_N = r"/(wq|wk|wv|gate|up)"
_TP_K = r"/(wo|down)"

# (regex, action): "col" slices the last dim, "row" the second-to-last,
# "rep" replicates. First match wins.
_TP_RULES = [
    (_TP_N + r"/w1/(values|scale)$", "rep"),
    (_TP_N + r"/w2/values$", "col"),
    (_TP_N + r"/w2/scale$", "rep"),       # (R, 1) per-rank-row scale
    (_TP_K + r"/w1/values$", "row"),
    (_TP_K + r"/w1/scale$", "rep"),       # (1, R) per-column scale
    (_TP_K + r"/w2/(values|scale)$", "rep"),
    (_TP_N + r"(/values|/scale)?$", "col"),
    (_TP_K + r"/values$", "row"),
    (_TP_K + r"/scale$", "rep"),          # (1, N) per-output-column scale
    (_TP_K + r"$", "row"),
]


def check_tp_geometry(cfg, tp: int) -> None:
    """Raise unless `cfg` shards cleanly over a model axis of size `tp`.

    shard_map cannot pad the way GSPMD does, so every sharded dimension
    must divide exactly; the error names the ModelConfig field to fix."""
    if tp <= 1:
        return
    if cfg.layout != "dense":
        raise NotImplementedError(
            f"tensor-parallel serving supports layout='dense' only, got "
            f"layout={cfg.layout!r}")
    bad = [f"ModelConfig.{name}={val}" for name, val in
           (("num_heads", cfg.num_heads), ("num_kv_heads", cfg.num_kv_heads),
            ("d_ff", cfg.d_ff)) if val % tp]
    if bad:
        raise ValueError(
            f"model geometry does not divide the tensor-parallel axis "
            f"(tp={tp}): {', '.join(bad)}. shard_map slices arrays "
            f"literally — there is no GSPMD padding — so attention/KV "
            f"heads and the MLP hidden dim must each be a multiple of "
            f"the mesh 'model' axis size.")


def tp_local_config(cfg, tp: int):
    """The per-shard ModelConfig the shard_map body runs with: each
    shard owns num_heads/tp query heads and num_kv_heads/tp KV heads.
    head_dim is a concrete field after __post_init__, so it survives
    the replace; d_model/d_ff are untouched (the weight slices carry
    the hidden-dim split)."""
    import dataclasses
    if tp <= 1:
        return cfg
    return dataclasses.replace(cfg, num_heads=cfg.num_heads // tp,
                               num_kv_heads=cfg.num_kv_heads // tp)


def tp_spec_for(path: str, leaf, tp: int) -> P:
    """shard_map PartitionSpec for one param leaf under `tp`-way TP."""
    ndim = getattr(leaf, "ndim", 0)
    if tp <= 1 or ndim < 2:
        return P(*([None] * ndim))
    action = "rep"
    for pat, act in _TP_RULES:
        if re.search(pat, path):
            action = act
            break
    if action == "rep":
        return P(*([None] * ndim))
    dim = ndim - 1 if action == "col" else ndim - 2
    if leaf.shape[dim] % tp:
        raise ValueError(
            f"TP cannot slice {path}: dim {dim} has size {leaf.shape[dim]}"
            f", not divisible by tp={tp} (packed sub-8-bit leaves halve "
            f"the packed axis — geometry must divide after packing)")
    spec = [None] * ndim
    spec[dim] = "model"
    return P(*spec)


def tp_param_specs(params, tp: int):
    """PartitionSpec pytree (shard_map in_specs) for the serving params."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: tp_spec_for(path_str(p), l, tp), params)


def tp_param_shardings(params, mesh):
    """NamedSharding pytree placing params for the TP serving step, so
    shard_map finds every leaf pre-sliced (no per-dispatch resharding)."""
    tp = mesh.shape["model"]
    specs = tp_param_specs(params, tp)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def opt_shardings(opt_state, params, mesh, cfg=None, *, zero1=True):
    """Optimizer-state shardings.

    fp32 m/v mirror the param spec (plus ZeRO-1: the first replicated,
    divisible dim gets sharded over 'data'). 8-bit state leaves are
    (nblocks, 256) block tables -> shard dim0 over 'data' when divisible.
    """
    from repro.optim.adamw import zero1_pspec

    pspecs = {
        path_str(p): spec_for(path_str(p), l, mesh, cfg)
        for p, l in jax.tree_util.tree_flatten_with_path(params)[0]
    }

    def visit(path, leaf):
        ps = path_str(path)
        if getattr(leaf, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        m = re.match(r"^(m|v)/(.+)$", ps)
        if not m:
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        base = m.group(2)
        if base.endswith(("/q", "/scale", "/off")):
            d0 = "data" if _divisible(leaf.shape[0],
                                      resolve_axis("data", mesh), mesh) \
                else None
            return NamedSharding(
                mesh, P(d0, *([None] * (leaf.ndim - 1))))
        spec = pspecs.get(base, P(*([None] * leaf.ndim)))
        if zero1:
            spec = zero1_pspec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, opt_state)
