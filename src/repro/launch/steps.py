"""Step builders + abstract input specs for every (arch x shape) cell.

Everything here is allocation-free: params/opt-state/caches are
jax.eval_shape ShapeDtypeStructs, batches are ShapeDtypeStructs, and the
builders return (fn, args, in_shardings, out_shardings) ready for
jax.jit(...).lower(...).compile().

Step kinds map to the shape kinds:
  train    -> train_step(params, opt_state, batch)  [value_and_grad + AdamW]
  prefill  -> prefill_step(params, batch)           [forward + cache build]
  decode   -> serve_step(params, cache, tok, pos)   [1 token w/ KV cache]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch import sharding as shd
from repro.models import transformer as tfm
from repro.optim import adamw


def model_inputs(cfg, batch: int, seq: int, *, with_labels: bool):
    """ShapeDtypeStructs for the model inputs of one batch."""
    if cfg.frontend in ("audio", "vision"):
        inp = {"inputs_embeds": jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_model), jnp.dtype(cfg.dtype))}
    else:
        inp = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if with_labels:
        inp["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return inp


def abstract_params(cfg):
    return jax.eval_shape(
        functools.partial(tfm.init_params, cfg=cfg), jax.random.PRNGKey(0))


def abstract_opt_state(params, opt_cfg):
    return jax.eval_shape(functools.partial(adamw.init, cfg=opt_cfg), params)


def abstract_cache(cfg, batch, max_len):
    return jax.eval_shape(
        lambda: tfm.init_cache(cfg, batch, max_len))


# ------------------------------------------------------------------ steps --
def make_train_step(cfg, opt_cfg, *, ssm_engine="sequential"):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            tfm.loss_fn, has_aux=True)(params, batch, cfg,
                                       ssm_engine=ssm_engine)
        new_params, new_opt, om = adamw.update(grads, opt_state, params,
                                               opt_cfg)
        return new_params, new_opt, {
            "loss": loss, "ce": metrics["ce"], **om}

    return train_step


def make_prefill_step(cfg, *, ssm_engine="sequential"):
    def prefill_step(params, batch):
        inputs = batch.get("inputs_embeds", batch.get("tokens"))
        return tfm.prefill(params, inputs, cfg, ssm_engine=ssm_engine)

    return prefill_step


def make_serve_step(cfg):
    def serve_step(params, cache, tok, pos):
        return tfm.decode_step(params, cache, tok, pos, cfg)

    return serve_step


# ------------------------------------------------------------- cell build --
def build_cell(arch: str, shape_name: str, mesh, *,
               opt_cfg: adamw.AdamWConfig | None = None,
               compression=None, ssm_engine="sequential",
               zero1: bool = True, cfg_overrides: dict | None = None):
    """Returns dict(fn, args, in_shardings, out_shardings, donate) for one
    dry-run cell. `compression` optionally swaps inference params for the
    ITERA / quant-only compressed layout (CompressionConfig);
    `cfg_overrides` patches ModelConfig fields (perf variants: remat_policy,
    kv_cache_bits, attn_chunk, ...)."""
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        ov = dict(cfg_overrides)
        ssm_chunk = ov.pop("ssm_chunk", None)
        if ssm_chunk and cfg.ssm is not None:
            cfg = _dc.replace(cfg, ssm=_dc.replace(cfg.ssm, chunk=ssm_chunk))
        if ov:
            cfg = _dc.replace(cfg, **ov)
    spec = SHAPES[shape_name]
    params = abstract_params(cfg)
    if compression is not None:
        from repro.core.compress import compress_params
        params = jax.eval_shape(
            lambda p: compress_params(p, compression)[0], params)
    pshard = shd.param_shardings(params, mesh, cfg)

    if spec.kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        opt = abstract_opt_state(params, opt_cfg)
        oshard = shd.opt_shardings(opt, params, mesh, cfg, zero1=zero1)
        batch = model_inputs(cfg, spec.global_batch, spec.seq_len,
                             with_labels=True)
        bshard = shd.batch_shardings(batch, mesh)
        fn = make_train_step(cfg, opt_cfg, ssm_engine=ssm_engine)
        metr = NamedSharding(mesh, P())
        return dict(
            fn=fn, args=(params, opt, batch),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard,
                           jax.tree_util.tree_map(lambda _: metr,
                                                  {"loss": 0, "ce": 0,
                                                   "grad_norm": 0, "lr": 0})),
            donate_argnums=(0, 1), cfg=cfg)

    if spec.kind == "prefill":
        batch = model_inputs(cfg, spec.global_batch, spec.seq_len,
                             with_labels=False)
        bshard = shd.batch_shardings(batch, mesh)
        fn = make_prefill_step(cfg, ssm_engine=ssm_engine)
        logits, cache = jax.eval_shape(fn, params, batch)
        cshard = shd.cache_shardings(cache, mesh, batch=spec.global_batch)
        lshard = NamedSharding(mesh, P(
            shd.resolve_axis("batch", mesh), None, "model"))
        return dict(
            fn=fn, args=(params, batch),
            in_shardings=(pshard, bshard),
            out_shardings=(lshard, cshard),
            donate_argnums=(), cfg=cfg)

    # decode
    cache = abstract_cache(cfg, spec.global_batch, spec.seq_len)
    cshard = shd.cache_shardings(cache, mesh, batch=spec.global_batch)
    tok = {"tokens": jax.ShapeDtypeStruct((spec.global_batch, 1), jnp.int32)}
    tshard = shd.batch_shardings(
        tok, mesh, shard_batch_dim=spec.global_batch > 1)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    fn = make_serve_step(cfg)
    b_ax = shd.resolve_axis("batch", mesh) \
        if spec.global_batch % _batch_size(mesh) == 0 else None
    lshard = NamedSharding(mesh, P(b_ax, None, "model"))
    return dict(
        fn=lambda params, cache, tok, pos: fn(params, cache, tok["tokens"],
                                              pos),
        args=(params, cache, tok, pos),
        in_shardings=(pshard, cshard, tshard, NamedSharding(mesh, P())),
        out_shardings=(lshard, cshard),
        donate_argnums=(1,), cfg=cfg)


def _batch_size(mesh):
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
