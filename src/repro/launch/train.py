"""Training driver: mesh + sharded params + resilient loop + checkpoints.

Runs for real on any device pool (the end-to-end example trains a ~100M
model on CPU); on a pod it is the production entry point:

  python -m repro.launch.train --arch stablelm-12b --steps 500 \
      --batch 32 --seq 512 --ckpt-dir /tmp/ckpt [--smoke] [--grad-compress]

Features: bf16 params with fp32 AdamW, gradient accumulation, ZeRO-1
optimizer sharding, async checkpoints + restart-on-failure (ResilientLoop),
straggler monitoring, optional int8+error-feedback gradient compression
(shard_map DP reduction), elastic resume from any divisible mesh.
"""
from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import get_config
from repro.data import pipeline
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as shd
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.runtime import shardctx
from repro.runtime.fault import ResilientLoop


def make_accum_train_step(cfg, opt_cfg, microbatches: int):
    """Gradient accumulation over `microbatches` scan steps."""
    def train_step(params, opt_state, batch):
        def one(b):
            return jax.value_and_grad(tfm.loss_fn, has_aux=True)(
                params, b, cfg)

        if microbatches <= 1:
            (loss, metrics), grads = one(batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def body(acc, b):
                (l, m), g = one(b)
                gsum, lsum = acc
                return (jax.tree_util.tree_map(jnp.add, gsum, g),
                        lsum + l), m

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), metrics = jax.lax.scan(body, (zero_g, 0.0), mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        new_params, new_opt, om = adamw.update(grads, opt_state, params,
                                               opt_cfg)
        return new_params, new_opt, {"loss": loss, **om}

    return train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opus-mt")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--opt-bits", type=int, default=32, choices=[32, 8])
    ap.add_argument("--data", default="markov", choices=["markov", "hash"])
    ap.add_argument("--mesh", default="auto",
                    help="auto | dxm (e.g. 2x4) using available devices")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 5),
                                state_bits=args.opt_bits)

    n_dev = jax.device_count()
    if args.mesh == "auto":
        mesh = mesh_lib.make_mesh((n_dev, 1), ("data", "model"))
    else:
        d, m = map(int, args.mesh.split("x"))
        mesh = mesh_lib.make_mesh((d, m), ("data", "model"))

    with shardctx.use_mesh(mesh):
        key = jax.random.PRNGKey(args.seed)
        params = tfm.init_params(key, cfg)
        opt_state = adamw.init(params, opt_cfg)
        pshard = shd.param_shardings(params, mesh, cfg)
        oshard = shd.opt_shardings(opt_state, params, mesh, cfg)
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)

        if args.data == "markov":
            task = pipeline.MarkovTask(cfg.vocab_size, seed=args.seed)
            make = functools.partial(task.batch, batch=args.batch,
                                     seq=args.seq)
        else:
            make = lambda s: pipeline.hash_batch(  # noqa: E731
                args.seed, s, args.batch, args.seq, cfg.vocab_size)

        if cfg.frontend in ("audio", "vision"):
            table = jax.random.normal(
                jax.random.fold_in(key, 7),
                (cfg.vocab_size, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.02
            base_make = make
            make = lambda s: pipeline.lift_to_embeddings(  # noqa: E731
                base_make(s), table)

        train_step = jax.jit(
            make_accum_train_step(cfg, opt_cfg, args.microbatches),
            donate_argnums=(0, 1))

        state = {"params": params, "opt": opt_state}
        start = 0
        if args.resume and ckpt_lib.latest_step(args.ckpt_dir) is not None:
            like = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, start = ckpt_lib.restore(args.ckpt_dir, like)
            print(f"[train] resumed from step {start}")

        def step_fn(state, step):
            batch = pipeline.shard_batch(make(step), mesh)
            p, o, metrics = train_step(state["params"], state["opt"], batch)
            return {"params": p, "opt": o}, metrics

        def save_fn(state, step):
            ckpt_lib.save(args.ckpt_dir, step, state, async_save=False)

        def restore_fn():
            like = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            return ckpt_lib.restore(args.ckpt_dir, like)

        loop = ResilientLoop(
            step_fn, save_fn, restore_fn,
            ckpt_every=args.ckpt_every,
            inject_failure_at=args.inject_failure_at)
        # initial checkpoint so restore-on-failure always has a target
        save_fn(state, 0)
        state, end = loop.run(state, start, args.steps - start)
        save_fn(state, end)

        r = loop.report
        losses = r.losses
        print(f"[train] done: steps={r.steps_run} failures={r.failures} "
              f"restores={r.restores} stragglers={r.straggler_events}")
        if losses:
            k = max(len(losses) // 10, 1)
            print(f"[train] loss first10={np.mean(losses[:k]):.4f} "
                  f"last10={np.mean(losses[-k:]):.4f}")
        return losses


if __name__ == "__main__":
    main()
