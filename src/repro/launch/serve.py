"""Serving CLI — a thin front-end over `repro.api.InferenceEngine`.

The inference face of ITERA-LLM: weights are compressed post-training per
a `CompressionPlan` (a DSE artifact, or a uniform plan built from the
legacy flags), then batched requests are prefilled and decoded by the
compiled engine.

  # deploy a DSE result (per-layer method x wl x rank):
  python -m repro.launch.serve --arch opus-mt --smoke --plan plan.json

  # or a uniform plan from flags (legacy CompressionConfig semantics):
  python -m repro.launch.serve --arch opus-mt --smoke --compression itera \
      --rank-fraction 0.4 --wl 4 --prompt-len 64 --gen 32 --batch 4

  # mixed-length prompts through the continuous-batching scheduler
  # (blocked KV cache; see docs/serving.md):
  python -m repro.launch.serve --arch opus-mt --smoke --ragged \
      --batch 8 --max-batch 4 --block-size 16

On CPU this runs the pure-jnp reference math; on TPU the same entry point
dispatches the Pallas cascade kernels (models.set_linear_mode("auto")).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import (CompressionPlan, InferenceEngine, SamplingParams,
                       TokenEvent)
from repro.configs import get_config
from repro.core.compress import CompressionConfig
from repro.data import pipeline


async def serve_stream(engine, requests, sampling=None, **serve_kwargs):
    """Async streaming front door over `engine.serve`: yields each
    `TokenEvent` the moment the pipelined readback confirms it, then the
    final `ServeResult` as the last item.

    The serve loop runs unchanged on a worker thread (its 2-deep
    dispatch pipeline never blocks on the consumer); the engine's
    `on_token` callback bridges events onto the caller's running event
    loop with `call_soon_threadsafe`, so ordering is preserved and the
    consumer sees tokens at true completion time — not at drain. A
    serve-side exception is re-raised here after the events that
    preceded it.

        async for ev in serve_stream(engine, prompts, sampling):
            if isinstance(ev, TokenEvent):
                ...                     # stream ev.rid / ev.token out
            else:
                result = ev             # the closing ServeResult
    """
    import asyncio
    import threading

    loop = asyncio.get_running_loop()
    q: asyncio.Queue = asyncio.Queue()

    def on_token(ev: TokenEvent) -> None:
        loop.call_soon_threadsafe(q.put_nowait, ev)

    def run() -> None:
        try:
            res = engine.serve(requests, sampling, on_token=on_token,
                               **serve_kwargs)
        except BaseException as e:     # surface serve errors to the consumer
            loop.call_soon_threadsafe(q.put_nowait, e)
        else:
            loop.call_soon_threadsafe(q.put_nowait, res)

    threading.Thread(target=run, daemon=True).start()
    while True:
        item = await q.get()
        if isinstance(item, BaseException):
            raise item
        yield item
        if not isinstance(item, TokenEvent):   # the ServeResult closes it
            return


def generate(params, cfg, prompts, gen_len: int, *, greedy=True, seed=0):
    """Back-compat helper: decode `prompts` with already-built params.

    New code should hold an `InferenceEngine` and call `.generate` — this
    wrapper rebuilds the jitted callables on every call.
    """
    eng = InferenceEngine(cfg, params)
    res = eng.generate(prompts, SamplingParams(
        max_tokens=gen_len, temperature=0.0 if greedy else 1.0, seed=seed))
    return jnp.asarray(res.tokens)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opus-mt")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--plan", default=None,
                    help="CompressionPlan JSON (e.g. a serialized DSE "
                         "design point); overrides --compression/--wl/"
                         "--rank-fraction")
    ap.add_argument("--compression", default="none",
                    choices=["none", "quant", "svd", "itera"])
    ap.add_argument("--wl", type=int, default=8)
    ap.add_argument("--rank-fraction", type=float, default=0.5)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="continuous batching: batch-row capacity")
    ap.add_argument("--block-size", type=int, default=16,
                    help="continuous batching: KV-cache block size (tokens)")
    ap.add_argument("--chunk-tokens", type=int, default=256,
                    help="continuous batching: per-step token budget split "
                         "between prefill chunks and decode tokens")
    ap.add_argument("--paged-attn", default="auto",
                    choices=["auto", "kernel", "ref"],
                    help="serving attention over the blocked KV pool: "
                         "Pallas paged-attention kernel vs jnp gather "
                         "oracle (auto = kernel on TPU, oracle on CPU)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K tokens per "
                         "decode row with the truncated low-rank cascade, "
                         "verify with the full model in the same dispatch "
                         "(greedy outputs are unchanged; needs --ragged "
                         "and a low-rank plan to actually save work)")
    ap.add_argument("--draft-rank-fraction", type=float, default=0.5,
                    help="fraction of each cascade's rank the draft model "
                         "keeps (see runtime.speculation.DraftSpec)")
    ap.add_argument("--draft-act-wl", type=int, default=None,
                    help="optional activation word length override for "
                         "the draft pass (default: inherit the plan's)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="tensor-parallel serving: shard the engine over "
                         "a (1, N) device mesh — attention/KV heads and "
                         "MLP hidden dims split N ways, one all-reduce "
                         "per layer boundary (greedy outputs unchanged; "
                         "needs N devices — on CPU force them with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--ragged", action="store_true",
                    help="mixed-length demo: vary prompt lengths and serve "
                         "through the continuous-batching scheduler")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="share KV blocks between requests with equal "
                         "full-block prompt prefixes (on by default; "
                         "greedy outputs are unchanged)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="<= 0 -> greedy decode (sampling is fused "
                         "in-device; seeded runs replay token-for-token)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling threshold in (0, 1]; 1.0 "
                         "keeps the whole distribution")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a request after it emits this token id "
                         "(evaluated on device, inclusive)")
    ap.add_argument("--stop", action="append", default=[], metavar="IDS",
                    help="stop token sequence as comma-separated ids "
                         "(repeatable; matched inclusively on device)")
    ap.add_argument("--stream", action="store_true",
                    help="with --ragged: consume the serve through the "
                         "async streaming front door (serve_stream) and "
                         "print tokens as they complete")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.plan is not None:
        plan = CompressionPlan.load(args.plan)
        print(f"[serve] {plan.summary()}")
    elif args.compression != "none":
        plan = CompressionConfig(method=args.compression, weight_wl=args.wl,
                                 rank_fraction=args.rank_fraction)
    else:
        plan = None

    speculate = None
    if args.speculate > 0:
        from repro.api import DraftSpec

        speculate = DraftSpec(k=args.speculate,
                              rank_fraction=args.draft_rank_fraction,
                              act_wl=args.draft_act_wl)
    mesh = None
    if args.mesh > 0:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.mesh)
        print(f"[serve] tensor-parallel over mesh (data=1, model="
              f"{args.mesh})")
    engine = InferenceEngine.build(cfg, plan, seed=args.seed, verbose=True,
                                   mesh=mesh,
                                   max_batch=args.max_batch,
                                   block_size=args.block_size,
                                   chunk_tokens=args.chunk_tokens,
                                   paged_attn=args.paged_attn,
                                   speculate=speculate,
                                   prefix_cache=args.prefix_cache)

    task = pipeline.MarkovTask(cfg.vocab_size, seed=args.seed)
    prompts = task.batch(0, args.batch, args.prompt_len)["tokens"]
    stop = tuple(tuple(int(t) for t in s.split(",")) for s in args.stop)
    sampling = SamplingParams(max_tokens=args.gen,
                              temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed, eos_id=args.eos_id,
                              stop=stop)

    if args.ragged:
        # mixed-length workload: truncate each row to a different length
        base = np.asarray(prompts)
        lens = [max(4, args.prompt_len - 4 * (i % 4))
                for i in range(args.batch)]
        ragged = [base[i, :lens[i]] for i in range(args.batch)]
        if args.stream:
            import asyncio

            async def drive():
                shown = 0
                async for ev in serve_stream(engine, ragged, sampling):
                    if isinstance(ev, TokenEvent):
                        if shown < 8 or ev.final:
                            tag = " (final)" if ev.final else ""
                            print(f"[stream] rid={ev.rid} "
                                  f"#{ev.index}: {ev.token}{tag}")
                        shown += 1
                    else:
                        return ev

            res = asyncio.run(drive())
        else:
            res = engine.serve(ragged, sampling)
        print(f"[serve] in-flight batching: {len(ragged)} requests "
              f"(prompt lens {lens}) in {res.seconds:.1f}s — "
              f"{res.steps} unified steps ({res.mixed_steps} mixed), "
              f"{res.prefill_chunks} prefill chunks "
              f"({res.prefill_tokens} tokens, budget "
              f"{res.chunk_tokens}/step), peak queue "
              f"{res.max_queue_depth}, {res.tokens_per_second:.1f} tok/s")
        print(f"[serve] latency: TTFT p50 {res.ttft_p50 * 1e3:.0f}ms / "
              f"p95 {res.ttft_p95 * 1e3:.0f}ms, per-output-token p50 "
              f"{res.tpot_p50 * 1e3:.1f}ms / p95 {res.tpot_p95 * 1e3:.1f}ms")
        # goodput under a deadline of 2x the median finish time: requests
        # the queue starved past that contribute nothing
        deadline = 2 * float(np.median(res.finish_times))
        print(f"[serve] SLO: queue p50 {res.queue_p50 * 1e3:.0f}ms / "
              f"p95 {res.queue_p95 * 1e3:.0f}ms, goodput@{deadline:.1f}s "
              f"{res.goodput(deadline):.1f} tok/s, "
              f"{res.stopped_early} stopped early")
        if res.spec_k:
            print(f"[serve] speculation: k={res.spec_k}, accept rate "
                  f"{res.accept_rate:.2f} ({res.accepted}/{res.drafted} "
                  f"draft tokens over {res.spec_rounds} rounds)")
        if res.prefix_cache:
            print(f"[serve] prefix cache: hit rate "
                  f"{res.cache_hit_rate:.2f} "
                  f"({res.cache_hit_blocks}/{res.cache_lookup_blocks} "
                  f"blocks, {res.cache_hit_tokens} prompt tokens "
                  f"skipped), {res.cache_blocks_saved} blocks saved, "
                  f"{res.cache_cow_blocks} COW, "
                  f"{res.cache_evictions} evictions, "
                  f"{res.preemptions} preemptions")
        print("[serve] sample:", res.outputs[0][:16].tolist())
        out = np.zeros((len(res.outputs), args.gen), np.int32)
        for i, o in enumerate(res.outputs):   # stop-shortened rows: 0-pad
            out[i, :o.size] = o
        return out

    res = engine.generate(prompts, sampling)
    print(f"[serve] generated {res.tokens.shape} in {res.seconds:.1f}s "
          f"({res.tokens_per_second:.1f} tok/s)")
    print("[serve] sample:", np.asarray(res.tokens[0][:16]).tolist())
    return res.tokens


if __name__ == "__main__":
    main()
