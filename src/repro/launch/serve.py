"""Serving driver: compress (optional) -> prefill -> batched decode.

This is the inference face of ITERA-LLM: weights are compressed
post-training (quant-only baseline or ITERA low-rank + SRA ranks), then a
batch of requests is prefilled and decoded with jit'd steps.

  python -m repro.launch.serve --arch opus-mt --smoke --compression itera \
      --rank-fraction 0.4 --wl 4 --prompt-len 64 --gen 32 --batch 4

On CPU this runs the pure-jnp reference math; on TPU the same entry point
dispatches the Pallas cascade kernels (models.set_linear_mode("auto")).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.compress import CompressionConfig, compress_params
from repro.data import pipeline
from repro.models import transformer as tfm


def generate(params, cfg, prompts, gen_len: int, *, greedy=True, seed=0):
    """prompts: (B, S) int tokens. Returns (B, gen_len) generated ids."""
    b, s = prompts.shape
    max_len = s + gen_len

    prefill = jax.jit(lambda p, x: tfm.prefill(p, x, cfg, max_len=max_len))
    step = jax.jit(lambda p, c, t, pos: tfm.decode_step(p, c, t, pos, cfg))

    logits, cache = prefill(params, prompts)
    out = []
    key = jax.random.PRNGKey(seed)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(gen_len):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.asarray(s + i))
        if greedy:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, k2 = jax.random.split(key)
            tok = jax.random.categorical(k2, logits[:, -1])[:, None].astype(
                jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opus-mt")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "quant", "svd", "itera"])
    ap.add_argument("--wl", type=int, default=8)
    ap.add_argument("--rank-fraction", type=float, default=0.5)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(key, cfg)

    if args.compression != "none":
        ccfg = CompressionConfig(method=args.compression, weight_wl=args.wl,
                                 rank_fraction=args.rank_fraction)
        t0 = time.time()
        params, report = compress_params(params, ccfg)
        print(f"[serve] compressed in {time.time()-t0:.1f}s: "
              f"{report.summary()}")

    task = pipeline.MarkovTask(cfg.vocab_size, seed=args.seed)
    prompts = task.batch(0, args.batch, args.prompt_len)["tokens"]

    t0 = time.time()
    toks = generate(params, cfg, prompts, args.gen)
    dt = time.time() - t0
    print(f"[serve] generated {toks.shape} in {dt:.1f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("[serve] sample:", np.asarray(toks[0][:16]).tolist())
    return toks


if __name__ == "__main__":
    main()
