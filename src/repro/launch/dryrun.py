import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialization. This module is the ONLY place the
# 512-way host-platform device pool is created; tests and benches see 1.
"""Multi-pod dry-run driver.

For every (arch x input-shape x mesh) cell:
    lowered  = jax.jit(step, in_shardings, out_shardings).lower(*abstract)
    compiled = lowered.compile()
print memory_analysis (fits-per-device proof) and cost_analysis, run the
HLO-text analyzer (trip-count-aware FLOPs / HBM bytes / collective bytes),
and cache everything to results/dryrun/<cell>.json — EXPERIMENTS.md tables
and the roofline are generated from that cache.

Usage:
  python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --all --mesh single --compression itera
"""
import argparse
import json
import time
import traceback

import jax


VARIANTS = {
    # §Perf hillclimb variants: ModelConfig field overrides per cell
    "": {},
    "dots": {"remat_policy": "dots"},
    "kv8": {"kv_cache_bits": 8},
    "chunked512": {"attn_chunk": 512},
    "chunked2k": {"attn_chunk": 2048},
    "lchunk4k": {"loss_chunk": 4096},
    "ssmchunk32": {"ssm_chunk": 32},
    "ssmchunk64": {"ssm_chunk": 64},
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             compression: str = "none", out_dir: str = "results/dryrun",
             ssm_engine: str = "sequential", force: bool = False,
             variant: str = "") -> dict:
    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.core.compress import CompressionConfig
    from repro.hw import hlo_analysis
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.models import set_linear_mode
    from repro.runtime import shardctx

    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (
        f"__{compression}" if compression != "none" else "") + (
        f"__{variant}" if variant else "")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("status") in ("ok", "skipped"):  # errors retry
            return cached

    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": why}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    comp_cfg = None
    if compression == "quant":
        comp_cfg = CompressionConfig(method="quant", weight_wl=4)
    elif compression == "itera":
        comp_cfg = CompressionConfig(method="itera", weight_wl=4,
                                     rank_fraction=0.35)

    t0 = time.time()
    set_linear_mode("ref")  # SPMD-friendly jnp math inside the big graphs
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": list(mesh.devices.shape), "compression": compression,
           "status": "error"}
    try:
        with shardctx.use_mesh(mesh):
            cell = steps.build_cell(arch, shape_name, mesh,
                                    compression=comp_cfg,
                                    ssm_engine=ssm_engine,
                                    cfg_overrides=VARIANTS[variant])
            jitted = jax.jit(
                cell["fn"],
                in_shardings=cell["in_shardings"],
                out_shardings=cell["out_shardings"],
                donate_argnums=cell["donate_argnums"])
            lowered = jitted.lower(*cell["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax returns a list
            ca = ca[0] if ca else {}
        hlo_text = compiled.as_text()
        hlo = hlo_analysis.analyze(hlo_text)
        try:  # cache the HLO so analyzer updates re-run without recompiling
            import zstandard
            with open(os.path.join(out_dir, cell_id + ".hlo.zst"),
                      "wb") as zf:
                zf.write(zstandard.ZstdCompressor(level=6).compress(
                    hlo_text.encode()))
        except Exception:  # noqa: BLE001 — cache is best-effort
            pass

        spec = SHAPES[shape_name]
        cfg = get_config(arch)
        n_chips = int(mesh.devices.size)
        rec.update(
            status="ok",
            n_chips=n_chips,
            seconds={"lower": round(t_lower, 1),
                     "compile": round(t_compile, 1)},
            memory_analysis={
                "argument_bytes_per_device": int(ma.argument_size_in_bytes),
                "output_bytes_per_device": int(ma.output_size_in_bytes),
                "temp_bytes_per_device": int(ma.temp_size_in_bytes),
                "alias_bytes_per_device": int(ma.alias_size_in_bytes),
                "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                             + ma.output_size_in_bytes
                                             + ma.temp_size_in_bytes
                                             - ma.alias_size_in_bytes),
            },
            xla_cost_analysis={"flops": ca.get("flops", 0.0),
                               "bytes_accessed": ca.get("bytes accessed",
                                                        0.0)},
            hlo_analysis=hlo,
            workload={
                "kind": spec.kind, "seq_len": spec.seq_len,
                "global_batch": spec.global_batch,
                "params": cfg.param_count(),
                "active_params": cfg.active_param_count(),
            },
        )
        print(f"[dryrun] {cell_id}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s, "
              f"peak/device {rec['memory_analysis']['peak_bytes_per_device']/2**30:.2f} GiB, "
              f"flops/device {hlo['flops_per_device']:.3e}, "
              f"coll/device {hlo['collective_bytes_per_device']:.3e} B)")
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {cell_id}: FAIL {rec['error']}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def reanalyze(out_dir="results/dryrun"):
    """Re-run the HLO analyzer over cached .hlo.zst files (no recompiles)."""
    import glob

    import zstandard

    from repro.hw import hlo_analysis

    n = 0
    for jf in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        zf = jf[:-5] + ".hlo.zst"
        if not os.path.exists(zf):
            continue
        with open(jf) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        text = zstandard.ZstdDecompressor().decompress(
            open(zf, "rb").read()).decode()
        rec["hlo_analysis"] = hlo_analysis.analyze(text)
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"[dryrun] reanalyzed {n} cells in {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reanalyze", action="store_true",
                    help="refresh hlo_analysis from cached HLO, no compiles")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "quant", "itera"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--ssm-engine", default="sequential",
                    choices=["sequential", "chunked"])
    ap.add_argument("--variant", default="", choices=sorted(VARIANTS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze(args.out)
        return

    from repro.configs import cells

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    todo = []
    if args.all:
        for a, s, ok, _ in cells(include_skipped=True):
            for m in meshes:
                todo.append((a, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for m in meshes:
            todo.append((args.arch, args.shape, m))

    n_ok = n_skip = n_fail = 0
    for a, s, m in todo:
        rec = run_cell(a, s, m, compression=args.compression,
                       out_dir=args.out, ssm_engine=args.ssm_engine,
                       force=args.force, variant=args.variant)
        st = rec.get("status")
        n_ok += st == "ok"
        n_skip += st == "skipped"
        n_fail += st == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"of {len(todo)}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
