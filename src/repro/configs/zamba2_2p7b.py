"""zamba2-2.7b [hybrid] — Mamba2 backbone with a shared-weight attention
block invoked every `hybrid_period` layers (fresh KV cache per invocation).
[arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        layout="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,                      # shared transformer block MLP
        vocab_size=32000,
        hybrid_period=6,                 # 9 shared-attn invocations
        ssm=SSMConfig(version=2, d_state=64, d_conv=4, expand=2,
                      head_dim=64),
        mlp_act="gelu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        layout="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        hybrid_period=2,
        ssm=SSMConfig(version=2, d_state=8, d_conv=4, expand=2, head_dim=32),
        mlp_act="gelu",
        dtype="float32",
        remat=False,
    )
