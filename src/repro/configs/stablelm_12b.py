"""stablelm-12b [dense] — GQA kv=8, partial rotary.
[hf:stabilityai/stablelm-2-1_6b; hf]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        layout="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=100352,
        mlp_act="swiglu",
        norm="layernorm",
        rotary_pct=0.25,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b-smoke",
        layout="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=256,
        mlp_act="swiglu",
        norm="layernorm",
        rotary_pct=0.25,
        dtype="float32",
        remat=False,
    )
