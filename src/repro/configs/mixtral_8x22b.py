"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        layout="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        attn_window=4096,                 # SWA -> long_500k decodes with an
        moe=MoEConfig(num_experts=8,      # O(window) rolling cache
                      top_k=2,
                      capacity_factor=1.25),
        mlp_act="swiglu",
        rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke",
        layout="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        attn_window=8,
        # cf = E/k: dropless in the smoke tests (prefix consistency)
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0),
        mlp_act="swiglu",
        dtype="float32",
        remat=False,
    )
