"""opus-mt proxy [paper's own model family].

The paper evaluates OPUS-MT (Marian NMT, 6+6 encoder-decoder, d_model=512,
8 heads, d_ff=2048). No WMT data or pretrained weights exist offline, so we
use a 12-layer decoder-only proxy with identical linear-layer geometry —
the compression technique operates on exactly the same 512x512 / 512x2048
matmuls the paper optimizes (its hardware workload M·K·N = 512³ comes from
these layers). DESIGN.md §7 records the substitution.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="opus-mt",
        layout="dense",
        num_layers=12,                   # 6 enc + 6 dec, as decoder layers
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=32000,
        mlp_act="gelu",
        norm="layernorm",
        pos_emb="sinusoidal",
        dtype="float32",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="opus-mt-smoke",
        layout="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        mlp_act="gelu",
        norm="layernorm",
        pos_emb="sinusoidal",
        dtype="float32",
        remat=False,
    )
