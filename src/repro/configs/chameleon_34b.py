"""chameleon-34b [vlm] — early-fusion multimodal decoder over a unified
text + VQ-image token vocabulary. The VQ image tokenizer is a STUB: inputs
arrive as precomputed patch/token embeddings (B, S, d_model).
[arXiv:2405.09818; unverified]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        layout="dense",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,                # text + VQ codes, early fusion
        frontend="vision",
        mlp_act="swiglu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-smoke",
        layout="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        frontend="vision",
        mlp_act="swiglu",
        dtype="float32",
        remat=False,
    )
