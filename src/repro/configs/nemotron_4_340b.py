"""nemotron-4-340b [dense] — GQA kv=8, squared-ReLU MLP.
[arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        layout="dense",
        num_layers=96,
        d_model=18432,
        num_heads=96,
        num_kv_heads=8,
        d_ff=73728,
        vocab_size=256000,
        mlp_act="relu2",                  # squared ReLU
        norm="layernorm",
        rope_theta=10000.0,
        rotary_pct=0.5,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b-smoke",
        layout="dense",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        d_ff=384,
        vocab_size=256,
        mlp_act="relu2",
        norm="layernorm",
        rotary_pct=0.5,
        dtype="float32",
        remat=False,
    )
