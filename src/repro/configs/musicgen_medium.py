"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens.
The EnCodec frontend is a STUB: inputs are precomputed frame embeddings
(B, S, d_model); the decode path generates codec-vocab tokens.
[arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        layout="dense",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,                  # EnCodec codebook
        frontend="audio",
        pos_emb="sinusoidal",
        mlp_act="gelu",
        norm="layernorm",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        layout="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        frontend="audio",
        pos_emb="sinusoidal",
        mlp_act="gelu",
        norm="layernorm",
        dtype="float32",
        remat=False,
    )
