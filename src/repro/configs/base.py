"""Model configuration schema covering every assigned architecture family.

One dataclass drives the whole zoo: dense / MoE / SSM / hybrid layouts,
GQA geometry, attention flavors (sliding window, local-global alternation,
logit soft-capping), MLP flavors (SwiGLU, squared-ReLU, GELU), Mamba1/2
blocks, and stub modality frontends (audio / vision token streams).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    num_shared: int = 0            # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    version: int = 1               # 1 = Mamba1 selective scan, 2 = Mamba2 SSD
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64             # Mamba2 only
    dt_rank: Optional[int] = None  # default d_model // 16
    chunk: int = 128               # chunked-scan block (perf option)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    layout: str = "dense"          # dense | moe | ssm | hybrid
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention flavor
    attn_window: Optional[int] = None       # sliding-window size (Mixtral)
    local_global_period: int = 0            # >0: alternate local/global (Gemma2)
    local_window: int = 4096                # window of the "local" layers
    logit_softcap: float = 0.0              # Gemma2 attn soft-capping
    final_softcap: float = 0.0              # Gemma2 final-logit soft-capping
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0                 # StableLM partial rotary
    pos_emb: str = "rope"                   # rope | sinusoidal | none
    attn_impl: str = "auto"                 # auto | full | chunked
    attn_chunk: int = 1024                  # KV block for chunked attention
    # serving attention over the blocked KV pool (span_attention_paged):
    # "kernel" = Pallas paged-attention (block-table DMA walk, online
    # softmax, in-kernel int8-KV dequant); "ref" = the jnp gather oracle;
    # "auto" = kernel on TPU, oracle on CPU (same dispatch rule as the
    # matmul kernels — interpret-mode Pallas inside the big jitted step
    # would bloat the HLO for tests while the TPU path gets the O(ctx)
    # streaming win).
    paged_attn_impl: str = "auto"           # auto | kernel | ref

    # MLP flavor
    mlp_act: str = "swiglu"                 # swiglu | relu2 | gelu | geglu

    # mixture-of-experts / ssm blocks
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_period: int = 6                  # Zamba2: shared attn every N blocks

    # modality frontend stub: "none" -> token ids; "audio"/"vision" ->
    # precomputed frame/patch embeddings are fed directly (see input_specs).
    frontend: str = "none"

    # numerics / norms
    kv_cache_bits: int = 16                 # 16 (model dtype) | 8 (int8+scales)
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # training-time policy
    remat: bool = True
    remat_policy: str = "full"              # full | dots (save matmul outs)
    loss_chunk: int = 2048                  # vocab-chunked loss block (tokens)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.layout == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM / hybrid / bounded-window attention."""
        if self.layout in ("ssm", "hybrid"):
            return True
        return self.attn_window is not None and self.local_global_period == 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        n = V * d  # embed
        if not self.tie_embeddings:
            n += V * d
        hd = self.head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        mlp_mats = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        mlp = mlp_mats * d * self.d_ff
        if self.layout == "dense":
            n += L * (attn + mlp)
        elif self.layout == "moe":
            e = self.moe.num_experts + self.moe.num_shared
            n += L * (attn + e * mlp + d * self.moe.num_experts)
        elif self.layout == "ssm":
            di = d * self.ssm.expand
            dtr = self.ssm.dt_rank or d // 16
            blk = d * 2 * di + di * (dtr + 2 * self.ssm.d_state) \
                + dtr * di + di * d + di * self.ssm.d_conv + di * self.ssm.d_state
            n += L * blk
        elif self.layout == "hybrid":
            di = d * self.ssm.expand
            nh = di // self.ssm.head_dim
            blk = d * (2 * di + 2 * self.ssm.d_state + nh) + di * d \
                + di * self.ssm.d_conv
            n += L * blk            # mamba2 blocks (no per-block MLP)
            n += attn + mlp         # one shared attention+MLP block
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if self.layout != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        mlp_mats = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        mlp = mlp_mats * d * self.d_ff
        e_all = self.moe.num_experts + self.moe.num_shared
        e_act = self.moe.top_k + self.moe.num_shared
        return self.param_count() - L * (e_all - e_act) * mlp
