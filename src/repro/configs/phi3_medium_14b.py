"""phi3-medium-14b [dense] — RoPE + SwiGLU + GQA kv=10.
[arXiv:2404.14219; unverified]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        layout="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        d_ff=17920,
        vocab_size=100352,
        mlp_act="swiglu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b-smoke",
        layout="dense",
        num_layers=2,
        d_model=80,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=256,
        mlp_act="swiglu",
        dtype="float32",
        remat=False,
    )
