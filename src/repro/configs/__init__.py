"""Architecture registry: the 10 assigned architectures (+ the paper's own
OPUS-MT proxy), each selectable via --arch <id>, and the per-arch input
shapes that define the 40 dry-run cells.

Shapes (LM family — seq_len x global_batch):
  train_4k     4,096 x 256   train_step
  prefill_32k  32,768 x 32   prefill (one pass, returns cache + last logits)
  decode_32k   32,768 x 128  serve_step (1 new token, KV cache of seq_len)
  long_500k    524,288 x 1   serve_step; only sub-quadratic archs (SSM /
                             hybrid / bounded-window) — see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

_MODULES = {
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "opus-mt": "repro.configs.opus_mt",
}

ARCH_IDS = [k for k in _MODULES if k != "opus-mt"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.smoke() if smoke else mod.full()


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """Whether a (arch x shape) cell runs, and why not if it doesn't."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: no sub-quadratic path for a "
                       "512k-token decode cache (DESIGN.md §5)")
    return True, ""


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells in a stable order."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            ok, why = shape_applicable(a, s)
            if ok or include_skipped:
                out.append((a, s, ok, why))
    return out


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeSpec", "SHAPES",
    "ARCH_IDS", "get_config", "shape_applicable", "cells",
]
