"""deepseek-moe-16b [moe] — 2 shared + 64 routed experts top-6, fine-grained.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        layout="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,                        # per fine-grained expert
        vocab_size=102400,
        moe=MoEConfig(num_experts=64,
                      top_k=6,
                      num_shared=2,
                      capacity_factor=1.25),
        mlp_act="swiglu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        layout="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=48,
        vocab_size=256,
        # cf = E/k: dropless in the smoke tests (prefix consistency)
        moe=MoEConfig(num_experts=8, top_k=3, num_shared=1,
                      capacity_factor=2.7),
        mlp_act="swiglu",
        dtype="float32",
        remat=False,
    )
