"""gemma2-9b [dense] — local+global alternating attention, logit softcaps,
GeGLU, head_dim decoupled from d_model/H. [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        layout="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        local_global_period=2,
        local_window=4096,
        logit_softcap=50.0,
        final_softcap=30.0,
        mlp_act="geglu",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-smoke",
        layout="dense",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=256,
        local_global_period=2,
        local_window=8,
        logit_softcap=50.0,
        final_softcap=30.0,
        mlp_act="geglu",
        tie_embeddings=True,
        dtype="float32",
        remat=False,
    )
