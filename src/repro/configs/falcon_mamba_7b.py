"""falcon-mamba-7b [ssm] — attention-free Mamba1 architecture.
[arXiv:2410.05355; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        layout="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=1,                     # unused (attention-free)
        num_kv_heads=1,
        head_dim=64,
        d_ff=0,
        vocab_size=65024,
        ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2,
                      dt_rank=256),
        pos_emb="none",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke",
        layout="ssm",
        num_layers=2,
        d_model=64,
        num_heads=1,
        num_kv_heads=1,
        head_dim=64,
        d_ff=0,
        vocab_size=256,
        ssm=SSMConfig(version=1, d_state=8, d_conv=4, expand=2, dt_rank=8),
        pos_emb="none",
        dtype="float32",
        remat=False,
    )
