"""Public plan→engine API: explore offline, serialize the plan, serve it.

    from repro.api import CompressionPlan, InferenceEngine, SamplingParams
"""
from repro.api.plan import (
    CompressionPlan,
    LayerPlan,
    merge_plans,
)
from repro.api.engine import (
    GenerationResult,
    InferenceEngine,
    SamplingParams,
    ServeResult,
    TokenEvent,
)
from repro.runtime.scheduler import Request
from repro.runtime.speculation import DraftSpec

__all__ = [
    "CompressionPlan", "LayerPlan", "merge_plans",
    "GenerationResult", "InferenceEngine", "SamplingParams",
    "ServeResult", "TokenEvent", "Request", "DraftSpec",
]
