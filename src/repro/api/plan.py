"""Per-layer compression plans — the offline half of the plan→engine seam.

A `CompressionPlan` is an ordered list of `LayerPlan(path, method, wl, rank)`
entries, one per eligible linear weight in the parameter pytree. It is the
serializable artifact that carries a DSE result (paper §VII) into
deployment: explore offline, `plan.save("plan.json")`, then
`InferenceEngine.build(arch, CompressionPlan.load("plan.json"))` online.

Unlike the legacy `core.compress.CompressionConfig` (one global method/wl,
per-layer rank override only), a plan expresses *mixed precision across
layers* — e.g. W4 attention / W8 MLP with differing ranks — which is
exactly the shape of the per-layer configurations the co-design loop
produces. `CompressionConfig` remains as a thin shim that lowers to a
uniform plan (`CompressionPlan.uniform`).

Constructors:
  CompressionPlan.uniform(params, method=..., weight_wl=..., ...)
      — same selection semantics as CompressionConfig (back-compat);
  CompressionPlan.from_design_point(dp)
      — consumes a `hw.dse.DesignPoint`, closing the DSE→deployment loop;
  CompressionPlan.load(path) / loads(text)
      — JSON deserialization (inverse of save / dumps).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable

from repro.runtime.speculation import DraftSpec

METHODS = ("none", "quant", "svd", "itera")
_LOWRANK = ("svd", "itera")
PLAN_FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Compression decision for one pytree weight (a stacked (L, K, N)
    scan-layer leaf counts as one path; rank/wl apply to every slice)."""

    path: str
    method: str = "quant"       # none | quant | svd | itera
    wl: int = 8                 # weight word length in bits
    rank: int | None = None     # decomposition rank; None for none/quant

    def to_dict(self) -> dict:
        d = {"path": self.path, "method": self.method, "wl": self.wl}
        if self.rank is not None:
            d["rank"] = int(self.rank)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "LayerPlan":
        return cls(path=str(d["path"]), method=str(d.get("method", "quant")),
                   wl=int(d.get("wl", 8)),
                   rank=None if d.get("rank") is None else int(d["rank"]))


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    """Ordered per-layer compression decisions + activation-side settings.

    `meta` carries free-form provenance (DSE label, predicted latency,
    calibration accuracy, chosen engines) — serialized but never consulted
    by `compress_params`.
    """

    layers: tuple = ()
    act_wl: int = 8
    power_iters: int = 24
    label: str = ""
    # HBM residency: pack W4 weights two-nibbles-per-byte so the serving
    # path moves wl/8 bytes per weight (kernels unpack in VMEM; exact, so
    # packed and carrier plans generate identical tokens). W6/W8 stay
    # int8-carrier either way and are accounted at 8 bits.
    pack: bool = True
    # Self-speculative decoding config (runtime/speculation.py): the
    # draft model is the plan's own cascade truncated per this spec —
    # part of the deployment artifact because the useful draft depth
    # depends on the plan's ranks. None = engine serves non-speculatively
    # unless build(speculate=...) overrides.
    draft: DraftSpec | None = None
    meta: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------ access --
    def __iter__(self):
        return iter(self.layers)

    def __len__(self) -> int:
        return len(self.layers)

    def by_path(self) -> dict:
        return {lp.path: lp for lp in self.layers}

    def active_layers(self) -> tuple:
        return tuple(lp for lp in self.layers if lp.method != "none")

    def replace(self, **kwargs) -> "CompressionPlan":
        return dataclasses.replace(self, **kwargs)

    # ----------------------------------------------------- serialization --
    def to_dict(self) -> dict:
        d = {
            "format_version": PLAN_FORMAT_VERSION,
            "label": self.label,
            "act_wl": self.act_wl,
            "pack": self.pack,
            "power_iters": self.power_iters,
            "layers": [lp.to_dict() for lp in self.layers],
            "meta": self.meta,
        }
        if self.draft is not None:
            d["draft"] = self.draft.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CompressionPlan":
        v = int(d.get("format_version", PLAN_FORMAT_VERSION))
        if v > PLAN_FORMAT_VERSION:
            raise ValueError(f"plan format_version {v} is newer than "
                             f"supported {PLAN_FORMAT_VERSION}")
        return cls(
            layers=tuple(LayerPlan.from_dict(l) for l in d.get("layers", ())),
            act_wl=int(d.get("act_wl", 8)),
            pack=bool(d.get("pack", True)),
            power_iters=int(d.get("power_iters", 24)),
            label=str(d.get("label", "")),
            draft=(None if d.get("draft") is None
                   else DraftSpec.from_dict(d["draft"])),
            meta=dict(d.get("meta", {})),
        )

    def dumps(self, *, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=False)

    @classmethod
    def loads(cls, text: str) -> "CompressionPlan":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps() + "\n")

    @classmethod
    def load(cls, path: str) -> "CompressionPlan":
        with open(path) as f:
            return cls.loads(f.read())

    # -------------------------------------------------------- validation --
    def validate(self, params=None) -> "CompressionPlan":
        """Check internal consistency, and — given a param tree — that every
        path resolves to a 2-D+ weight with rank <= min(K, N). Returns self
        so calls chain; raises ValueError on the first violation."""
        seen = set()
        for lp in self.layers:
            if lp.method not in METHODS:
                raise ValueError(f"{lp.path}: unknown method {lp.method!r} "
                                 f"(expected one of {METHODS})")
            if not 2 <= lp.wl <= 8:
                raise ValueError(f"{lp.path}: wl={lp.wl} outside [2, 8]")
            if lp.method in _LOWRANK and (lp.rank is None or lp.rank < 1):
                raise ValueError(f"{lp.path}: method {lp.method!r} needs a "
                                 f"positive rank, got {lp.rank}")
            if lp.method not in _LOWRANK and lp.rank is not None:
                raise ValueError(f"{lp.path}: rank={lp.rank} is meaningless "
                                 f"for method {lp.method!r}")
            if lp.path in seen:
                raise ValueError(f"duplicate plan entry for {lp.path}")
            seen.add(lp.path)
        if not 2 <= self.act_wl <= 8:
            raise ValueError(f"act_wl={self.act_wl} outside [2, 8]")
        if params is not None:
            self._validate_against(params)
        return self

    def _validate_against(self, params) -> None:
        from repro.core.compress import param_leaves_by_path

        leaves = param_leaves_by_path(params)
        for lp in self.layers:
            if lp.path not in leaves:
                raise ValueError(f"plan path {lp.path!r} not found in the "
                                 f"parameter tree")
            leaf = leaves[lp.path]
            if getattr(leaf, "ndim", 0) < 2:
                raise ValueError(f"{lp.path}: not a 2-D+ weight "
                                 f"(ndim={getattr(leaf, 'ndim', 0)})")
            full = int(min(leaf.shape[-2:]))
            if lp.rank is not None and lp.rank > full:
                raise ValueError(f"{lp.path}: rank {lp.rank} exceeds "
                                 f"min(K, N) = {full}")

    # ------------------------------------------------------ constructors --
    @classmethod
    def uniform(cls, params, *, method: str = "quant", weight_wl: int = 8,
                act_wl: int = 8, rank_fraction: float = 0.5,
                ranks: dict | None = None, label: str = "",
                power_iters: int = 24, **selection) -> "CompressionPlan":
        """One plan entry per eligible linear, all with the same method/wl —
        the exact semantics of the legacy CompressionConfig (whose selection
        knobs include/exclude/min_dim/rank_multiple pass through)."""
        from repro.core.compress import CompressionConfig

        cfg = CompressionConfig(method=method, weight_wl=weight_wl,
                                act_wl=act_wl, rank_fraction=rank_fraction,
                                ranks=ranks, power_iters=power_iters,
                                **selection)
        return cls.from_config(params, cfg, label=label)

    @classmethod
    def from_config(cls, params, cfg, label: str = "") -> "CompressionPlan":
        """Lower a CompressionConfig against a param tree (the shim path)."""
        from repro.core.compress import eligible_linears

        entries = []
        for path, leaf in eligible_linears(params, cfg):
            kn = (int(leaf.shape[-2]), int(leaf.shape[-1]))
            rank = (cfg.rank_for(path, kn)
                    if cfg.method in _LOWRANK else None)
            entries.append(LayerPlan(path=path, method=cfg.method,
                                     wl=cfg.weight_wl, rank=rank))
        label = label or (f"{cfg.method}_W{cfg.weight_wl}"
                          if cfg.method != "none" else "none")
        return cls(layers=tuple(entries), act_wl=cfg.act_wl,
                   pack=cfg.pack, power_iters=cfg.power_iters,
                   label=label).validate()

    @classmethod
    def from_design_point(cls, dp) -> "CompressionPlan":
        """Extract the deployable plan from a `hw.dse.DesignPoint`.

        The DSE attaches the candidate plan it evaluated to every design
        point; this re-labels it with the point's provenance (quality,
        latency, per-layer engine choices) so the serialized artifact is
        self-describing."""
        plan = getattr(dp, "plan", None)
        if plan is None:
            raise ValueError(
                "DesignPoint carries no plan — run hw.dse.co_design with "
                "CompressionPlan candidates (dict candidates are legacy)")
        meta = dict(plan.meta)
        meta.update({
            "design_point": dp.label,
            "quality": float(dp.quality),
            "latency": float(dp.latency),
            "engines": [[name, kind] for name, kind, _, _ in dp.per_layer],
        })
        return plan.replace(label=dp.label or plan.label,
                            meta=meta).validate()

    # ---------------------------------------------------------- summary --
    def summary(self) -> str:
        from collections import Counter

        groups = Counter(f"{lp.method}_W{lp.wl}" for lp in self.layers)
        body = " ".join(f"{k}x{v}" for k, v in sorted(groups.items()))
        resid = "packed" if self.pack else "carrier"
        spec = ""
        if self.draft is not None:
            spec = (f", draft k={self.draft.k} "
                    f"r×{self.draft.rank_fraction:g}")
        return f"plan[{self.label or 'unlabeled'}] {len(self.layers)} " \
               f"layers: {body} (A{self.act_wl}, {resid}{spec})"


def merge_plans(base: CompressionPlan,
                overrides: Iterable[LayerPlan]) -> CompressionPlan:
    """New plan with `overrides` replacing matching-path entries of `base`
    (order preserved; non-matching overrides are appended)."""
    by_path = {lp.path: lp for lp in overrides}
    out = [by_path.pop(lp.path, lp) for lp in base.layers]
    out.extend(by_path.values())
    return base.replace(layers=tuple(out))
