"""`InferenceEngine` — the online half of the plan→engine seam.

Build compiles a serving engine from (architecture, CompressionPlan):
compress the weights per the plan, optionally place them on a device mesh,
and jit the prefill / decode-step callables once. Generation then runs any
number of batched requests against the same compiled engine:

    plan = CompressionPlan.load("plan.json")          # e.g. a DSE winner
    eng = InferenceEngine.build("opus-mt", plan, smoke=True)
    out = eng.generate(prompts, SamplingParams(max_tokens=32, top_k=40))

Two serving paths share the compiled model:

  * `generate` on a rectangular (B, S) batch — prefill once, decode in
    lockstep; the static-batching baseline.
  * `serve` (which `generate` uses for ragged prompt lists) — continuous
    batching: a `runtime.scheduler.Scheduler` admits requests into a
    fixed-capacity masked decode batch backed by a `runtime.kvblocks`
    blocked KV pool; rows join after individual prefill and leave the
    moment they finish, with their blocks returned to the pool.

`launch.serve` is a thin CLI over this class; every future serving feature
(KV paging variants, multi-host decode) lands behind this facade rather
than in loose scripts.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import CompressionPlan
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.compress import CompressionConfig, compress_params
from repro.models import transformer as tfm
from repro.runtime import kvblocks
from repro.runtime.scheduler import Request, Scheduler


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-generate sampling controls. temperature <= 0 means greedy;
    top_k == 0 samples the full vocabulary."""

    max_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_tokens) int32
    prompt_len: int             # ragged batches: the longest prompt
    seconds: float
    prompt_lens: list[int] | None = None   # set for ragged batches

    @property
    def tokens_per_second(self) -> float:
        b, g = self.tokens.shape
        return b * g / max(self.seconds, 1e-9)


@dataclasses.dataclass
class ServeResult:
    """Continuous-batching outcome: per-request continuations in
    submission order, plus the scheduler's step/occupancy accounting."""

    outputs: list[np.ndarray]   # outputs[i]: (requests[i].max_tokens,) int32
    prompt_lens: list[int]
    seconds: float
    steps: int                  # shared decode steps executed
    prefills: int               # individual prompt prefills
    max_queue_depth: int        # peak waiting-queue length (overflow proof)
    max_batch: int
    block_size: int
    num_blocks: int

    @property
    def total_tokens(self) -> int:
        return int(sum(o.size for o in self.outputs))

    @property
    def tokens_per_second(self) -> float:
        return self.total_tokens / max(self.seconds, 1e-9)


def _as_token_batch(requests):
    """Normalize requests: a (B, S) int32 array when rectangular, else a
    list of 1-D int32 prompts (the caller routes those through the
    continuous-batching scheduler)."""
    if isinstance(requests, (list, tuple)):
        if not requests:
            raise ValueError("empty request batch")
        rows = [np.asarray(r, np.int32) for r in requests]
        if any(r.ndim != 1 for r in rows):
            raise ValueError(
                f"each request must be a 1-D token sequence, got shapes "
                f"{[r.shape for r in rows]}")
        if any(r.size == 0 for r in rows):
            raise ValueError("empty prompt in request batch")
        if len({r.size for r in rows}) != 1:
            return rows
        requests = np.stack(rows)
    toks = jnp.asarray(requests, jnp.int32)
    if toks.ndim != 2:
        raise ValueError(f"requests must be (batch, seq), got {toks.shape}")
    return toks


class InferenceEngine:
    """Compiled compress→shard→serve pipeline for one model + plan."""

    def __init__(self, cfg: ModelConfig, params, *, plan=None, report=None,
                 mesh=None, max_batch: int = 8, block_size: int = 16):
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.report = report
        self.mesh = mesh
        self.max_batch = max_batch      # serve(): decode-batch capacity
        self.block_size = block_size    # serve(): KV block size (tokens)
        # jit once; XLA re-specializes per (batch, seq, max_len) shape.
        self._prefill = jax.jit(
            lambda p, toks, max_len: tfm.prefill(p, toks, cfg,
                                                 max_len=max_len),
            static_argnums=2)
        self._decode = jax.jit(
            lambda p, cache, tok, pos: tfm.decode_step(p, cache, tok, pos,
                                                       cfg))
        # continuous-batching step: static in (capacity, max blocks/seq),
        # so one compilation serves the whole admit/evict loop.
        self._decode_paged = jax.jit(
            lambda p, pool, bt, lens, tok: tfm.decode_step_paged(
                p, pool, bt, lens, tok, cfg))
        self._pack = jax.jit(kvblocks.pack_prefill)

    # ------------------------------------------------------------- build --
    @classmethod
    def build(cls, arch, plan=None, *, mesh=None, params=None,
              smoke: bool = False, seed: int = 0, verbose: bool = False,
              max_batch: int = 8, block_size: int = 16) -> "InferenceEngine":
        """arch: config name (see repro.configs) or a ModelConfig.
        plan: CompressionPlan | legacy CompressionConfig | None (dense).
        params: pre-trained weights; freshly initialized when omitted.
        mesh: optional jax Mesh — weights are placed per launch.sharding.
        max_batch / block_size: continuous-batching defaults for serve()."""
        cfg = get_config(arch, smoke=smoke) if isinstance(arch, str) else arch
        if params is None:
            params = tfm.init_params(jax.random.PRNGKey(seed), cfg)

        report = None
        if isinstance(plan, CompressionConfig):
            plan = (None if plan.method == "none"
                    else CompressionPlan.from_config(params, plan))
        if plan is not None:
            t0 = time.time()
            params, report = compress_params(params, plan)
            plan = report.plan
            if verbose:
                print(f"[engine] compressed in {time.time()-t0:.1f}s: "
                      f"{report.summary()}")

        if mesh is not None:
            from repro.launch import sharding as shd

            params = jax.device_put(params,
                                    shd.param_shardings(params, mesh, cfg))
        return cls(cfg, params, plan=plan, report=report, mesh=mesh,
                   max_batch=max_batch, block_size=block_size)

    # ---------------------------------------------------------- generate --
    def generate(self, requests, sampling: SamplingParams | None = None
                 ) -> GenerationResult:
        """Generate continuations for a batch of requests.

        requests: (B, S) int tokens — array or list of token lists. Equal
        lengths run the rectangular lockstep path; ragged lengths are
        served by the continuous-batching scheduler (`serve`), prefilled
        individually and decoded in a shared masked batch. Either way the
        result is the generated continuation only, (B, max_tokens), in
        request order — greedy outputs are token-identical between the
        two paths and to running each prompt alone.
        """
        sampling = sampling or SamplingParams()
        toks = _as_token_batch(requests)
        if isinstance(toks, list):          # ragged -> continuous batching
            res = self.serve(toks, sampling)
            return GenerationResult(
                tokens=np.stack(res.outputs).astype(np.int32),
                prompt_len=max(res.prompt_lens), seconds=res.seconds,
                prompt_lens=list(res.prompt_lens))
        s = toks.shape[1]
        max_len = s + sampling.max_tokens

        from repro.runtime import shardctx

        ctx = (shardctx.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        t0 = time.time()
        with ctx:
            logits, cache = self._prefill(self.params, toks, max_len)
            key = jax.random.PRNGKey(sampling.seed)
            out = []
            key, k = jax.random.split(key)
            tok = self._pick(logits, k, sampling)
            for i in range(sampling.max_tokens):
                out.append(tok)
                if i + 1 == sampling.max_tokens:
                    break
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.asarray(s + i))
                key, k = jax.random.split(key)
                tok = self._pick(logits, k, sampling)
            gen = jax.block_until_ready(jnp.concatenate(out, axis=1))
        return GenerationResult(tokens=np.asarray(gen), prompt_len=s,
                                seconds=time.time() - t0)

    # ------------------------------------------------------------- serve --
    def serve(self, requests, sampling: SamplingParams | None = None, *,
              max_batch: int | None = None, block_size: int | None = None,
              num_blocks: int | None = None) -> ServeResult:
        """Continuous batching: ragged prompts, per-request max_tokens.

        requests: list of token sequences or `runtime.scheduler.Request`s
        (the latter carry their own max_tokens; otherwise
        `sampling.max_tokens` applies). Requests are admitted FCFS into a
        fixed-capacity decode batch: each is prefilled individually, its
        KV packed into pool blocks, and its row decodes alongside whatever
        else is in flight; finished rows free their blocks immediately and
        the next waiting request takes the slot mid-flight. Overflow
        (rows or blocks) queues — it never crashes the batch.

        num_blocks defaults to enough for max_batch worst-case sequences,
        i.e. admission is then only row-limited. Pass a smaller pool to
        exercise block-limited admission.
        """
        sampling = sampling or SamplingParams()
        reqs: list[Request] = []
        for i, r in enumerate(requests):
            if not isinstance(r, Request):
                r = Request(tokens=r)
            if r.max_tokens is None:
                r = dataclasses.replace(r, max_tokens=sampling.max_tokens)
            reqs.append(dataclasses.replace(r, rid=i))
        if not reqs:
            raise ValueError("empty request batch")
        kvblocks.check_paged_support(self.cfg)

        bs = block_size or self.block_size
        cap = min(max_batch or self.max_batch, len(reqs))
        need = [kvblocks.blocks_needed(r.tokens.size, r.max_tokens, bs)
                for r in reqs]
        mb = max(max(need), 1)              # block-table width (static)
        if num_blocks is None:
            num_blocks = cap * mb + 1       # +1: reserved trash block
        pool_alloc = kvblocks.BlockPool(num_blocks, bs)
        sched = Scheduler(pool_alloc, cap)
        for r in reqs:
            sched.submit(r)

        pool = kvblocks.init_paged_cache(self.cfg, num_blocks, bs)
        tables = np.zeros((cap, mb), np.int32)
        lengths = np.zeros((cap,), np.int32)
        cur_tok = np.zeros((cap, 1), np.int32)
        active = np.zeros((cap,), bool)
        outputs: list[np.ndarray | None] = [None] * len(reqs)
        steps = prefills = 0
        key = jax.random.PRNGKey(sampling.seed)

        from repro.runtime import shardctx

        ctx = (shardctx.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        t0 = time.time()
        with ctx:
            while sched.has_work():
                # -- admission: prefill each newly admitted request alone --
                while (seq := sched.try_admit()) is not None:
                    nb_p = -(-seq.prompt_len // bs)
                    toks1 = jnp.asarray(seq.req.tokens[None], jnp.int32)
                    logits, cache = self._prefill(self.params, toks1,
                                                  nb_p * bs)
                    prefills += 1
                    key, k = jax.random.split(key)
                    tok = self._pick(logits, k, sampling)
                    seq.out.append(int(np.asarray(tok)[0, 0]))
                    if seq.done:            # max_tokens == 1: never decodes
                        outputs[seq.req.rid] = np.asarray(seq.out, np.int32)
                        sched.finish(seq)
                        continue
                    pool = self._pack(pool, cache["kv"],
                                      jnp.asarray(seq.block_ids[:nb_p],
                                                  jnp.int32))
                    r = seq.row
                    tables[r] = 0
                    tables[r, :len(seq.block_ids)] = seq.block_ids
                    lengths[r] = seq.prompt_len
                    cur_tok[r, 0] = seq.out[-1]
                    active[r] = True
                if not active.any():
                    break                   # queue drained by admission
                # -- one shared decode step over the masked batch ----------
                logits, pool = self._decode_paged(
                    self.params, pool, jnp.asarray(tables),
                    jnp.asarray(lengths), jnp.asarray(cur_tok))
                steps += 1
                key, k = jax.random.split(key)
                toks = np.asarray(self._pick(logits, k, sampling))
                lengths[active] += 1        # the step wrote position `len`
                # -- record tokens, evict finished rows --------------------
                for r in np.nonzero(active)[0]:
                    seq = sched.rows[r]
                    seq.out.append(int(toks[r, 0]))
                    if seq.done:
                        outputs[seq.req.rid] = np.asarray(seq.out, np.int32)
                        sched.finish(seq)
                        active[r] = False
                        tables[r] = 0
                        lengths[r] = 0
                        cur_tok[r, 0] = 0
                    else:
                        cur_tok[r, 0] = toks[r, 0]
        if pool_alloc.available != pool_alloc.capacity:
            raise RuntimeError(
                f"leaked KV blocks: {pool_alloc.capacity - pool_alloc.available}"
                f" of {pool_alloc.capacity} still allocated after drain")
        return ServeResult(
            outputs=outputs, prompt_lens=[r.tokens.size for r in reqs],
            seconds=time.time() - t0, steps=steps, prefills=prefills,
            max_queue_depth=sched.max_queue_depth, max_batch=cap,
            block_size=bs, num_blocks=num_blocks)

    @staticmethod
    def _pick(logits, key, sampling: SamplingParams) -> jnp.ndarray:
        """(B, 1) next tokens from (B, ..., V) last-position logits."""
        last = logits[:, -1]
        if sampling.temperature <= 0.0:
            return jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
        scaled = last / sampling.temperature
        if sampling.top_k > 0 and sampling.top_k < scaled.shape[-1]:
            kth = jax.lax.top_k(scaled, sampling.top_k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return jax.random.categorical(key, scaled)[:, None].astype(jnp.int32)
