"""`InferenceEngine` — the online half of the plan→engine seam.

Build compiles a serving engine from (architecture, CompressionPlan):
compress the weights per the plan, optionally place them on a device mesh,
and jit the prefill / step callables once. Generation then runs any
number of batched requests against the same compiled engine:

    plan = CompressionPlan.load("plan.json")          # e.g. a DSE winner
    eng = InferenceEngine.build("opus-mt", plan, smoke=True)
    out = eng.generate(prompts, SamplingParams(max_tokens=32, top_k=40))

Two serving paths share the compiled model:

  * `generate` on a rectangular (B, S) batch — prefill once (prompts are
    right-padded to power-of-two length buckets, so N distinct lengths
    cost O(log N) compilations), decode in lockstep; the static-batching
    baseline.
  * `serve` (which `generate` uses for ragged prompt lists) — in-flight
    batching with chunked prefill: every forward pass is ONE jitted
    token-budget step (`models.transformer.unified_step`) that mixes
    prefill chunks of newly admitted prompts with in-flight decode rows
    over a `runtime.kvblocks` blocked KV pool, scheduled by
    `runtime.scheduler.Scheduler`. There is no solo-prefill path: a
    prompt enters the pool chunk by chunk while older rows keep
    decoding, and rows leave the moment they finish, returning their
    blocks to the pool.

`launch.serve` is a thin CLI over this class; every future serving feature
(KV paging variants, multi-host decode) lands behind this facade rather
than in loose scripts.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import CompressionPlan
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.compress import CompressionConfig, compress_params
from repro.models import transformer as tfm
from repro.runtime import kvblocks
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.speculation import DraftSpec, SpeculationController


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-generate sampling controls. temperature <= 0 means greedy;
    top_k == 0 samples the full vocabulary."""

    max_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_tokens) int32
    prompt_len: int             # ragged batches: the longest prompt
    seconds: float
    prompt_lens: list[int] | None = None   # set for ragged batches

    @property
    def tokens_per_second(self) -> float:
        b, g = self.tokens.shape
        return b * g / max(self.seconds, 1e-9)


def _percentile(xs, q) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


@dataclasses.dataclass
class ServeResult:
    """In-flight batching outcome: per-request continuations in
    submission order, plus step/chunk/latency accounting."""

    outputs: list[np.ndarray]   # outputs[i]: (requests[i].max_tokens,) int32
    prompt_lens: list[int]
    seconds: float
    steps: int                  # unified token-budget steps executed
    prefill_chunks: int         # prompt chunks processed across all steps
    prefill_tokens: int         # prompt tokens entered via those chunks
    mixed_steps: int            # steps running prefill AND decode together
    chunk_tokens: int           # the per-step token budget
    max_queue_depth: int        # peak waiting-queue length (overflow proof)
    max_batch: int
    block_size: int
    num_blocks: int
    ttft: list[float] = dataclasses.field(default_factory=list)
    tpot: list[float] = dataclasses.field(default_factory=list)
    # self-speculative decoding accounting (0 when speculation is off):
    # over the whole serve, `drafted` draft tokens were proposed and
    # `accepted` of them survived full-model verification across
    # `spec_rounds` drafting rounds of width spec_k.
    spec_k: int = 0
    drafted: int = 0
    accepted: int = 0
    spec_rounds: int = 0
    # prefix-cache accounting (all zero when prefix_cache is False):
    # admission looked up `cache_lookup_blocks` full prompt blocks in the
    # pool's content index, mapped `cache_hit_blocks` of them by
    # reference (skipping `cache_hit_tokens` prompt tokens of prefill),
    # copy-on-wrote `cache_cow_blocks` final blocks of fully-cached
    # prompts, and the pool evicted `cache_evictions` idle cached blocks
    # under pressure. `preemptions` counts pool-pressure victim requeues.
    prefix_cache: bool = False
    cache_lookup_blocks: int = 0
    cache_hit_blocks: int = 0
    cache_hit_tokens: int = 0
    cache_cow_blocks: int = 0
    cache_evictions: int = 0
    preemptions: int = 0

    @property
    def accept_rate(self) -> float:
        """Fraction of proposed draft tokens the full model kept."""
        return self.accepted / self.drafted if self.drafted else 0.0

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of looked-up full prompt blocks served by reference."""
        return (self.cache_hit_blocks / self.cache_lookup_blocks
                if self.cache_lookup_blocks else 0.0)

    @property
    def cache_hit_token_rate(self) -> float:
        """Fraction of all prompt tokens whose prefill was skipped."""
        total = sum(self.prompt_lens)
        return self.cache_hit_tokens / total if total else 0.0

    @property
    def cache_blocks_saved(self) -> int:
        """Physical blocks admission did not allocate thanks to sharing
        (hit blocks mapped by reference; COW sources still cost a private
        copy, so they don't count)."""
        return self.cache_hit_blocks - self.cache_cow_blocks

    @property
    def total_tokens(self) -> int:
        return int(sum(o.size for o in self.outputs))

    @property
    def tokens_per_second(self) -> float:
        return self.total_tokens / max(self.seconds, 1e-9)

    # per-request latency aggregates (seconds). ttft[i] is measured from
    # serve() start to request i's first sampled token; tpot[i] is the
    # mean inter-token time over its remaining outputs (0.0 for
    # single-token requests).
    @property
    def ttft_p50(self) -> float:
        return _percentile(self.ttft, 50)

    @property
    def ttft_p95(self) -> float:
        return _percentile(self.ttft, 95)

    @property
    def tpot_p50(self) -> float:
        return _percentile([t for t in self.tpot if t > 0], 50)

    @property
    def tpot_p95(self) -> float:
        return _percentile([t for t in self.tpot if t > 0], 95)


def _as_token_batch(requests):
    """Normalize requests: a (B, S) int32 array when rectangular, else a
    list of 1-D int32 prompts (the caller routes those through the
    continuous-batching scheduler)."""
    if isinstance(requests, (list, tuple)):
        if not requests:
            raise ValueError("empty request batch")
        rows = [np.asarray(r, np.int32) for r in requests]
        if any(r.ndim != 1 for r in rows):
            raise ValueError(
                f"each request must be a 1-D token sequence, got shapes "
                f"{[r.shape for r in rows]}")
        if any(r.size == 0 for r in rows):
            raise ValueError("empty prompt in request batch")
        if len({r.size for r in rows}) != 1:
            return rows
        requests = np.stack(rows)
    toks = jnp.asarray(requests, jnp.int32)
    if toks.ndim != 2:
        raise ValueError(f"requests must be (batch, seq), got {toks.shape}")
    return toks


def _pow2_bucket(n: int) -> int:
    """Smallest power of two >= n."""
    return 1 << max(n - 1, 0).bit_length()


def _tree_nbytes(tree) -> int:
    """Bytes a pytree's arrays actually occupy (packed nibble arrays
    report their true halved size) — the single definition of measured
    weight residency."""
    return sum(int(getattr(l, "nbytes", 0))
               for l in jax.tree_util.tree_leaves(tree))


def _serve_step(params, pool, block_tables, step_buf, prev, cfg):
    """One fused serving dispatch. step_buf: (B, W + 3) int32 — the
    host-built span tokens (B, W) with three metadata columns appended
    (ctx_lens, q_lens, use_prev), packed so the hot loop uploads ONE
    array per step. Decode rows' first token column is spliced from
    `prev` (the previous step's device-resident sampled tokens) so token
    values never round-trip through the host. Returns (logits (B, 1, V),
    greedy next tokens (B, 1), pool)."""
    tokens = step_buf[:, :-3]
    ctx_lens, q_lens, use_prev = (step_buf[:, -3], step_buf[:, -2],
                                  step_buf[:, -1])
    tokens = tokens.at[:, 0].set(
        jnp.where(use_prev.astype(bool), prev[:, 0], tokens[:, 0]))
    logits, pool = tfm.unified_step(params, pool, block_tables, ctx_lens,
                                    q_lens, tokens, cfg)
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return logits, toks, pool


class InferenceEngine:
    """Compiled compress→shard→serve pipeline for one model + plan."""

    def __init__(self, cfg: ModelConfig, params, *, plan=None, report=None,
                 mesh=None, max_batch: int = 8, block_size: int = 16,
                 chunk_tokens: int = 256, bucket_prompts: bool = True,
                 speculate: DraftSpec | None = None,
                 prefix_cache: bool = True):
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.report = report
        self.mesh = mesh
        # prefix caching (serve): share full KV blocks between requests
        # with equal position-aligned prompt prefixes. The content-hash
        # chain is seeded with a model+plan fingerprint so blocks can
        # never be shared across engines whose K/V for the same tokens
        # would differ (different weights, dtype, or KV residency).
        self.prefix_cache = prefix_cache
        try:
            plan_id = plan.dumps() if plan is not None else "dense"
        except TypeError:           # unserializable plan metadata
            plan_id = repr(plan)
        self._cache_fingerprint = hashlib.sha256(
            (f"{getattr(cfg, 'name', 'model')}:{cfg.dtype}:"
             f"{getattr(cfg, 'kv_cache_bits', 16)}:{plan_id}")
            .encode()).digest()
        # tensor-parallel serving: a mesh with a "model" axis shard-maps
        # the unified step — params column/row-sliced, the KV pool
        # head-sliced, one psum per attention/MLP boundary. The mesh
        # model-axis size IS the TP degree (1 runs the same path).
        self._tp = (int(mesh.shape["model"])
                    if mesh is not None and "model" in mesh.axis_names
                    else 0)
        # self-speculative decoding: derive the truncated-cascade draft
        # tree once at engine construction (it shares every dense array
        # with `params` by reference — no second checkpoint in HBM)
        self.speculation = (SpeculationController(speculate, cfg, params,
                                                  mesh=mesh)
                            if speculate is not None else None)
        self.max_batch = max_batch      # serve(): batch-row capacity
        self.block_size = block_size    # serve(): KV block size (tokens)
        self.chunk_tokens = chunk_tokens  # serve(): per-step token budget
        # generate(): right-pad prompts to power-of-two length buckets so
        # N distinct lengths cost O(log N) prefill compilations. Only
        # sound where right-padding is inert: dense global causal
        # attention (padding K/V slots are overwritten before any decode
        # query can see them). Rolling/windowed caches and SSM state
        # fold padding into what decode reads, and MoE expert routing is
        # capacity-bounded per batch — pad tokens compete for expert
        # slots and can displace real tokens — so those archs prefill at
        # exact length.
        self.bucket_prompts = bucket_prompts and self._can_bucket(cfg)
        # jit once; XLA re-specializes per (batch, seq, max_len) shape.
        self._prefill = jax.jit(
            lambda p, toks, max_len, last: tfm.prefill(p, toks, cfg,
                                                       max_len=max_len,
                                                       last_pos=last),
            static_argnums=2)
        self._decode = jax.jit(
            lambda p, cache, tok, pos: tfm.decode_step(p, cache, tok, pos,
                                                       cfg))
        # the unified serving step: static in (capacity, span width, max
        # blocks/seq); the span width is power-of-two bucketed, so one
        # jitted function in O(log chunk_tokens) shapes serves the whole
        # admit/chunk/decode/evict loop. Everything per-step is fused
        # into this single dispatch — splicing the previous step's
        # device-resident sampled tokens into decode rows, the forward
        # pass, and the greedy argmax — because serving throughput on
        # small steps is bounded by host dispatch overhead, not FLOPs.
        self._unified = jax.jit(
            lambda p, pool, bt, buf, prev: _serve_step(
                p, pool, bt, buf, prev, cfg))
        if self._tp:
            # shard_map the SAME fused step: each shard runs it with the
            # per-shard config (its slice of heads / hidden columns) over
            # its head-slice of the pool; tokens / tables / buffers are
            # replicated. tp_axis binds at trace time, so the boundary
            # psums in transformer.unified_step land in this jaxpr only.
            from jax.sharding import PartitionSpec as P

            from repro.launch import sharding as shd
            from repro.runtime import shardctx

            shd.check_tp_geometry(cfg, self._tp)
            lcfg = shd.tp_local_config(cfg, self._tp)
            pspecs = shd.tp_param_specs(params, self._tp)
            pool_specs = kvblocks.pool_pspecs(cfg)

            def tp_body(p, pool, bt, buf, prev):
                with shardctx.tp_axis("model"):
                    return _serve_step(p, pool, bt, buf, prev, lcfg)

            self._unified = jax.jit(shardctx.tp_shard_map(
                tp_body, mesh,
                in_specs=(pspecs, pool_specs, P(), P(), P()),
                out_specs=(P(), P(), pool_specs)))
        # greedy sampling is the serving hot path: one fused jitted argmax
        # instead of a chain of eager ops + PRNG key splits per step.
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg[:, -1], axis=-1)[:, None]
            .astype(jnp.int32))
        # copy-on-write block duplication for fully-cached prompts; block
        # indices are traced scalars so one trace covers every copy, and
        # the op moves along the (unsharded) block axis so it is TP-inert.
        self._cow_copy = jax.jit(kvblocks.copy_block)

    @staticmethod
    def _can_bucket(cfg) -> bool:
        return (cfg.layout == "dense"
                and not cfg.attn_window and not cfg.local_global_period)

    def weight_hbm_bytes(self) -> int:
        """Bytes the parameter arrays actually occupy in device memory —
        the number the packed-W4 residency work shrinks. Measured
        residency (`.nbytes` per leaf), not an accounting claim."""
        return _tree_nbytes(self.params)

    # ------------------------------------------------------------- build --
    @classmethod
    def build(cls, arch, plan=None, *, mesh=None, params=None,
              smoke: bool = False, seed: int = 0, verbose: bool = False,
              max_batch: int = 8, block_size: int = 16,
              chunk_tokens: int = 256,
              paged_attn: str | None = None,
              speculate=None, prefix_cache: bool = True
              ) -> "InferenceEngine":
        """arch: config name (see repro.configs) or a ModelConfig.
        plan: CompressionPlan | legacy CompressionConfig | None (dense).
        params: pre-trained weights; freshly initialized when omitted.
        mesh: optional jax Mesh — weights are placed per launch.sharding.
        max_batch / block_size / chunk_tokens: serving defaults for
        serve() — batch rows, KV block size, per-step token budget.
        paged_attn: override cfg.paged_attn_impl for the serving
        attention backend — "auto" (Pallas kernel on TPU, jnp gather
        oracle on CPU), "kernel", or "ref".
        speculate: self-speculative decoding config. None defers to
        `plan.draft`; a `DraftSpec` (or int draft depth k, or True for
        the defaults) turns it on regardless of the plan; False/0 forces
        it off even when the plan carries a draft spec.
        prefix_cache: serve() default for KV prefix sharing (overridable
        per serve call)."""
        cfg = get_config(arch, smoke=smoke) if isinstance(arch, str) else arch
        if paged_attn is not None:
            cfg = dataclasses.replace(cfg, paged_attn_impl=paged_attn)
        if params is None:
            params = tfm.init_params(jax.random.PRNGKey(seed), cfg)

        report = None
        if isinstance(plan, CompressionConfig):
            plan = (None if plan.method == "none"
                    else CompressionPlan.from_config(params, plan))
        if plan is not None:
            t0 = time.time()
            params, report = compress_params(params, plan)
            plan = report.plan
            if verbose:
                print(f"[engine] compressed in {time.time()-t0:.1f}s: "
                      f"{report.summary()} "
                      f"resident={_tree_nbytes(params)/2**20:.1f}MiB")

        if mesh is not None:
            from repro.launch import sharding as shd

            if "model" in mesh.axis_names:
                # tensor-parallel serving placement: literal shard_map
                # slices (launch.sharding._TP_RULES), so every leaf is
                # already where its shard needs it and no per-dispatch
                # resharding happens. Geometry must divide exactly.
                shd.check_tp_geometry(cfg, int(mesh.shape["model"]))
                params = jax.device_put(params,
                                        shd.tp_param_shardings(params, mesh))
            else:
                params = jax.device_put(
                    params, shd.param_shardings(params, mesh, cfg))
        if isinstance(speculate, DraftSpec):
            spec = speculate
        elif speculate is None:
            spec = plan.draft if plan is not None else None
        elif speculate is True:
            spec = (plan.draft if plan is not None and plan.draft is not None
                    else DraftSpec())
        elif not speculate:             # False / 0: explicit off
            spec = None
        else:
            spec = DraftSpec(k=int(speculate))
        return cls(cfg, params, plan=plan, report=report, mesh=mesh,
                   max_batch=max_batch, block_size=block_size,
                   chunk_tokens=chunk_tokens, speculate=spec,
                   prefix_cache=prefix_cache)

    # ---------------------------------------------------------- generate --
    def generate(self, requests, sampling: SamplingParams | None = None
                 ) -> GenerationResult:
        """Generate continuations for a batch of requests.

        requests: (B, S) int tokens — array or list of token lists. Equal
        lengths run the rectangular lockstep path; ragged lengths are
        served by the in-flight batching scheduler (`serve`) through the
        unified token-budget step. Either way the result is the generated
        continuation only, (B, max_tokens), in request order — greedy
        outputs are token-identical between the two paths and to running
        each prompt alone.
        """
        sampling = sampling or SamplingParams()
        toks = _as_token_batch(requests)
        if isinstance(toks, list):          # ragged -> continuous batching
            res = self.serve(toks, sampling)
            return GenerationResult(
                tokens=np.stack(res.outputs).astype(np.int32),
                prompt_len=max(res.prompt_lens), seconds=res.seconds,
                prompt_lens=list(res.prompt_lens))
        s = toks.shape[1]
        padded = _pow2_bucket(s) if self.bucket_prompts else s
        if padded != s:
            toks = jnp.pad(toks, ((0, 0), (0, padded - s)))
        max_len = padded + sampling.max_tokens

        from repro.runtime import shardctx

        ctx = (shardctx.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        t0 = time.time()
        greedy = sampling.temperature <= 0.0
        with ctx:
            logits, cache = self._prefill(self.params, toks, max_len,
                                          jnp.asarray(s - 1))
            key = None if greedy else jax.random.PRNGKey(sampling.seed)
            out = []
            k = None
            if not greedy:
                key, k = jax.random.split(key)
            tok = self._pick(logits, k, sampling)
            for i in range(sampling.max_tokens):
                out.append(tok)
                if i + 1 == sampling.max_tokens:
                    break
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.asarray(s + i))
                if not greedy:
                    key, k = jax.random.split(key)
                tok = self._pick(logits, k, sampling)
            gen = jax.block_until_ready(jnp.concatenate(out, axis=1))
        return GenerationResult(tokens=np.asarray(gen), prompt_len=s,
                                seconds=time.time() - t0)

    # ------------------------------------------------------------- serve --
    def serve(self, requests, sampling: SamplingParams | None = None, *,
              max_batch: int | None = None, block_size: int | None = None,
              num_blocks: int | None = None,
              chunk_tokens: int | None = None,
              speculate: bool | None = None,
              prefix_cache: bool | None = None) -> ServeResult:
        """In-flight batching with chunked prefill: ragged prompts,
        per-request max_tokens, one jitted token-budget step.

        requests: list of token sequences or `runtime.scheduler.Request`s
        (the latter carry their own max_tokens; otherwise
        `sampling.max_tokens` applies). Requests are admitted FCFS into a
        fixed-capacity batch; each step the scheduler splits
        `chunk_tokens` of budget between one decode token for every
        in-flight row (decode always advances) and prompt chunks for
        newly admitted rows, and a single forward pass processes the
        whole mix. Finished rows free their blocks immediately and the
        next waiting request takes the slot mid-flight. Overflow (rows or
        blocks) queues — it never crashes the batch.

        The loop is software-pipelined two steps deep: scheduling depends
        only on token *counts* (per-request max_tokens, no early
        stopping), so later steps are dispatched — decode rows fed the
        previous step's sampled tokens device-to-device — before earlier
        steps' values are read back. The host consumes a step's tokens
        while the device runs the next two, which both hides the
        per-step sync and timestamps each token at true completion
        (TTFT/TPOT in the result).

        num_blocks defaults to enough for max_batch worst-case sequences,
        i.e. admission is then only row-limited. Pass a smaller pool to
        exercise block-limited admission.

        When the engine carries a draft model (`build(speculate=...)` or
        `plan.draft`), decode rows additionally propose up to `spec.k`
        draft tokens per step with the truncated cascade and the full
        model verifies the whole span in the same dispatch — greedy
        acceptance keeps the outputs token-identical to non-speculative
        serve (see runtime/speculation.py). `speculate=False` disables
        it for this call; `speculate=True` requires the engine to have a
        draft model. This path is synchronous (acceptance is
        value-dependent), trading the 2-deep pipeline for >1 token per
        dispatch.

        serve() is greedy-only: speculative verification and the
        count-based pipelined bookkeeping both rely on deterministic
        argmax tokens, so SamplingParams.temperature > 0 raises instead
        of being silently ignored (rectangular `generate` batches do
        sample).

        prefix_cache (default: the engine's build-time setting) shares
        KV blocks between requests with equal full-block prompt
        prefixes: admission maps cached blocks by reference and prefill
        starts at the first uncached position. Greedy serve is
        token-identical with the cache on or off — K/V at position p
        depends only on tokens <= p, never on how prefill was chunked,
        so a cached block holds bit-for-bit what recomputation would
        write (int8 KV quantizes per (token, head), which block
        boundaries preserve). The cache lives for this serve call (the
        pool is per-call); hit/COW/eviction counts land in the result.
        """
        sampling = sampling or SamplingParams()
        if sampling.temperature > 0.0:
            raise NotImplementedError(
                f"serve() (in-flight batching) is greedy-only: speculative "
                f"verification and count-based pipelined scheduling rely on "
                f"deterministic argmax tokens, but "
                f"SamplingParams.temperature={sampling.temperature} requests "
                f"sampled decoding. Set SamplingParams.temperature=0 (the "
                f"default, greedy), or use generate() on a rectangular "
                f"batch, which does support temperature/top_k sampling.")
        ctl = self.speculation
        if speculate is False:
            ctl = None
        elif speculate is True and ctl is None:
            raise ValueError(
                "speculate=True but the engine has no draft model — build "
                "with speculate=DraftSpec(...) or a plan carrying .draft")
        reqs: list[Request] = []
        for i, r in enumerate(requests):
            if not isinstance(r, Request):
                r = Request(tokens=r)
            if r.max_tokens is None:
                r = dataclasses.replace(r, max_tokens=sampling.max_tokens)
            reqs.append(dataclasses.replace(r, rid=i))
        if not reqs:
            raise ValueError("empty request batch")
        kvblocks.check_paged_support(self.cfg)

        bs = block_size or self.block_size
        cap = min(max_batch or self.max_batch, len(reqs))
        budget = chunk_tokens or self.chunk_tokens
        need = [kvblocks.blocks_needed(r.tokens.size, r.max_tokens, bs)
                for r in reqs]
        mb = max(max(need), 1)              # block-table width (static)
        if num_blocks is None:
            num_blocks = cap * mb + 1       # +1: reserved trash block
        use_cache = self.prefix_cache if prefix_cache is None else prefix_cache
        pool_alloc = kvblocks.BlockPool(num_blocks, bs)
        sched = Scheduler(pool_alloc, cap, prefix_cache=use_cache,
                          fingerprint=self._cache_fingerprint)
        for r in reqs:
            sched.submit(r)

        pool = kvblocks.init_paged_cache(self.cfg, num_blocks, bs)
        if self._tp:
            from jax.sharding import NamedSharding

            pool = jax.device_put(
                pool, {k: NamedSharding(self.mesh, s)
                       for k, s in kvblocks.pool_pspecs(self.cfg).items()})
        tables = np.zeros((cap, mb), np.int32)
        out_vals: list[list[int]] = [[] for _ in reqs]
        first_tok_t = [None] * len(reqs)
        finish_t = [0.0] * len(reqs)
        steps = prefill_chunks = prefill_tokens = mixed_steps = 0
        drafted = accepted = spec_rounds = 0

        from repro.runtime import shardctx

        # TP serving must NOT install the GSPMD mesh: the step is a
        # shard_map program over manual axes, where maybe_shard's
        # with_sharding_constraint is meaningless (and errors).
        ctx = (shardctx.use_mesh(self.mesh)
               if self.mesh is not None and not self._tp
               else contextlib.nullcontext())
        t0 = time.time()

        def consume(emits, toks_dev):
            """Read back one step's sampled tokens (blocks until the
            device finishes that step) and credit them to requests."""
            vals = np.asarray(toks_dev)
            now = time.time()
            for rid, r in emits:
                out_vals[rid].append(int(vals[r, 0]))
                if first_tok_t[rid] is None:
                    first_tok_t[rid] = now
                if len(out_vals[rid]) == reqs[rid].max_tokens:
                    finish_t[rid] = now

        with ctx:
            if ctl is not None:
                (steps, prefill_chunks, prefill_tokens, mixed_steps,
                 drafted, accepted, spec_rounds) = self._spec_loop(
                    reqs, sched, pool, tables, cap, budget, ctl,
                    out_vals, first_tok_t, finish_t)
                sched_done = True
            else:
                sched_done = False
            tables_dev = None       # device-safe copy, refreshed on change
            inflight = collections.deque()   # (emits, device toks), oldest
            prev_toks = jnp.zeros((cap, 1), jnp.int32)
            while not sched_done and sched.has_work():
                plan = sched.schedule(budget)
                for r in plan.preempted:    # victim rows: table to trash
                    tables[r] = 0           # (before any admission that
                    tables_dev = None       # reuses the row below)
                for seq in plan.admitted:
                    tables[seq.row] = 0
                    tables[seq.row, :len(seq.block_ids)] = seq.block_ids
                    tables_dev = None
                    if seq.cow_dst is not None:
                        # fully-cached prompt: materialize a private copy
                        # of the last matched block before this step's
                        # span write recomputes its final position
                        pool = self._cow_copy(pool, jnp.int32(seq.cow_src),
                                              jnp.int32(seq.cow_dst))
                        sched.release_cow(seq)
                if not plan.prefill and not plan.decode:
                    raise RuntimeError(
                        "scheduler returned an empty step with work "
                        "pending — admission deadlock")
                # ---- build the (cap, W + meta) span batch ----------------
                # one fresh packed buffer per step: span tokens then
                # (ctx, q_len, use_prev) columns. Handed to the jitted
                # step as numpy — never mutated after dispatch, so jax's
                # zero-copy aliasing of host buffers is safe here.
                w = _pow2_bucket(plan.max_span)
                buf = np.zeros((cap, w + 3), np.int32)
                for r, width in plan.prefill.items():
                    seq = sched.rows[r]
                    lo = seq.prefilled
                    buf[r, :width] = seq.req.tokens[lo:lo + width]
                    buf[r, -3] = lo
                    buf[r, -2] = width
                for r in plan.decode:
                    seq = sched.rows[r]
                    # the input token is the one sampled last step; it is
                    # still on device (prev_toks), spliced in by the step.
                    # pool holds prompt + all but that newest token.
                    buf[r, -3] = seq.prompt_len + seq.n_emitted - 1
                    buf[r, -2] = 1
                    buf[r, -1] = 1
                # ---- ONE fused dispatch for the prefill/decode mix -------
                if tables_dev is None:
                    # a private copy: `tables` is mutated by later
                    # admissions/evictions while earlier dispatched steps
                    # may still be reading the (possibly aliased) upload
                    tables_dev = tables.copy()
                logits, toks_dev, pool = self._unified(
                    self.params, pool, tables_dev, buf, prev_toks)
                steps += 1
                prefill_chunks += len(plan.prefill)
                prefill_tokens += sum(plan.prefill.values())
                mixed_steps += plan.is_mixed
                prev_toks = toks_dev
                # ---- count-based bookkeeping at dispatch time ------------
                # (no early stopping, so who emits/finishes never depends
                # on token values — eviction and admission can run ahead
                # of the device)
                emits = []
                for r, width in plan.prefill.items():
                    # advance + register newly completed full prompt
                    # blocks into the content index (dispatch order =
                    # device order, so later readers see the writes)
                    sched.advance_prefill(sched.rows[r], width)
                for r in list(plan.prefill) + plan.decode:
                    seq = sched.rows[r]
                    if not seq.prefill_done:
                        continue            # mid-prompt: logits unused
                    seq.n_emitted += 1
                    emits.append((seq.req.rid, r))
                    if seq.done:
                        sched.finish(seq)
                        tables[r] = 0
                        tables_dev = None
                # ---- consume an older step while this one runs -----------
                # (two steps of lookahead keep the device queue busy
                # through the host's scheduling + readback work)
                inflight.append((emits, toks_dev))
                if len(inflight) > 2:
                    consume(*inflight.popleft())
            while inflight:
                consume(*inflight.popleft())
        if pool_alloc.available != pool_alloc.capacity:
            raise RuntimeError(
                f"leaked KV blocks: {pool_alloc.capacity - pool_alloc.available}"
                f" of {pool_alloc.capacity} still allocated after drain")
        outputs = [np.asarray(v, np.int32) for v in out_vals]
        ttft = [first_tok_t[i] - t0 for i in range(len(reqs))]
        tpot = [(finish_t[i] - first_tok_t[i]) / max(r.max_tokens - 1, 1)
                if r.max_tokens > 1 else 0.0
                for i, r in enumerate(reqs)]
        return ServeResult(
            outputs=outputs, prompt_lens=[r.tokens.size for r in reqs],
            seconds=time.time() - t0, steps=steps,
            prefill_chunks=prefill_chunks, prefill_tokens=prefill_tokens,
            mixed_steps=mixed_steps, chunk_tokens=budget,
            max_queue_depth=sched.max_queue_depth, max_batch=cap,
            block_size=bs, num_blocks=num_blocks, ttft=ttft, tpot=tpot,
            spec_k=(ctl.spec.k if ctl is not None else 0),
            drafted=drafted, accepted=accepted, spec_rounds=spec_rounds,
            prefix_cache=use_cache,
            cache_lookup_blocks=sched.cache_lookup_blocks,
            cache_hit_blocks=sched.cache_hit_blocks,
            cache_hit_tokens=sched.cache_hit_tokens,
            cache_cow_blocks=sched.cache_cow_blocks,
            cache_evictions=pool_alloc.evictions,
            preemptions=sched.preemptions)

    def _spec_loop(self, reqs, sched, pool, tables, cap, budget, ctl,
                   out_vals, first_tok_t, finish_t):
        """The speculative serve loop: one fused draft->verify->accept
        dispatch per step (runtime.speculation.speculative_step).

        Synchronous by design — how many tokens a row advanced is
        value-dependent (the accept count), so the next step's schedule
        must wait for this step's readback. The throughput win comes
        from E[accepted + 1] tokens per dispatch, not from pipelining;
        in the dispatch-bound small-step regime that IS the serving
        bottleneck. Only two step variants ever trace: draft width
        spec.k (any drafting row this step) and 0 (none — e.g. a
        prefill-only step), mirroring the non-speculative path's
        power-of-two span bucketing.

        Mutates out_vals / first_tok_t / finish_t in place (same
        contract as serve's consume()); returns the step counters."""
        steps = prefill_chunks = prefill_tokens = mixed_steps = 0
        drafted = accepted = spec_rounds = 0
        tables_dev = None
        prev_toks = jnp.zeros((cap, 1), jnp.int32)
        while sched.has_work():
            plan = sched.schedule(budget, spec_k=ctl.spec.k)
            for r in plan.preempted:
                tables[r] = 0
                tables_dev = None
            for seq in plan.admitted:
                tables[seq.row] = 0
                tables[seq.row, :len(seq.block_ids)] = seq.block_ids
                tables_dev = None
                if seq.cow_dst is not None:
                    pool = self._cow_copy(pool, jnp.int32(seq.cow_src),
                                          jnp.int32(seq.cow_dst))
                    sched.release_cow(seq)
            # draft-block reservations can grow a row's table mid-flight
            # (only when admission could not pre-reserve the worst case)
            for r in plan.spec:
                seq = sched.rows[r]
                if seq.draft_blocks:
                    tables[r, :len(seq.block_ids)] = seq.block_ids
                    tables_dev = None
            if not plan.prefill and not plan.decode:
                raise RuntimeError(
                    "scheduler returned an empty step with work "
                    "pending — admission deadlock")
            # ---- (cap, W + meta) span batch; meta gains spec_lens -------
            k_step = ctl.spec.k if plan.spec else 0
            w = _pow2_bucket(max(plan.max_span, k_step + 1))
            buf = np.zeros((cap, w + 4), np.int32)
            for r, width in plan.prefill.items():
                seq = sched.rows[r]
                lo = seq.prefilled
                buf[r, :width] = seq.req.tokens[lo:lo + width]
                buf[r, -4] = lo
                buf[r, -3] = width
            for r in plan.decode:
                seq = sched.rows[r]
                kr = plan.spec.get(r, 0)
                # span: [prev (device-spliced), kr draft slots]
                buf[r, -4] = seq.prompt_len + seq.n_emitted - 1
                buf[r, -3] = 1 + kr
                buf[r, -2] = 1
                buf[r, -1] = kr
            if tables_dev is None:
                tables_dev = tables.copy()
            full_toks, n_acc, prev_toks, pool = ctl.step_fn(k_step)(
                self.params, ctl.draft_params, pool, tables_dev, buf,
                prev_toks)
            steps += 1
            spec_rounds += bool(plan.spec)
            prefill_chunks += len(plan.prefill)
            prefill_tokens += sum(plan.prefill.values())
            mixed_steps += plan.is_mixed
            # acceptance decides how far each row advanced: read back now
            fv = np.asarray(full_toks)
            na = np.asarray(n_acc)
            now = time.time()
            for r, width in plan.prefill.items():
                sched.advance_prefill(sched.rows[r], width)
            for r in list(plan.prefill) + plan.decode:
                seq = sched.rows[r]
                if not seq.prefill_done:
                    continue        # mid-prompt: logits unused
                if r in plan.prefill:
                    # prompt finished this step: emit the last-valid-
                    # position token (appended verify column k_step + 1)
                    toks = fv[r, k_step + 1:k_step + 2]
                else:
                    # decode: accepted draft prefix + the full model's
                    # own token at the first divergence (or the bonus)
                    toks = fv[r, :int(na[r]) + 1]
                rid = seq.req.rid
                out_vals[rid].extend(int(t) for t in toks)
                if first_tok_t[rid] is None:
                    first_tok_t[rid] = now
                seq.n_emitted += len(toks)
                kr = plan.spec.get(r, 0)
                if kr:
                    drafted += kr
                    accepted += len(toks) - 1
                    if sched.commit_speculation(seq):
                        # rollback released tail blocks: rewind the table
                        tables[r] = 0
                        tables[r, :len(seq.block_ids)] = seq.block_ids
                        tables_dev = None
                if seq.done:
                    finish_t[rid] = now
                    sched.finish(seq)
                    tables[r] = 0
                    tables_dev = None
        return (steps, prefill_chunks, prefill_tokens, mixed_steps,
                drafted, accepted, spec_rounds)

    def _pick(self, logits, key, sampling: SamplingParams) -> jnp.ndarray:
        """(B, 1) next tokens from (B, ..., V) last-position logits."""
        if sampling.temperature <= 0.0:
            return self._argmax(logits)
        last = logits[:, -1]
        scaled = last / sampling.temperature
        if sampling.top_k > 0 and sampling.top_k < scaled.shape[-1]:
            kth = jax.lax.top_k(scaled, sampling.top_k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return jax.random.categorical(key, scaled)[:, None].astype(jnp.int32)
