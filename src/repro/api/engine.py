"""`InferenceEngine` — the online half of the plan→engine seam.

Build compiles a serving engine from (architecture, CompressionPlan):
compress the weights per the plan, optionally place them on a device mesh,
and jit the prefill / step callables once. Generation then runs any
number of batched requests against the same compiled engine:

    plan = CompressionPlan.load("plan.json")          # e.g. a DSE winner
    eng = InferenceEngine.build("opus-mt", plan, smoke=True)
    out = eng.generate(prompts, SamplingParams(max_tokens=32, top_k=40))

Two serving paths share the compiled model:

  * `generate` on a rectangular (B, S) batch — prefill once (prompts are
    right-padded to power-of-two length buckets, so N distinct lengths
    cost O(log N) compilations), decode in lockstep; the static-batching
    baseline.
  * `serve` (which `generate` uses for ragged prompt lists) — in-flight
    batching with chunked prefill: every forward pass is ONE jitted
    token-budget step (`models.transformer.unified_step`) that mixes
    prefill chunks of newly admitted prompts with in-flight decode rows
    over a `runtime.kvblocks` blocked KV pool, scheduled by
    `runtime.scheduler.Scheduler`. There is no solo-prefill path: a
    prompt enters the pool chunk by chunk while older rows keep
    decoding, and rows leave the moment they finish, returning their
    blocks to the pool.

`launch.serve` is a thin CLI over this class; every future serving feature
(KV paging variants, multi-host decode) lands behind this facade rather
than in loose scripts.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import CompressionPlan
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.compress import CompressionConfig, compress_params
from repro.models import transformer as tfm
from repro.runtime import kvblocks
from repro.runtime.scheduler import Request, Scheduler
from repro.runtime.speculation import DraftSpec, SpeculationController


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-call sampling / stop controls (a `runtime.scheduler.Request`
    can override any of them per request). temperature <= 0 means
    greedy; top_k == 0 and top_p == 1.0 apply no truncation tighter
    than the sampler's static top-`sampling.TOPK_CAP` candidate window. `stop` is a tuple of token-id sequences matched inclusively
    — generation stops after emitting the token that completes a match,
    and the matched tokens stay in the output (see runtime/sampling.py);
    eos_id is a single-token stop. Seeded sampled runs are reproducible
    token-for-token across repeats, prefix-cache on/off, TP mesh sizes,
    and the generate()/serve() split (per-row counter-based PRNG keys)."""

    max_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    eos_id: int | None = None
    stop: tuple = ()

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.eos_id is not None and self.eos_id < 0:
            raise ValueError(f"eos_id must be >= 0, got {self.eos_id}")
        object.__setattr__(self, "stop", tuple(
            tuple(int(t) for t in s) for s in self.stop))
        if any(len(s) == 0 for s in self.stop):
            raise ValueError("empty stop sequence")

    def to_dict(self) -> dict:
        d = {"max_tokens": self.max_tokens, "temperature": self.temperature,
             "top_k": self.top_k, "top_p": self.top_p, "seed": self.seed}
        if self.eos_id is not None:
            d["eos_id"] = int(self.eos_id)
        if self.stop:
            d["stop"] = [list(s) for s in self.stop]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SamplingParams":
        return cls(max_tokens=int(d.get("max_tokens", 32)),
                   temperature=float(d.get("temperature", 0.0)),
                   top_k=int(d.get("top_k", 0)),
                   top_p=float(d.get("top_p", 1.0)),
                   seed=int(d.get("seed", 0)),
                   eos_id=(None if d.get("eos_id") is None
                           else int(d["eos_id"])),
                   stop=tuple(tuple(s) for s in d.get("stop", ())))


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed token, delivered by serve(on_token=...) the moment
    the pipelined readback confirms it (true completion time, the same
    timestamp TTFT/TPOT use). `index` is the token's position in the
    request's output; `final` marks the request's last token (its stop
    criterion fired or max_tokens was reached)."""

    rid: int
    token: int
    index: int
    time: float
    final: bool


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_tokens) int32
    prompt_len: int             # ragged batches: the longest prompt
    seconds: float
    prompt_lens: list[int] | None = None   # set for ragged batches

    @property
    def tokens_per_second(self) -> float:
        b, g = self.tokens.shape
        return b * g / max(self.seconds, 1e-9)


def _percentile(xs, q) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


@dataclasses.dataclass
class ServeResult:
    """In-flight batching outcome: per-request continuations in
    submission order, plus step/chunk/latency accounting."""

    outputs: list[np.ndarray]   # outputs[i]: (requests[i].max_tokens,) int32
    prompt_lens: list[int]
    seconds: float
    steps: int                  # unified token-budget steps executed
    prefill_chunks: int         # prompt chunks processed across all steps
    prefill_tokens: int         # prompt tokens entered via those chunks
    mixed_steps: int            # steps running prefill AND decode together
    chunk_tokens: int           # the per-step token budget
    max_queue_depth: int        # peak waiting-queue length (overflow proof)
    max_batch: int
    block_size: int
    num_blocks: int
    ttft: list[float] = dataclasses.field(default_factory=list)
    tpot: list[float] = dataclasses.field(default_factory=list)
    # self-speculative decoding accounting (0 when speculation is off):
    # over the whole serve, `drafted` draft tokens were proposed and
    # `accepted` of them survived full-model verification across
    # `spec_rounds` drafting rounds of width spec_k.
    spec_k: int = 0
    drafted: int = 0
    accepted: int = 0
    spec_rounds: int = 0
    # prefix-cache accounting (all zero when prefix_cache is False):
    # admission looked up `cache_lookup_blocks` full prompt blocks in the
    # pool's content index, mapped `cache_hit_blocks` of them by
    # reference (skipping `cache_hit_tokens` prompt tokens of prefill),
    # copy-on-wrote `cache_cow_blocks` final blocks of fully-cached
    # prompts, and the pool evicted `cache_evictions` idle cached blocks
    # under pressure. `preemptions` counts pool-pressure victim requeues.
    prefix_cache: bool = False
    cache_lookup_blocks: int = 0
    cache_hit_blocks: int = 0
    cache_hit_tokens: int = 0
    cache_cow_blocks: int = 0
    cache_evictions: int = 0
    preemptions: int = 0
    # SLO accounting: queue_times[i] is request i's admission wait
    # (serve() start -> scheduler admission; re-admission after a
    # preemption overwrites it), finish_times[i] its completion time
    # relative to serve() start. `stopped_early` counts requests a
    # device stop criterion (eos / stop sequence) finished before
    # max_tokens.
    queue_times: list[float] = dataclasses.field(default_factory=list)
    finish_times: list[float] = dataclasses.field(default_factory=list)
    stopped_early: int = 0

    @property
    def accept_rate(self) -> float:
        """Fraction of proposed draft tokens the full model kept."""
        return self.accepted / self.drafted if self.drafted else 0.0

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of looked-up full prompt blocks served by reference."""
        return (self.cache_hit_blocks / self.cache_lookup_blocks
                if self.cache_lookup_blocks else 0.0)

    @property
    def cache_hit_token_rate(self) -> float:
        """Fraction of all prompt tokens whose prefill was skipped."""
        total = sum(self.prompt_lens)
        return self.cache_hit_tokens / total if total else 0.0

    @property
    def cache_blocks_saved(self) -> int:
        """Physical blocks admission did not allocate thanks to sharing
        (hit blocks mapped by reference; COW sources still cost a private
        copy, so they don't count)."""
        return self.cache_hit_blocks - self.cache_cow_blocks

    @property
    def total_tokens(self) -> int:
        return int(sum(o.size for o in self.outputs))

    @property
    def tokens_per_second(self) -> float:
        return self.total_tokens / max(self.seconds, 1e-9)

    # per-request latency aggregates (seconds). ttft[i] is measured from
    # serve() start to request i's first sampled token; tpot[i] is the
    # mean inter-token time over its remaining outputs (0.0 for
    # single-token requests).
    @property
    def ttft_p50(self) -> float:
        return _percentile(self.ttft, 50)

    @property
    def ttft_p95(self) -> float:
        return _percentile(self.ttft, 95)

    @property
    def tpot_p50(self) -> float:
        return _percentile([t for t in self.tpot if t > 0], 50)

    @property
    def tpot_p95(self) -> float:
        return _percentile([t for t in self.tpot if t > 0], 95)

    @property
    def queue_p50(self) -> float:
        return _percentile(self.queue_times, 50)

    @property
    def queue_p95(self) -> float:
        return _percentile(self.queue_times, 95)

    def goodput(self, deadline_s: float) -> float:
        """Tokens per second counting ONLY requests that finished within
        `deadline_s` of serve() start — the SLO-aware throughput number
        (a request that blows its deadline contributes nothing, however
        many tokens it produced)."""
        good = sum(self.outputs[i].size for i, f in enumerate(
            self.finish_times) if f <= deadline_s)
        return good / max(self.seconds, 1e-9)

    def slo_attainment(self, ttft_s: float, tpot_s: float) -> float:
        """Fraction of requests meeting BOTH a TTFT and a per-output-
        token latency target."""
        n = len(self.outputs)
        if not n:
            return 0.0
        ok = sum(1 for i in range(n)
                 if self.ttft[i] <= ttft_s and self.tpot[i] <= tpot_s)
        return ok / n


def _as_token_batch(requests):
    """Normalize requests: a (B, S) int32 array when rectangular, else a
    list of 1-D int32 prompts (the caller routes those through the
    continuous-batching scheduler)."""
    if isinstance(requests, (list, tuple)):
        if not requests:
            raise ValueError("empty request batch")
        rows = [np.asarray(r, np.int32) for r in requests]
        if any(r.ndim != 1 for r in rows):
            raise ValueError(
                f"each request must be a 1-D token sequence, got shapes "
                f"{[r.shape for r in rows]}")
        if any(r.size == 0 for r in rows):
            raise ValueError("empty prompt in request batch")
        if len({r.size for r in rows}) != 1:
            return rows
        requests = np.stack(rows)
    toks = jnp.asarray(requests, jnp.int32)
    if toks.ndim != 2:
        raise ValueError(f"requests must be (batch, seq), got {toks.shape}")
    return toks


def _pow2_bucket(n: int) -> int:
    """Smallest power of two >= n."""
    return 1 << max(n - 1, 0).bit_length()


def _tree_nbytes(tree) -> int:
    """Bytes a pytree's arrays actually occupy (packed nibble arrays
    report their true halved size) — the single definition of measured
    weight residency."""
    return sum(int(getattr(l, "nbytes", 0))
               for l in jax.tree_util.tree_leaves(tree))


def _generate_pick(logits, temperature, top_k, top_p, seed, counter):
    """Sampled next tokens for the rectangular generate() path: (B, 1)
    int32 from (B, ..., V) last-position logits. Scalar sampling
    controls are broadcast per row; keys are counter-based —
    fold_in(fold_in(PRNGKey(seed), row), counter) with row == batch
    index == the rid serve() would assign the same prompts — so the
    rectangular and continuous-batching paths sample identical tokens
    under a shared seed (counter is a traced scalar: one trace serves
    every step)."""
    from repro.runtime import sampling as smp

    last = logits[:, -1]
    b = last.shape[0]
    bcast = lambda x, dt: jnp.full((b,), x, dt)    # noqa: E731
    keys = smp.row_keys(bcast(seed, jnp.int32),
                        jnp.arange(b, dtype=jnp.int32),
                        bcast(counter, jnp.int32))
    return smp.sample_tokens(last, bcast(temperature, jnp.float32),
                             bcast(top_k, jnp.int32),
                             bcast(top_p, jnp.float32), keys)[:, None]


class InferenceEngine:
    """Compiled compress→shard→serve pipeline for one model + plan."""

    def __init__(self, cfg: ModelConfig, params, *, plan=None, report=None,
                 mesh=None, max_batch: int = 8, block_size: int = 16,
                 chunk_tokens: int = 256, bucket_prompts: bool = True,
                 speculate: DraftSpec | None = None,
                 prefix_cache: bool = True):
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.report = report
        self.mesh = mesh
        # prefix caching (serve): share full KV blocks between requests
        # with equal position-aligned prompt prefixes. The content-hash
        # chain is seeded with a model+plan fingerprint so blocks can
        # never be shared across engines whose K/V for the same tokens
        # would differ (different weights, dtype, or KV residency).
        self.prefix_cache = prefix_cache
        try:
            plan_id = plan.dumps() if plan is not None else "dense"
        except TypeError:           # unserializable plan metadata
            plan_id = repr(plan)
        self._cache_fingerprint = hashlib.sha256(
            (f"{getattr(cfg, 'name', 'model')}:{cfg.dtype}:"
             f"{getattr(cfg, 'kv_cache_bits', 16)}:{plan_id}")
            .encode()).digest()
        # tensor-parallel serving: a mesh with a "model" axis shard-maps
        # the unified step — params column/row-sliced, the KV pool
        # head-sliced, one psum per attention/MLP boundary. The mesh
        # model-axis size IS the TP degree (1 runs the same path).
        self._tp = (int(mesh.shape["model"])
                    if mesh is not None and "model" in mesh.axis_names
                    else 0)
        # self-speculative decoding: derive the truncated-cascade draft
        # tree once at engine construction (it shares every dense array
        # with `params` by reference — no second checkpoint in HBM)
        self.speculation = (SpeculationController(speculate, cfg, params,
                                                  mesh=mesh)
                            if speculate is not None else None)
        self.max_batch = max_batch      # serve(): batch-row capacity
        self.block_size = block_size    # serve(): KV block size (tokens)
        self.chunk_tokens = chunk_tokens  # serve(): per-step token budget
        # generate(): right-pad prompts to power-of-two length buckets so
        # N distinct lengths cost O(log N) prefill compilations. Only
        # sound where right-padding is inert: dense global causal
        # attention (padding K/V slots are overwritten before any decode
        # query can see them). Rolling/windowed caches and SSM state
        # fold padding into what decode reads, and MoE expert routing is
        # capacity-bounded per batch — pad tokens compete for expert
        # slots and can displace real tokens — so those archs prefill at
        # exact length.
        self.bucket_prompts = bucket_prompts and self._can_bucket(cfg)
        # jit once; XLA re-specializes per (batch, seq, max_len) shape.
        self._prefill = jax.jit(
            lambda p, toks, max_len, last: tfm.prefill(p, toks, cfg,
                                                       max_len=max_len,
                                                       last_pos=last),
            static_argnums=2)
        self._decode = jax.jit(
            lambda p, cache, tok, pos: tfm.decode_step(p, cache, tok, pos,
                                                       cfg))
        # the unified serving step (models.transformer.serve_step):
        # static in (capacity, span width, max blocks/seq); the span
        # width is power-of-two bucketed, so one jitted function in
        # O(log chunk_tokens) shapes serves the whole
        # admit/chunk/decode/evict loop. Everything per-step is fused
        # into this single dispatch — splicing the previous step's
        # device-resident sampled tokens into decode rows, the forward
        # pass, per-row temperature/top-k/top-p sampling, and the
        # eos/stop/max-tokens finished mask — because serving
        # throughput on small steps is bounded by host dispatch
        # overhead, not FLOPs. One variant traces per static
        # (any-row-samples, any-stop-criteria) pair; the (False, False)
        # variant is the bare greedy step (no sort, no PRNG, no ring).
        self._unified_cache: dict[tuple[bool, bool], object] = {}
        if self._tp:
            from repro.launch import sharding as shd

            shd.check_tp_geometry(cfg, self._tp)
        # greedy sampling is the rectangular-generate hot path: one fused
        # jitted argmax instead of a chain of eager ops per step; the
        # sampled path is the SAME fused sampler the serve step uses,
        # keyed by (seed, row, emission counter) so generate() and
        # serve() agree token-for-token under a shared seed.
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg[:, -1], axis=-1)[:, None]
            .astype(jnp.int32))
        self._sample = jax.jit(_generate_pick)
        # copy-on-write block duplication for fully-cached prompts; block
        # indices are traced scalars so one trace covers every copy, and
        # the op moves along the (unsharded) block axis so it is TP-inert.
        self._cow_copy = jax.jit(kvblocks.copy_block)

    def _unified_fn(self, sample: bool, stop: bool):
        """The jitted fused serving step for one static (sample, stop)
        pair, traced on first use and cached for the engine's lifetime."""
        fn = self._unified_cache.get((sample, stop))
        if fn is not None:
            return fn
        cfg = self.cfg
        if self._tp:
            # shard_map the SAME fused step: each shard runs it with the
            # per-shard config (its slice of heads / hidden columns) over
            # its head-slice of the pool; tokens / tables / buffers are
            # replicated. tp_axis binds at trace time, so the boundary
            # psums in transformer.unified_step land in this jaxpr only.
            # Sampling runs identically on every shard: the residual —
            # hence the logits and the per-row keys — is replicated
            # after the boundary psums, so toks/finished come out
            # replicated too (out_specs P()), exactly like the greedy
            # argmax before.
            from jax.sharding import PartitionSpec as P

            from repro.launch import sharding as shd
            from repro.runtime import shardctx

            lcfg = shd.tp_local_config(cfg, self._tp)
            pspecs = shd.tp_param_specs(self.params, self._tp)
            pool_specs = kvblocks.pool_pspecs(cfg)

            def tp_body(p, pool, bt, buf, prev, recent, stops):
                with shardctx.tp_axis("model"):
                    return tfm.serve_step(p, pool, bt, buf, prev, recent,
                                          stops, lcfg, sample=sample,
                                          stop=stop)

            fn = jax.jit(shardctx.tp_shard_map(
                tp_body, self.mesh,
                in_specs=(pspecs, pool_specs, P(), P(), P(), P(), P()),
                out_specs=(P(), P(), P(), pool_specs)))
        else:
            fn = jax.jit(
                lambda p, pool, bt, buf, prev, recent, stops:
                tfm.serve_step(p, pool, bt, buf, prev, recent, stops, cfg,
                               sample=sample, stop=stop))
        self._unified_cache[(sample, stop)] = fn
        return fn

    @staticmethod
    def _can_bucket(cfg) -> bool:
        return (cfg.layout == "dense"
                and not cfg.attn_window and not cfg.local_global_period)

    def weight_hbm_bytes(self) -> int:
        """Bytes the parameter arrays actually occupy in device memory —
        the number the packed-W4 residency work shrinks. Measured
        residency (`.nbytes` per leaf), not an accounting claim."""
        return _tree_nbytes(self.params)

    # ------------------------------------------------------------- build --
    @classmethod
    def build(cls, arch, plan=None, *, mesh=None, params=None,
              smoke: bool = False, seed: int = 0, verbose: bool = False,
              max_batch: int = 8, block_size: int = 16,
              chunk_tokens: int = 256,
              paged_attn: str | None = None,
              speculate=None, prefix_cache: bool = True
              ) -> "InferenceEngine":
        """arch: config name (see repro.configs) or a ModelConfig.
        plan: CompressionPlan | legacy CompressionConfig | None (dense).
        params: pre-trained weights; freshly initialized when omitted.
        mesh: optional jax Mesh — weights are placed per launch.sharding.
        max_batch / block_size / chunk_tokens: serving defaults for
        serve() — batch rows, KV block size, per-step token budget.
        paged_attn: override cfg.paged_attn_impl for the serving
        attention backend — "auto" (Pallas kernel on TPU, jnp gather
        oracle on CPU), "kernel", or "ref".
        speculate: self-speculative decoding config. None defers to
        `plan.draft`; a `DraftSpec` (or int draft depth k, or True for
        the defaults) turns it on regardless of the plan; False/0 forces
        it off even when the plan carries a draft spec.
        prefix_cache: serve() default for KV prefix sharing (overridable
        per serve call)."""
        cfg = get_config(arch, smoke=smoke) if isinstance(arch, str) else arch
        if paged_attn is not None:
            cfg = dataclasses.replace(cfg, paged_attn_impl=paged_attn)
        if params is None:
            params = tfm.init_params(jax.random.PRNGKey(seed), cfg)

        report = None
        if isinstance(plan, CompressionConfig):
            plan = (None if plan.method == "none"
                    else CompressionPlan.from_config(params, plan))
        if plan is not None:
            t0 = time.time()
            params, report = compress_params(params, plan)
            plan = report.plan
            if verbose:
                print(f"[engine] compressed in {time.time()-t0:.1f}s: "
                      f"{report.summary()} "
                      f"resident={_tree_nbytes(params)/2**20:.1f}MiB")

        if mesh is not None:
            from repro.launch import sharding as shd

            if "model" in mesh.axis_names:
                # tensor-parallel serving placement: literal shard_map
                # slices (launch.sharding._TP_RULES), so every leaf is
                # already where its shard needs it and no per-dispatch
                # resharding happens. Geometry must divide exactly.
                shd.check_tp_geometry(cfg, int(mesh.shape["model"]))
                params = jax.device_put(params,
                                        shd.tp_param_shardings(params, mesh))
            else:
                params = jax.device_put(
                    params, shd.param_shardings(params, mesh, cfg))
        if isinstance(speculate, DraftSpec):
            spec = speculate
        elif speculate is None:
            spec = plan.draft if plan is not None else None
        elif speculate is True:
            spec = (plan.draft if plan is not None and plan.draft is not None
                    else DraftSpec())
        elif not speculate:             # False / 0: explicit off
            spec = None
        else:
            spec = DraftSpec(k=int(speculate))
        return cls(cfg, params, plan=plan, report=report, mesh=mesh,
                   max_batch=max_batch, block_size=block_size,
                   chunk_tokens=chunk_tokens, speculate=spec,
                   prefix_cache=prefix_cache)

    # ---------------------------------------------------------- generate --
    def generate(self, requests, sampling: SamplingParams | None = None
                 ) -> GenerationResult:
        """Generate continuations for a batch of requests.

        requests: (B, S) int tokens — array or list of token lists. Equal
        lengths run the rectangular lockstep path; ragged lengths are
        served by the in-flight batching scheduler (`serve`) through the
        unified token-budget step. Either way the result is the generated
        continuation only, (B, max_tokens), in request order — greedy
        outputs are token-identical between the two paths and to running
        each prompt alone, seeded sampled outputs likewise (both paths
        share the fused sampler and counter-based keys,
        runtime/sampling.py). Stop criteria (sampling.eos_id / .stop)
        truncate inclusively; rows that stop early are zero-padded to
        max_tokens to keep the result rectangular.
        """
        sampling = sampling or SamplingParams()
        toks = _as_token_batch(requests)
        if isinstance(toks, list):          # ragged -> continuous batching
            res = self.serve(toks, sampling)
            out = np.zeros((len(res.outputs), sampling.max_tokens), np.int32)
            for i, o in enumerate(res.outputs):
                out[i, :o.size] = o         # stop-shortened rows: zero tail
            return GenerationResult(
                tokens=out,
                prompt_len=max(res.prompt_lens), seconds=res.seconds,
                prompt_lens=list(res.prompt_lens))
        s = toks.shape[1]
        padded = _pow2_bucket(s) if self.bucket_prompts else s
        if padded != s:
            toks = jnp.pad(toks, ((0, 0), (0, padded - s)))
        max_len = padded + sampling.max_tokens

        from repro.runtime import shardctx

        ctx = (shardctx.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        t0 = time.time()
        with ctx:
            logits, cache = self._prefill(self.params, toks, max_len,
                                          jnp.asarray(s - 1))
            out = []
            tok = self._pick(logits, sampling, 0)
            for i in range(sampling.max_tokens):
                out.append(tok)
                if i + 1 == sampling.max_tokens:
                    break
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.asarray(s + i))
                tok = self._pick(logits, sampling, i + 1)
            gen = jax.block_until_ready(jnp.concatenate(out, axis=1))
        arr = np.asarray(gen)
        if sampling.eos_id is not None or sampling.stop:
            # lockstep decode runs every row to max_tokens; apply the
            # shared stop oracle post-hoc (inclusive match, zero tail)
            # so the rectangular and ragged paths return the same thing
            from repro.runtime import sampling as smp
            arr = arr.copy()
            for i in range(arr.shape[0]):
                keep = smp.match_stop_host(arr[i], sampling.eos_id,
                                           sampling.stop,
                                           sampling.max_tokens)
                if keep is not None:
                    arr[i, keep:] = 0
        return GenerationResult(tokens=arr, prompt_len=s,
                                seconds=time.time() - t0)

    # ------------------------------------------------------------- serve --
    def serve(self, requests, sampling: SamplingParams | None = None, *,
              max_batch: int | None = None, block_size: int | None = None,
              num_blocks: int | None = None,
              chunk_tokens: int | None = None,
              speculate: bool | None = None,
              prefix_cache: bool | None = None,
              on_token=None) -> ServeResult:
        """In-flight batching with chunked prefill: ragged prompts,
        per-request max_tokens, one jitted token-budget step.

        requests: list of token sequences or `runtime.scheduler.Request`s
        (the latter carry their own max_tokens; otherwise
        `sampling.max_tokens` applies). Requests are admitted FCFS into a
        fixed-capacity batch; each step the scheduler splits
        `chunk_tokens` of budget between one decode token for every
        in-flight row (decode always advances) and prompt chunks for
        newly admitted rows, and a single forward pass processes the
        whole mix. Finished rows free their blocks immediately and the
        next waiting request takes the slot mid-flight. Overflow (rows or
        blocks) queues — it never crashes the batch.

        The loop is software-pipelined two steps deep: scheduling depends
        only on token *counts* (per-request max_tokens, no early
        stopping), so later steps are dispatched — decode rows fed the
        previous step's sampled tokens device-to-device — before earlier
        steps' values are read back. The host consumes a step's tokens
        while the device runs the next two, which both hides the
        per-step sync and timestamps each token at true completion
        (TTFT/TPOT in the result).

        num_blocks defaults to enough for max_batch worst-case sequences,
        i.e. admission is then only row-limited. Pass a smaller pool to
        exercise block-limited admission.

        When the engine carries a draft model (`build(speculate=...)` or
        `plan.draft`), decode rows additionally propose up to `spec.k`
        draft tokens per step with the truncated cascade and the full
        model verifies the whole span in the same dispatch — greedy
        acceptance keeps the outputs token-identical to non-speculative
        serve (see runtime/speculation.py). `speculate=False` disables
        it for this call; `speculate=True` requires the engine to have a
        draft model. This path is synchronous (acceptance is
        value-dependent), trading the 2-deep pipeline for >1 token per
        dispatch. Rows decoding with temperature > 0 never draft —
        greedy acceptance verifies an argmax chain — but they sample in
        the same fused dispatch, so mixed greedy+sampled batches keep
        speculating on their greedy rows.

        Sampling is per request and fused into the dispatch: each
        request's temperature / top_k / top_p / seed (its Request
        fields, else `sampling`) travel as packed metadata columns in
        the one per-step buffer upload, and tokens are sampled on
        device with counter-based PRNG keys (runtime/sampling.py) — so
        seeded sampled runs replay token-identically across repeats,
        prefix-cache on/off, and TP mesh sizes; rows with temperature
        <= 0 stay bit-identical to greedy serve; and an all-greedy call
        still traces the bare argmax program (no sort, no PRNG).

        Stop criteria (eos_id / stop token sequences, per request or
        call-wide) are evaluated on device in the same dispatch; the
        per-row finished mask rides the already-pipelined readback, so
        the loop learns of a stop at most two steps late (those zombie
        steps' tokens are discarded), then frees the row's blocks.
        Matching is inclusive: the matched tokens stay in the (possibly
        shorter than max_tokens) output.

        on_token, if given, is called as `on_token(TokenEvent(...))`
        the moment the pipelined readback confirms each token — the
        async streaming front door (`launch.serve.serve_stream`)
        bridges it onto an event loop. Callbacks run between dispatches
        on the serve thread, so keep them cheap.

        prefix_cache (default: the engine's build-time setting) shares
        KV blocks between requests with equal full-block prompt
        prefixes: admission maps cached blocks by reference and prefill
        starts at the first uncached position. Greedy serve is
        token-identical with the cache on or off — K/V at position p
        depends only on tokens <= p, never on how prefill was chunked,
        so a cached block holds bit-for-bit what recomputation would
        write (int8 KV quantizes per (token, head), which block
        boundaries preserve). The cache lives for this serve call (the
        pool is per-call); hit/COW/eviction counts land in the result.
        """
        sampling = sampling or SamplingParams()
        ctl = self.speculation
        if speculate is False:
            ctl = None
        elif speculate is True and ctl is None:
            raise ValueError(
                "speculate=True but the engine has no draft model — build "
                "with speculate=DraftSpec(...) or a plan carrying .draft")
        # resolve every per-request sampling/stop field against the
        # call-level SamplingParams BEFORE submission: the scheduler and
        # the packed-buffer build only ever see concrete values.
        reqs: list[Request] = []
        for i, r in enumerate(requests):
            if not isinstance(r, Request):
                r = Request(tokens=r)
            repl: dict = {"rid": i}
            if r.max_tokens is None:
                repl["max_tokens"] = sampling.max_tokens
            if r.temperature is None:
                repl["temperature"] = sampling.temperature
            if r.top_k is None:
                repl["top_k"] = sampling.top_k
            if r.top_p is None:
                repl["top_p"] = sampling.top_p
            if r.seed is None:
                repl["seed"] = sampling.seed
            if r.eos_id is None:
                repl["eos_id"] = sampling.eos_id
            if not r.stop:
                repl["stop"] = sampling.stop
            reqs.append(dataclasses.replace(r, **repl))
        if not reqs:
            raise ValueError("empty request batch")
        kvblocks.check_paged_support(self.cfg)
        # serve-call statics: which fused-step variant traces, and the
        # stop-buffer geometry (ring width S, stop slots NS)
        do_sample = any(r.temperature > 0.0 for r in reqs)
        do_stop = any(r.eos_id is not None or r.stop for r in reqs)
        n_stops = max([len(r.stop) for r in reqs] + [1])
        stop_len = max([len(s) for r in reqs for s in r.stop] + [1])

        bs = block_size or self.block_size
        cap = min(max_batch or self.max_batch, len(reqs))
        budget = chunk_tokens or self.chunk_tokens
        need = [kvblocks.blocks_needed(r.tokens.size, r.max_tokens, bs)
                for r in reqs]
        mb = max(max(need), 1)              # block-table width (static)
        if num_blocks is None:
            num_blocks = cap * mb + 1       # +1: reserved trash block
        use_cache = self.prefix_cache if prefix_cache is None else prefix_cache
        pool_alloc = kvblocks.BlockPool(num_blocks, bs)
        sched = Scheduler(pool_alloc, cap, prefix_cache=use_cache,
                          fingerprint=self._cache_fingerprint)
        for r in reqs:
            sched.submit(r)

        pool = kvblocks.init_paged_cache(self.cfg, num_blocks, bs)
        if self._tp:
            from jax.sharding import NamedSharding

            pool = jax.device_put(
                pool, {k: NamedSharding(self.mesh, s)
                       for k, s in kvblocks.pool_pspecs(self.cfg).items()})
        tables = np.zeros((cap, mb), np.int32)
        out_vals: list[list[int]] = [[] for _ in reqs]
        first_tok_t = [None] * len(reqs)
        finish_t = [0.0] * len(reqs)
        queue_t = [0.0] * len(reqs)
        steps = prefill_chunks = prefill_tokens = mixed_steps = 0
        drafted = accepted = spec_rounds = 0
        spec_stopped = 0

        from repro.runtime import sampling as smp
        from repro.runtime import shardctx

        # TP serving must NOT install the GSPMD mesh: the step is a
        # shard_map program over manual axes, where maybe_shard's
        # with_sharding_constraint is meaningless (and errors).
        ctx = (shardctx.use_mesh(self.mesh)
               if self.mesh is not None and not self._tp
               else contextlib.nullcontext())
        t0 = time.time()
        # rids whose device stop criterion fired before max_tokens: the
        # pipeline learns (at most two steps late, at consume time) and
        # discards the zombie steps' tokens; the loop top frees the row.
        stopped: set[int] = set()

        def consume(emits, toks_dev, fin_dev):
            """Read back one step's sampled tokens + finished mask
            (blocks until the device finishes that step) and credit
            them to requests."""
            vals = np.asarray(toks_dev)
            fins = None if fin_dev is None else np.asarray(fin_dev)
            now = time.time()
            for rid, r in emits:
                if rid in stopped:
                    continue    # zombie tokens dispatched past the stop
                out_vals[rid].append(int(vals[r, 0]))
                if first_tok_t[rid] is None:
                    first_tok_t[rid] = now
                done = len(out_vals[rid]) >= reqs[rid].max_tokens
                if fins is not None and fins[r]:
                    if not done:            # eos / stop sequence fired
                        stopped.add(rid)    # before the token budget ran
                    done = True
                if done:
                    finish_t[rid] = now
                if on_token is not None:
                    on_token(TokenEvent(rid=rid, token=out_vals[rid][-1],
                                        index=len(out_vals[rid]) - 1,
                                        time=now, final=done))

        with ctx:
            if ctl is not None:
                (steps, prefill_chunks, prefill_tokens, mixed_steps,
                 drafted, accepted, spec_rounds, spec_stopped) = \
                    self._spec_loop(
                        reqs, sched, pool, tables, cap, budget, ctl,
                        out_vals, first_tok_t, finish_t, queue_t, t0,
                        do_sample, on_token)
                sched_done = True
            else:
                sched_done = False
            step_fn = self._unified_fn(do_sample, do_stop)
            tables_dev = None       # device-safe copy, refreshed on change
            stops_dev = None        # ditto, for the stop-sequence buffer
            stop_buf = np.full((cap, n_stops, stop_len), -1, np.int32)
            no_stops = jnp.zeros((cap, 1, 1), jnp.int32)  # stop=False dummy
            inflight = collections.deque()  # (emits, toks, fin), oldest
            prev_toks = jnp.zeros((cap, 1), jnp.int32)
            recent = jnp.zeros((cap, stop_len), jnp.int32)
            while not sched_done and sched.has_work():
                # rows whose stop fired (discovered at consume): retire
                # them before scheduling so the row + blocks free now
                if stopped:
                    for seq in list(sched.rows):
                        if seq is not None and seq.req.rid in stopped:
                            sched.finish(seq)
                            tables[seq.row] = 0
                            tables_dev = None
                    if not sched.has_work():
                        break
                plan = sched.schedule(budget)
                for r in plan.preempted:    # victim rows: table to trash
                    tables[r] = 0           # (before any admission that
                    tables_dev = None       # reuses the row below)
                for seq in plan.admitted:
                    tables[seq.row] = 0
                    tables[seq.row, :len(seq.block_ids)] = seq.block_ids
                    tables_dev = None
                    queue_t[seq.req.rid] = time.time() - t0
                    if do_stop:
                        stop_buf[seq.row] = smp.pack_stop_seqs(
                            seq.req.stop, n_stops, stop_len)
                        stops_dev = None
                    if seq.cow_dst is not None:
                        # fully-cached prompt: materialize a private copy
                        # of the last matched block before this step's
                        # span write recomputes its final position
                        pool = self._cow_copy(pool, jnp.int32(seq.cow_src),
                                              jnp.int32(seq.cow_dst))
                        sched.release_cow(seq)
                if not plan.prefill and not plan.decode:
                    raise RuntimeError(
                        "scheduler returned an empty step with work "
                        "pending — admission deadlock")
                # ---- build the (cap, W + meta) span batch ----------------
                # one fresh packed buffer per step: span tokens, the
                # (ctx, q_len, use_prev) scheduling columns, then the
                # packed per-row sampling/stop metadata — still ONE
                # upload. Handed to the jitted step as numpy — never
                # mutated after dispatch, so jax's zero-copy aliasing of
                # host buffers is safe here.
                w = _pow2_bucket(plan.max_span)
                m = smp.SAMP_COLS
                buf = np.zeros((cap, w + 3 + m), np.int32)
                for r, width in plan.prefill.items():
                    seq = sched.rows[r]
                    lo = seq.prefilled
                    buf[r, :width] = seq.req.tokens[lo:lo + width]
                    buf[r, -(m + 3)] = lo
                    buf[r, -(m + 2)] = width
                for r in plan.decode:
                    seq = sched.rows[r]
                    # the input token is the one sampled last step; it is
                    # still on device (prev_toks), spliced in by the step.
                    # pool holds prompt + all but that newest token.
                    buf[r, -(m + 3)] = seq.prompt_len + seq.n_emitted - 1
                    buf[r, -(m + 2)] = 1
                    buf[r, -(m + 1)] = 1
                for r in list(plan.prefill) + plan.decode:
                    seq = sched.rows[r]
                    smp.write_row_meta(buf, r, seq.req, seq.n_emitted)
                # ---- ONE fused dispatch for the prefill/decode mix -------
                if tables_dev is None:
                    # a private copy: `tables` is mutated by later
                    # admissions/evictions while earlier dispatched steps
                    # may still be reading the (possibly aliased) upload
                    tables_dev = tables.copy()
                if do_stop and stops_dev is None:
                    stops_dev = stop_buf.copy()
                toks_dev, fin_dev, recent, pool = step_fn(
                    self.params, pool, tables_dev, buf, prev_toks,
                    recent, stops_dev if do_stop else no_stops)
                steps += 1
                prefill_chunks += len(plan.prefill)
                prefill_tokens += sum(plan.prefill.values())
                mixed_steps += plan.is_mixed
                prev_toks = toks_dev
                # ---- count-based bookkeeping at dispatch time ------------
                # (scheduling never waits on token values — eviction and
                # admission run ahead of the device; value-dependent
                # stops arrive via the pipelined finished mask above)
                emits = []
                for r, width in plan.prefill.items():
                    # advance + register newly completed full prompt
                    # blocks into the content index (dispatch order =
                    # device order, so later readers see the writes)
                    sched.advance_prefill(sched.rows[r], width)
                for r in list(plan.prefill) + plan.decode:
                    seq = sched.rows[r]
                    if not seq.prefill_done:
                        continue            # mid-prompt: logits unused
                    seq.n_emitted += 1
                    emits.append((seq.req.rid, r))
                    if seq.done:
                        sched.finish(seq)
                        tables[r] = 0
                        tables_dev = None
                # ---- consume an older step while this one runs -----------
                # (two steps of lookahead keep the device queue busy
                # through the host's scheduling + readback work)
                inflight.append((emits, toks_dev,
                                 fin_dev if do_stop else None))
                if len(inflight) > 2:
                    consume(*inflight.popleft())
            while inflight:
                consume(*inflight.popleft())
            # stops discovered in the final drain: the rows already
            # finished by count, but late-stopped outputs stay truncated
            if not sched_done:
                for seq in list(sched.rows):
                    if seq is not None and seq.req.rid in stopped:
                        sched.finish(seq)
                        tables[seq.row] = 0
        if pool_alloc.available != pool_alloc.capacity:
            raise RuntimeError(
                f"leaked KV blocks: {pool_alloc.capacity - pool_alloc.available}"
                f" of {pool_alloc.capacity} still allocated after drain")
        outputs = [np.asarray(v, np.int32) for v in out_vals]
        ttft = [first_tok_t[i] - t0 for i in range(len(reqs))]
        tpot = [(finish_t[i] - first_tok_t[i]) / (len(out_vals[i]) - 1)
                if len(out_vals[i]) > 1 else 0.0
                for i in range(len(reqs))]
        return ServeResult(
            outputs=outputs, prompt_lens=[r.tokens.size for r in reqs],
            seconds=time.time() - t0, steps=steps,
            prefill_chunks=prefill_chunks, prefill_tokens=prefill_tokens,
            mixed_steps=mixed_steps, chunk_tokens=budget,
            max_queue_depth=sched.max_queue_depth, max_batch=cap,
            block_size=bs, num_blocks=num_blocks, ttft=ttft, tpot=tpot,
            spec_k=(ctl.spec.k if ctl is not None else 0),
            drafted=drafted, accepted=accepted, spec_rounds=spec_rounds,
            prefix_cache=use_cache,
            cache_lookup_blocks=sched.cache_lookup_blocks,
            cache_hit_blocks=sched.cache_hit_blocks,
            cache_hit_tokens=sched.cache_hit_tokens,
            cache_cow_blocks=sched.cache_cow_blocks,
            cache_evictions=pool_alloc.evictions,
            preemptions=sched.preemptions,
            queue_times=queue_t,
            finish_times=[finish_t[i] - t0 for i in range(len(reqs))],
            stopped_early=len(stopped) + spec_stopped)

    def _spec_loop(self, reqs, sched, pool, tables, cap, budget, ctl,
                   out_vals, first_tok_t, finish_t, queue_t, t0,
                   do_sample, on_token):
        """The speculative serve loop: one fused draft->verify->accept
        dispatch per step (runtime.speculation.speculative_step).

        Synchronous by design — how many tokens a row advanced is
        value-dependent (the accept count), so the next step's schedule
        must wait for this step's readback. The throughput win comes
        from E[accepted + 1] tokens per dispatch, not from pipelining;
        in the dispatch-bound small-step regime that IS the serving
        bottleneck. Only two step variants ever trace per sampling
        mode: draft width spec.k (any drafting row this step) and 0
        (none — e.g. a prefill-only step), mirroring the
        non-speculative path's power-of-two span bucketing.

        Rows with temperature > 0 never draft (the scheduler skips them
        in the spec offer) but sample their one token inside the same
        fused dispatch. Stop criteria are evaluated host-side with the
        shared oracle (`sampling.match_stop_host`) — this loop reads
        every token back synchronously anyway, so the device mask would
        buy nothing.

        Mutates out_vals / first_tok_t / finish_t / queue_t in place
        (same contract as serve's consume()); returns the step
        counters."""
        from repro.runtime import sampling as smp

        steps = prefill_chunks = prefill_tokens = mixed_steps = 0
        drafted = accepted = spec_rounds = 0
        stopped_early = 0
        m = smp.SAMP_COLS
        tables_dev = None
        prev_toks = jnp.zeros((cap, 1), jnp.int32)
        while sched.has_work():
            plan = sched.schedule(budget, spec_k=ctl.spec.k)
            for r in plan.preempted:
                tables[r] = 0
                tables_dev = None
            for seq in plan.admitted:
                tables[seq.row] = 0
                tables[seq.row, :len(seq.block_ids)] = seq.block_ids
                tables_dev = None
                queue_t[seq.req.rid] = time.time() - t0
                if seq.cow_dst is not None:
                    pool = self._cow_copy(pool, jnp.int32(seq.cow_src),
                                          jnp.int32(seq.cow_dst))
                    sched.release_cow(seq)
            # draft-block reservations can grow a row's table mid-flight
            # (only when admission could not pre-reserve the worst case)
            for r in plan.spec:
                seq = sched.rows[r]
                if seq.draft_blocks:
                    tables[r, :len(seq.block_ids)] = seq.block_ids
                    tables_dev = None
            if not plan.prefill and not plan.decode:
                raise RuntimeError(
                    "scheduler returned an empty step with work "
                    "pending — admission deadlock")
            # ---- (cap, W + meta) span batch; meta gains spec_lens -------
            k_step = ctl.spec.k if plan.spec else 0
            w = _pow2_bucket(max(plan.max_span, k_step + 1))
            buf = np.zeros((cap, w + 4 + m), np.int32)
            for r, width in plan.prefill.items():
                seq = sched.rows[r]
                lo = seq.prefilled
                buf[r, :width] = seq.req.tokens[lo:lo + width]
                buf[r, -(m + 4)] = lo
                buf[r, -(m + 3)] = width
            for r in plan.decode:
                seq = sched.rows[r]
                kr = plan.spec.get(r, 0)
                # span: [prev (device-spliced), kr draft slots]
                buf[r, -(m + 4)] = seq.prompt_len + seq.n_emitted - 1
                buf[r, -(m + 3)] = 1 + kr
                buf[r, -(m + 2)] = 1
                buf[r, -(m + 1)] = kr
            for r in list(plan.prefill) + plan.decode:
                seq = sched.rows[r]
                smp.write_row_meta(buf, r, seq.req, seq.n_emitted)
            if tables_dev is None:
                tables_dev = tables.copy()
            full_toks, n_acc, prev_toks, pool = ctl.step_fn(
                k_step, do_sample)(
                self.params, ctl.draft_params, pool, tables_dev, buf,
                prev_toks)
            steps += 1
            spec_rounds += bool(plan.spec)
            prefill_chunks += len(plan.prefill)
            prefill_tokens += sum(plan.prefill.values())
            mixed_steps += plan.is_mixed
            # acceptance decides how far each row advanced: read back now
            fv = np.asarray(full_toks)
            na = np.asarray(n_acc)
            now = time.time()
            for r, width in plan.prefill.items():
                sched.advance_prefill(sched.rows[r], width)
            for r in list(plan.prefill) + plan.decode:
                seq = sched.rows[r]
                if not seq.prefill_done:
                    continue        # mid-prompt: logits unused
                if r in plan.prefill:
                    # prompt finished this step: emit the last-valid-
                    # position token (appended verify column k_step + 1)
                    toks = fv[r, k_step + 1:k_step + 2]
                else:
                    # decode: accepted draft prefix + the full model's
                    # own token at the first divergence (or the bonus)
                    toks = fv[r, :int(na[r]) + 1]
                rid = seq.req.rid
                prev_len = len(out_vals[rid])
                out_vals[rid].extend(int(t) for t in toks)
                if first_tok_t[rid] is None:
                    first_tok_t[rid] = now
                seq.n_emitted += len(toks)
                kr = plan.spec.get(r, 0)
                if kr:
                    drafted += kr
                    accepted += len(toks) - 1
                    if sched.commit_speculation(seq):
                        # rollback released tail blocks: rewind the table
                        tables[r] = 0
                        tables[r, :len(seq.block_ids)] = seq.block_ids
                        tables_dev = None
                # host-side stop check (shared oracle; tokens already
                # read back). Inclusive semantics: keep through the
                # matching token, drop anything verified past it.
                keep = smp.match_stop_host(out_vals[rid], seq.req.eos_id,
                                           seq.req.stop, seq.max_tokens)
                if keep is not None:
                    del out_vals[rid][keep:]
                if on_token is not None:
                    for j in range(prev_len, len(out_vals[rid])):
                        on_token(TokenEvent(
                            rid=rid, token=out_vals[rid][j], index=j,
                            time=now,
                            final=(keep is not None
                                   and j == len(out_vals[rid]) - 1)))
                if keep is not None:
                    stopped_early += len(out_vals[rid]) < seq.max_tokens
                    finish_t[rid] = now
                    sched.finish(seq)
                    tables[r] = 0
                    tables_dev = None
        return (steps, prefill_chunks, prefill_tokens, mixed_steps,
                drafted, accepted, spec_rounds, stopped_early)

    def _pick(self, logits, sampling: SamplingParams,
              counter: int) -> jnp.ndarray:
        """(B, 1) next tokens from (B, ..., V) last-position logits —
        greedy argmax, or the shared counter-keyed sampler (see
        runtime/sampling.py; `counter` is the output-token index)."""
        if sampling.temperature <= 0.0:
            return self._argmax(logits)
        return self._sample(logits, np.float32(sampling.temperature),
                            np.int32(sampling.top_k),
                            np.float32(sampling.top_p),
                            np.int32(sampling.seed), np.int32(counter))
