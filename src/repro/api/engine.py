"""`InferenceEngine` — the online half of the plan→engine seam.

Build compiles a serving engine from (architecture, CompressionPlan):
compress the weights per the plan, optionally place them on a device mesh,
and jit the prefill / decode-step callables once. Generation then runs any
number of batched requests against the same compiled engine:

    plan = CompressionPlan.load("plan.json")          # e.g. a DSE winner
    eng = InferenceEngine.build("opus-mt", plan, smoke=True)
    out = eng.generate(prompts, SamplingParams(max_tokens=32, top_k=40))

`launch.serve` is a thin CLI over this class; every future serving feature
(continuous batching, KV paging, multi-host decode) lands behind this
facade rather than in loose scripts.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import CompressionPlan
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.compress import CompressionConfig, compress_params
from repro.models import transformer as tfm


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-generate sampling controls. temperature <= 0 means greedy;
    top_k == 0 samples the full vocabulary."""

    max_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_tokens) int32
    prompt_len: int
    seconds: float

    @property
    def tokens_per_second(self) -> float:
        b, g = self.tokens.shape
        return b * g / max(self.seconds, 1e-9)


def _as_token_batch(requests) -> jnp.ndarray:
    """(B, S) int32 from an array or a list of equal-length token lists."""
    if isinstance(requests, (list, tuple)):
        if not requests:
            raise ValueError("empty request batch")
        lens = {len(r) for r in requests}
        if len(lens) != 1:
            raise ValueError(
                f"ragged request lengths {sorted(lens)}: pad requests to a "
                f"common length (continuous batching is a future engine "
                f"feature, not a caller concern)")
        requests = np.asarray(requests)
    toks = jnp.asarray(requests, jnp.int32)
    if toks.ndim != 2:
        raise ValueError(f"requests must be (batch, seq), got {toks.shape}")
    return toks


class InferenceEngine:
    """Compiled compress→shard→serve pipeline for one model + plan."""

    def __init__(self, cfg: ModelConfig, params, *, plan=None, report=None,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.report = report
        self.mesh = mesh
        # jit once; XLA re-specializes per (batch, seq, max_len) shape.
        self._prefill = jax.jit(
            lambda p, toks, max_len: tfm.prefill(p, toks, cfg,
                                                 max_len=max_len),
            static_argnums=2)
        self._decode = jax.jit(
            lambda p, cache, tok, pos: tfm.decode_step(p, cache, tok, pos,
                                                       cfg))

    # ------------------------------------------------------------- build --
    @classmethod
    def build(cls, arch, plan=None, *, mesh=None, params=None,
              smoke: bool = False, seed: int = 0,
              verbose: bool = False) -> "InferenceEngine":
        """arch: config name (see repro.configs) or a ModelConfig.
        plan: CompressionPlan | legacy CompressionConfig | None (dense).
        params: pre-trained weights; freshly initialized when omitted.
        mesh: optional jax Mesh — weights are placed per launch.sharding."""
        cfg = get_config(arch, smoke=smoke) if isinstance(arch, str) else arch
        if params is None:
            params = tfm.init_params(jax.random.PRNGKey(seed), cfg)

        report = None
        if isinstance(plan, CompressionConfig):
            plan = (None if plan.method == "none"
                    else CompressionPlan.from_config(params, plan))
        if plan is not None:
            t0 = time.time()
            params, report = compress_params(params, plan)
            plan = report.plan
            if verbose:
                print(f"[engine] compressed in {time.time()-t0:.1f}s: "
                      f"{report.summary()}")

        if mesh is not None:
            from repro.launch import sharding as shd

            params = jax.device_put(params,
                                    shd.param_shardings(params, mesh, cfg))
        return cls(cfg, params, plan=plan, report=report, mesh=mesh)

    # ---------------------------------------------------------- generate --
    def generate(self, requests, sampling: SamplingParams | None = None
                 ) -> GenerationResult:
        """Prefill + batched decode for a rectangular batch of requests.

        requests: (B, S) int tokens (array or list of equal-length lists).
        Returns the generated continuation only, shape (B, max_tokens).
        """
        sampling = sampling or SamplingParams()
        toks = _as_token_batch(requests)
        s = toks.shape[1]
        max_len = s + sampling.max_tokens

        from repro.runtime import shardctx

        ctx = (shardctx.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        t0 = time.time()
        with ctx:
            logits, cache = self._prefill(self.params, toks, max_len)
            key = jax.random.PRNGKey(sampling.seed)
            out = []
            key, k = jax.random.split(key)
            tok = self._pick(logits, k, sampling)
            for i in range(sampling.max_tokens):
                out.append(tok)
                if i + 1 == sampling.max_tokens:
                    break
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.asarray(s + i))
                key, k = jax.random.split(key)
                tok = self._pick(logits, k, sampling)
            gen = jax.block_until_ready(jnp.concatenate(out, axis=1))
        return GenerationResult(tokens=np.asarray(gen), prompt_len=s,
                                seconds=time.time() - t0)

    @staticmethod
    def _pick(logits, key, sampling: SamplingParams) -> jnp.ndarray:
        """(B, 1) next tokens from (B, ..., V) last-position logits."""
        last = logits[:, -1]
        if sampling.temperature <= 0.0:
            return jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
        scaled = last / sampling.temperature
        if sampling.top_k > 0 and sampling.top_k < scaled.shape[-1]:
            kth = jax.lax.top_k(scaled, sampling.top_k)[0][..., -1:]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return jax.random.categorical(key, scaled)[:, None].astype(jnp.int32)
