"""Checkpointing: atomic manifest-committed saves, async (off the critical
path), keep-last-k GC, and *elastic* restore — a checkpoint written on one
mesh can resume on any mesh whose axis sizes divide the global shapes.

Layout:
  <dir>/step_000123.tmp/       (written)
  <dir>/step_000123/           (atomic rename = commit)
    manifest.json              step, keys, shapes, dtypes
    arrays.npz                 flattened pytree, path-keyed

Restore never trusts a directory without a manifest (a crash mid-save
leaves only *.tmp, which is garbage-collected on the next save).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.itera import LowRankQ      # noqa: F401  (registers pytree
from repro.core.quant import QuantizedTensor  # noqa: F401   nodes appearing
                                              # in compressed checkpoints)

_SEP = "|"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_part(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _part(p):
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    if hasattr(p, "name"):
        return f"a:{p.name}"
    return f"x:{p}"


def _quant_formats(tree) -> dict:
    """{path: {wl, axis, packed, act_wl}} for every QuantizedTensor node.

    The codes/scales land in arrays.npz like any leaf; this records the
    *layout* aux alongside them so the manifest is self-describing and
    restore can refuse a tree built with the wrong residency (a packed-W4
    checkpoint restored into a carrier-layout tree, or vice versa, would
    otherwise only surface as a confusing shape error)."""
    fmts = {}

    def visit(path, leaf):
        if isinstance(leaf, QuantizedTensor):
            fmts[_SEP.join(_part(p) for p in path)] = {
                "wl": int(leaf.wl), "axis": int(leaf.axis),
                "packed": bool(leaf.packed), "act_wl": int(leaf.act_wl),
            }
        return leaf

    jax.tree_util.tree_map_with_path(
        visit, tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    return fmts


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         async_save: bool = False):
    """Write a checkpoint. async_save=True returns a join()able thread."""
    host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        name = f"step_{step:08d}"
        tmp = os.path.join(ckpt_dir, name + ".tmp")
        final = os.path.join(ckpt_dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays = _flatten(host_tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "quant_formats": _quant_formats(host_tree),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic commit
        _gc(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    for d in os.listdir(ckpt_dir):                 # crashed partial saves
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str):
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like, step: int | None = None, *,
            shardings=None):
    """Restore into the structure of `like` (a pytree or ShapeDtypeStructs).

    shardings: optional pytree of NamedSharding matching `like` — this is
    the elastic-resume path: arrays are device_put with the *new* mesh's
    shardings regardless of what mesh wrote them.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat = jax.tree_util.tree_flatten_with_path(like)
    paths = [(_SEP.join(_part(p) for p in path), leaf)
             for path, leaf in flat[0]]
    missing = [k for k, _ in paths if k not in manifest["keys"]]
    if missing:
        raise KeyError(f"checkpoint at step {step} missing keys: "
                       f"{missing[:5]}{'...' if len(missing) > 5 else ''}")

    # layout guard: quantized nodes must agree on the fields that shape
    # the stored arrays (wl, axis, packed) — restoring a packed
    # checkpoint into a carrier tree (or the reverse) is a plan mismatch,
    # not an elastic-resume case. act_wl is runtime-only aux (it never
    # changes resident bytes), so differing act_wl restores fine and
    # `like`'s value wins.
    saved_fmts = manifest.get("quant_formats")
    if saved_fmts is not None:
        want_fmts = _quant_formats(like)
        layout = ("wl", "axis", "packed")
        for key in sorted(set(saved_fmts) & set(want_fmts)):
            got = {f: saved_fmts[key].get(f) for f in layout}
            want = {f: want_fmts[key].get(f) for f in layout}
            if got != want:
                raise ValueError(
                    f"{key}: checkpoint quant layout {got} != expected "
                    f"{want} — rebuild `like` with the plan this "
                    f"checkpoint was compressed under")

    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(paths))
    leaves = []
    for (key, leaf), sh in zip(paths, shard_flat):
        arr = data[key]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {want}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(flat[1], leaves), step
