"""Pure-jnp oracles for the Pallas kernels.

Two kinds of references:
  * *_ref       — bit-faithful mirror of the kernel's arithmetic (including
                  the intermediate requantization of the cascade) used for
                  assert_allclose in tests;
  * *_exact     — full-precision math, used for error-bound style checks.
"""
from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(xq, sx, wq, sw):
    """Y = dequant(Xq) @ dequant(Wq).

    xq: (M, K) int8 codes; sx: (M, 1) fp32 row scales
    wq: (K, N) int8 codes; sw: (1, N) fp32 column scales
    """
    acc = jnp.dot(
        xq.astype(jnp.int32), wq.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * sx * sw


def requant_rows(t: jnp.ndarray, qm: int = 127):
    """Symmetric per-row requantization of an fp intermediate to an int8
    carrier, clamped to ±qm = ±qmax(act_wl) (127 == A8)."""
    absmax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
    st = jnp.where(absmax > 0, absmax / qm, 1.0)
    tq = jnp.clip(jnp.round(t / st), -qm, qm).astype(jnp.int8)
    return tq, st.astype(jnp.float32)


def lowrank_qmm_ref(xq, sx, w1q, s1, w2q, s2, qm: int = 127):
    """Cascade low-rank quantized matmul, mirroring the fused kernel:

    phase 1: T̃ = (Xq @ W1q) · sx · s1 · s2ᵀ     (s2 folded into T)
    requant: Tq, sT = rowquant(T̃)  clamped to ±qm (the plan's act_wl)
    phase 2: Y = (Tq @ W2q) · sT

    xq: (M, K) int8; sx: (M, 1) f32
    w1q: (K, R) int8; s1: (1, R) f32
    w2q: (R, N) int8; s2: (R, 1) f32
    Factors arrive in carrier layout — callers unpack packed W4 first
    (ops.qmm/lrmm do); nibble unpack is exact, so this stays a
    bit-faithful oracle for the packed kernels too.
    """
    t = jnp.dot(
        xq.astype(jnp.int32), w1q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    t = t * sx * s1 * s2.reshape(1, -1)
    tq, st = requant_rows(t, qm)
    y = jnp.dot(
        tq.astype(jnp.int32), w2q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    return y * st


def lowrank_qmm_exact(x, w1f, w2f):
    """Full-precision (X @ W1) @ W2 for error-bound checks."""
    return (x @ w1f) @ w2f
