"""Public jit'd wrappers around the Pallas kernels.

Handles: activation quantization (A8 per-row), padding to block multiples,
automatic block-shape selection under a VMEM budget (the DSE's per-layer
choice — see hw/dse.py for the global search), and backend dispatch:

  * on TPU           -> compiled Pallas kernels
  * on CPU (tests)   -> interpret=True Pallas (bit-faithful emulation)
  * use_kernel=False -> pure-jnp reference path (used inside big jitted
                        models / dry-runs, where interpret-mode Pallas would
                        bloat the HLO; numerically identical to ref.py)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.itera import LowRankQ
from repro.core.quant import QuantizedTensor
from repro.kernels import lowrank_qmm as _lr
from repro.kernels import quant_matmul as _qm
from repro.kernels import ref as _ref

VMEM_BUDGET = 12 * 1024 * 1024  # leave headroom below the 16 MiB/core VMEM


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize_acts(x: jax.Array, qm: int = 127):
    """Per-row symmetric A8 activation quantization."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    sx = jnp.where(absmax > 0, absmax / qm, 1.0).astype(jnp.float32)
    xq = jnp.clip(jnp.round(x / sx), -qm, qm).astype(jnp.int8)
    return xq, sx


def choose_blocks(m: int, k: int, n: int, r: int | None = None,
                  budget: int = VMEM_BUDGET):
    """Pick (bm, bk, bn) aligned to the MXU that fit the VMEM budget.

    Mirrors the paper's hardware-aware tile selection: prefer large bm/bn
    (amortize weight streaming), shrink until the working set fits.
    """
    bm = min(_round_up(m, 8), 256)
    bk = min(_round_up(k, 128), 512)
    bn = min(_round_up(n, 128), 512)
    fits = (lambda: _lr.vmem_bytes(bm, bk, bn, r)) if r is not None else (
        lambda: _qm.vmem_bytes(bm, bk, bn))
    while fits() > budget and bm > 8:
        bm //= 2
    while fits() > budget and bn > 128:
        bn //= 2
    while fits() > budget and bk > 128:
        bk //= 2
    return bm, bk, bn


def _pad2(x, m0, m1):
    p0, p1 = m0 - x.shape[0], m1 - x.shape[1]
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


@functools.partial(
    jax.jit,
    static_argnames=("use_kernel", "interpret", "blocks", "out_dtype"),
)
def qmm(
    x: jax.Array,
    w: QuantizedTensor,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    blocks: tuple | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """y = dequant(quant(x)) @ dequant(w) — WxA8 dense linear.

    x: (..., K) float; w: QuantizedTensor (K, N) with per-column scales.
    """
    if interpret is None:
        interpret = not on_tpu()
    lead = x.shape[:-1]
    k, n = w.shape
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    xq, sx = quantize_acts(x2)
    sw = w.scale.reshape(1, n)

    if not use_kernel:
        y = _ref.quant_matmul_ref(xq, sx, w.values, sw)
        return y.astype(out_dtype).reshape(*lead, n)

    bm, bk, bn = blocks or choose_blocks(m, k, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    y = _qm.quant_matmul(
        _pad2(xq, mp, kp), _pad2(sx, mp, 1),
        _pad2(w.values, kp, np_), _pad2(sw, 1, np_),
        bm=bm, bk=bk, bn=bn, out_dtype=out_dtype, interpret=interpret,
    )[:m, :n]
    return y.reshape(*lead, n)


@functools.partial(
    jax.jit,
    static_argnames=("use_kernel", "interpret", "blocks", "out_dtype", "fused"),
)
def lrmm(
    x: jax.Array,
    lr: LowRankQ,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    blocks: tuple | None = None,
    out_dtype=jnp.float32,
    fused: bool = True,
) -> jax.Array:
    """y = ((quant(x) @ W1') @ W2') — the ITERA low-rank linear.

    fused=True  -> Cascade engine analog (single kernel, T pinned in VMEM)
    fused=False -> Single engine analog (two quant_matmul launches; T makes
                   an HBM round-trip — kept for the engine comparison bench)
    """
    if interpret is None:
        interpret = not on_tpu()
    lead = x.shape[:-1]
    k, r = lr.w1.shape
    _, n = lr.w2.shape
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    xq, sx = quantize_acts(x2)
    s1 = lr.w1.scale.reshape(1, r)
    s2 = lr.w2.scale.reshape(r, 1)

    if not use_kernel:
        y = _ref.lowrank_qmm_ref(xq, sx, lr.w1.values, s1, lr.w2.values, s2)
        return y.astype(out_dtype).reshape(*lead, n)

    if not fused:
        # Single-engine schedule: T leaves the chip between the two matmuls.
        t = _ref.quant_matmul_ref(xq, sx, lr.w1.values, s1)
        t = t * s2.reshape(1, -1)
        tq, st = quantize_acts(t)
        bm, bk, bn = blocks or choose_blocks(m, r, n)
        mp, rp, np_ = _round_up(m, bm), _round_up(r, bk), _round_up(n, bn)
        y = _qm.quant_matmul(
            _pad2(tq, mp, rp), _pad2(st, mp, 1),
            _pad2(lr.w2.values, rp, np_),
            jnp.ones((1, np_), jnp.float32),
            bm=bm, bk=bk, bn=bn, out_dtype=out_dtype, interpret=interpret,
        )[:m, :n]
        return y.reshape(*lead, n)

    rp = _round_up(r, 128)
    bm, bk, bn = blocks or choose_blocks(m, k, n, rp)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    y = _lr.lowrank_qmm(
        _pad2(xq, mp, kp), _pad2(sx, mp, 1),
        _pad2(lr.w1.values, kp, rp),
        _pad2(jnp.pad(s1, ((0, 0), (0, rp - r)), constant_values=1.0), 1, rp),
        _pad2(lr.w2.values, rp, np_),
        _pad2(jnp.pad(s2, ((0, rp - r), (0, 0)), constant_values=1.0), rp, 1),
        bm=bm, bk=bk, bn=bn, out_dtype=out_dtype, interpret=interpret,
    )[:m, :n]
    return y.reshape(*lead, n)
