"""Public jit'd wrappers around the Pallas kernels.

Handles: activation quantization (per-row symmetric, clamp from the plan's
act_wl carried on the weight node — A8 by default), packed-W4 layout
dispatch (packed arrays DMA as-is; the kernels unpack in VMEM), padding to
block multiples (zero bytes unpack to zero codes, so padding happens
directly in the packed domain), automatic block-shape selection under a
VMEM budget (the DSE's per-layer choice — see hw/dse.py for the global
search), and backend dispatch:

  * on TPU           -> compiled Pallas kernels
  * on CPU (tests)   -> interpret=True Pallas (bit-faithful emulation)
  * use_kernel=False -> pure-jnp reference path (used inside big jitted
                        models / dry-runs, where interpret-mode Pallas would
                        bloat the HLO; numerically identical to the kernels
                        — packed weights are unpacked up front, which is
                        exact)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.itera import LowRankQ
from repro.core.quant import (
    QuantizedTensor, packed_pad_ok, qmax, unpack_int4,
)
from repro.kernels import lowrank_qmm as _lr
from repro.kernels import quant_matmul as _qm
from repro.kernels import ref as _ref

VMEM_BUDGET = 12 * 1024 * 1024  # leave headroom below the 16 MiB/core VMEM


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize_acts(x: jax.Array, qm: int = 127):
    """Per-row symmetric activation quantization into an int8 carrier,
    clamped to ±qm = ±qmax(act_wl); qm=127 is the A8 default."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    sx = jnp.where(absmax > 0, absmax / qm, 1.0).astype(jnp.float32)
    xq = jnp.clip(jnp.round(x / sx), -qm, qm).astype(jnp.int8)
    return xq, sx


# Pad-inflating pack axes (core.quant.packed_pad_ok false — e.g. the
# paper512 cascade's R=128, once `kernel_lrmm_interp_W4_packed_paper512`'s
# 11297us-vs-6379us regression) are refused at PACK time: compress_params
# stores them as int8 carriers, so the dispatchers below normally never
# see them. The demotion branches in qmm/lrmm are a fallback for
# hand-built packed tensors only — they unpack per call (exact, so still
# bit-identical), and the *_hbm_bytes models charge that unpack
# round-trip so the benchmark's packed<=carrier assert stays honest.


def choose_blocks(m: int, k: int, n: int, r: int | None = None,
                  budget: int = VMEM_BUDGET, *,
                  packed_n: bool = False, packed_r: bool = False):
    """Pick (bm, bk, bn) aligned to the MXU that fit the VMEM budget.

    Mirrors the paper's hardware-aware tile selection: prefer large bm/bn
    (amortize weight streaming), shrink until the working set fits.
    packed_n: the N-axis operand (W, or W2 in the cascade) is
    nibble-packed, so bn stays >= 256 (the packed half-block must remain
    lane-aligned) and the working set counts the unpack temp. packed_r:
    the cascade's W1 is packed along R (affects only the vmem model; R is
    never tiled). Callers must only set packed_* for axes where
    `packed_pad_ok` holds — qmm/lrmm demote the rest to carrier first —
    so the bn_floor=256 constraint never inflates a small-N/R launch.
    """
    bn_floor = 256 if packed_n else 128
    bm = min(_round_up(m, 8), 256)
    bk = min(_round_up(k, 128), 512)
    # packed N blocks must be multiples of 256 (half-block lane-aligned),
    # and halving from 512 keeps them so; carrier blocks align to 128
    bn = max(min(_round_up(n, bn_floor), 512), bn_floor)
    if r is not None:
        fits = lambda: _lr.vmem_bytes(bm, bk, bn, r, w1_packed=packed_r,
                                      w2_packed=packed_n)     # noqa: E731
    else:
        fits = lambda: _qm.vmem_bytes(bm, bk, bn,
                                      w_packed=packed_n)      # noqa: E731
    while fits() > budget and bm > 8:
        bm //= 2
    while fits() > budget and bn > bn_floor:
        bn //= 2
    while fits() > budget and bk > 128:
        bk //= 2
    return bm, bk, bn


def _pad2(x, m0, m1):
    p0, p1 = m0 - x.shape[0], m1 - x.shape[1]
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


@functools.partial(
    jax.jit,
    static_argnames=("use_kernel", "interpret", "blocks", "out_dtype"),
)
def qmm(
    x: jax.Array,
    w: QuantizedTensor,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    blocks: tuple | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """y = dequant(quant(x)) @ dequant(w) — WxAy dense linear.

    x: (..., K) float; w: QuantizedTensor (K, N) with per-column scales.
    The activation word length (Ay) and the packed/carrier layout ride on
    `w` as pytree aux data, so they are static here: the clamp range is
    qmax(w.act_wl), and a packed w streams its nibble bytes straight into
    the kernel.
    """
    if interpret is None:
        interpret = not on_tpu()
    lead = x.shape[:-1]
    k, n = w.shape                     # logical, even when packed
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    xq, sx = quantize_acts(x2, qmax(w.act_wl))
    sw = w.scale.reshape(1, n)

    if not use_kernel:
        wv = unpack_int4(w.values) if w.packed else w.values
        y = _ref.quant_matmul_ref(xq, sx, wv, sw)
        return y.astype(out_dtype).reshape(*lead, n)

    w_packed, wval = w.packed, w.values
    if w_packed and not packed_pad_ok(n):
        wval, w_packed = unpack_int4(wval), False  # exact; see packed_pad_ok
    bm, bk, bn = blocks or choose_blocks(m, k, n, packed_n=w_packed)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    wv = _pad2(wval, kp, np_ // 2 if w_packed else np_)
    y = _qm.quant_matmul(
        _pad2(xq, mp, kp), _pad2(sx, mp, 1),
        wv, _pad2(sw, 1, np_),
        bm=bm, bk=bk, bn=bn, out_dtype=out_dtype, interpret=interpret,
        w_packed=w_packed,
    )[:m, :n]
    return y.reshape(*lead, n)


@functools.partial(
    jax.jit,
    static_argnames=("use_kernel", "interpret", "blocks", "out_dtype", "fused"),
)
def lrmm(
    x: jax.Array,
    lr: LowRankQ,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    blocks: tuple | None = None,
    out_dtype=jnp.float32,
    fused: bool = True,
) -> jax.Array:
    """y = ((quant(x) @ W1') @ W2') — the ITERA low-rank linear.

    fused=True  -> Cascade engine analog (single kernel, T pinned in VMEM)
    fused=False -> Single engine analog (two quant_matmul launches; T makes
                   an HBM round-trip — kept for the engine comparison bench)

    Activation word length (input quantization AND the phase-boundary
    requant clamp) comes from lr.act_wl; packed factors stream packed.
    """
    if interpret is None:
        interpret = not on_tpu()
    lead = x.shape[:-1]
    k, r = lr.w1.shape                 # logical
    _, n = lr.w2.shape
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    act_qm = qmax(lr.act_wl)
    xq, sx = quantize_acts(x2, act_qm)
    s1 = lr.w1.scale.reshape(1, r)
    s2 = lr.w2.scale.reshape(r, 1)

    if not use_kernel:
        w1v = unpack_int4(lr.w1.values) if lr.w1.packed else lr.w1.values
        w2v = unpack_int4(lr.w2.values) if lr.w2.packed else lr.w2.values
        y = _ref.lowrank_qmm_ref(xq, sx, w1v, s1, w2v, s2, act_qm)
        return y.astype(out_dtype).reshape(*lead, n)

    # demote packed factors whose axis would pad fatter than its carrier
    # (exact nibble unpack; see packed_pad_ok) — W1 packs along R, W2
    # along N
    w1_packed, w1v = lr.w1.packed, lr.w1.values
    if w1_packed and not packed_pad_ok(r):
        w1v, w1_packed = unpack_int4(w1v), False
    w2_packed, w2v = lr.w2.packed, lr.w2.values
    if w2_packed and not packed_pad_ok(n):
        w2v, w2_packed = unpack_int4(w2v), False

    if not fused:
        # Single-engine schedule: T leaves the chip between the two
        # matmuls — and both phases run the Pallas kernel, so the engine
        # comparison bench measures kernel-vs-kernel, not ref-vs-kernel.
        bm1, bk1, bn1 = choose_blocks(m, k, r, packed_n=w1_packed)
        mp, kp = _round_up(m, bm1), _round_up(k, bk1)
        rp1 = _round_up(r, bn1)
        t = _qm.quant_matmul(
            _pad2(xq, mp, kp), _pad2(sx, mp, 1),
            _pad2(w1v, kp, rp1 // 2 if w1_packed else rp1),
            _pad2(s1, 1, rp1),
            bm=bm1, bk=bk1, bn=bn1, interpret=interpret,
            w_packed=w1_packed,
        )[:m, :r]
        t = t * s2.reshape(1, -1)
        tq, st = quantize_acts(t, act_qm)
        bm, bk, bn = blocks or choose_blocks(m, r, n, packed_n=w2_packed)
        mp, rp, np_ = _round_up(m, bm), _round_up(r, bk), _round_up(n, bn)
        y = _qm.quant_matmul(
            _pad2(tq, mp, rp), _pad2(st, mp, 1),
            _pad2(w2v, rp, np_ // 2 if w2_packed else np_),
            jnp.ones((1, np_), jnp.float32),
            bm=bm, bk=bk, bn=bn, out_dtype=out_dtype, interpret=interpret,
            w_packed=w2_packed,
        )[:m, :n]
        return y.reshape(*lead, n)

    # R is held whole in VMEM; a packed W1 needs rp // 2 lane-aligned.
    rp = _round_up(r, 256 if w1_packed else 128)
    bm, bk, bn = blocks or choose_blocks(m, k, n, rp,
                                         packed_n=w2_packed,
                                         packed_r=w1_packed)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    y = _lr.lowrank_qmm(
        _pad2(xq, mp, kp), _pad2(sx, mp, 1),
        _pad2(w1v, kp, rp // 2 if w1_packed else rp),
        _pad2(jnp.pad(s1, ((0, 0), (0, rp - r)), constant_values=1.0), 1, rp),
        _pad2(w2v, rp, np_ // 2 if w2_packed else np_),
        _pad2(jnp.pad(s2, ((0, rp - r), (0, 0)), constant_values=1.0), rp, 1),
        bm=bm, bk=bk, bn=bn, out_dtype=out_dtype, interpret=interpret,
        w1_packed=w1_packed, w2_packed=w2_packed, act_qmax=act_qm,
    )[:m, :n]
    return y.reshape(*lead, n)


def qmm_hbm_bytes(m: int, w: QuantizedTensor,
                  blocks: tuple | None = None) -> int:
    """Modeled HBM bytes one qmm(x, w) launch moves for an (m, K) input —
    the bytes-moved column in BENCH_kernels.json. Uses the same block
    choice AND the same packed-axis demotion as the dispatch above, on
    the padded shapes."""
    k, n = w.shape
    packed = w.packed and packed_pad_ok(n)
    bm, bk, bn = blocks or choose_blocks(m, k, n, packed_n=packed)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    total = _qm.hbm_bytes_moved(mp, kp, np_, bm, bn, w_packed=packed)
    if w.packed and not packed:
        total += k * n * 3 // 2     # fallback demotion: packed read + write
    return total


def lrmm_hbm_bytes(m: int, lr: LowRankQ,
                   blocks: tuple | None = None) -> int:
    """Modeled HBM bytes one fused lrmm(x, lr) launch moves (with the
    dispatch's packed-axis demotion applied, so the model prices what
    actually streams)."""
    k, r = lr.w1.shape
    _, n = lr.w2.shape
    w1p = lr.w1.packed and packed_pad_ok(r)
    w2p = lr.w2.packed and packed_pad_ok(n)
    rp = _round_up(r, 256 if w1p else 128)
    bm, bk, bn = blocks or choose_blocks(m, k, n, rp,
                                         packed_n=w2p, packed_r=w1p)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    total = _lr.hbm_bytes_moved(mp, kp, np_, rp, bm,
                                w1_packed=w1p, w2_packed=w2p)
    if lr.w1.packed and not w1p:
        total += k * r * 3 // 2     # fallback demotion: packed read + write
    if lr.w2.packed and not w2p:
        total += r * n * 3 // 2
    return total
