"""Dense quantized matmul Pallas kernel — TPU analog of the paper's baseline
MatMul engine (§V-A).

The paper's engine tiles (M_t, N_t) spatially with K_f-parallel dot products;
on TPU the MXU is the inner 128x128 tile and the BlockSpec factors
(bm, bk, bn) play the role of (M_t, K_f, N_t). The grid accumulates over the
K dimension in an int32 VMEM scratch (output-stationary, exactly like the
paper's output-stationary PE array).

Inputs are pre-quantized int8 codes with per-row activation scales and
per-column weight scales (symmetric, matching core/quant.py). Sub-8-bit
weights (W4/W6) arrive as int8 carriers whose values are range-limited; the
MXU computes int8xint8->int32 regardless (see DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.6 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(xq_ref, sx_ref, wq_ref, sw_ref, o_ref, acc_ref, *, k_blocks):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xq_ref[...], wq_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == k_blocks - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * sx_ref[...] * sw_ref[...]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret", "out_dtype")
)
def quant_matmul(
    xq: jax.Array,
    sx: jax.Array,
    wq: jax.Array,
    sw: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    bn: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Y[M,N] = (Xq·sx) @ (Wq·sw) with int8 MXU arithmetic.

    Shapes must be divisible by the block factors — `ops.py` handles padding.
    """
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2, (xq.shape, wq.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        (m, k, n), (bm, bk, bn))

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, k_blocks=k // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, sx, wq, sw)


def vmem_bytes(bm: int, bk: int, bn: int) -> int:
    """VMEM working set of one grid step (the BRAM analog, DESIGN.md §2)."""
    return (
        bm * bk            # x block int8
        + bk * bn          # w block int8
        + bm * 4           # sx
        + bn * 4           # sw
        + bm * bn * 4      # out f32
        + bm * bn * 4      # acc int32
    )
