"""Dense quantized matmul Pallas kernel — TPU analog of the paper's baseline
MatMul engine (§V-A).

The paper's engine tiles (M_t, N_t) spatially with K_f-parallel dot products;
on TPU the MXU is the inner 128x128 tile and the BlockSpec factors
(bm, bk, bn) play the role of (M_t, K_f, N_t). The grid accumulates over the
K dimension in an int32 VMEM scratch (output-stationary, exactly like the
paper's output-stationary PE array).

Inputs are pre-quantized int8 codes with per-row activation scales and
per-column weight scales (symmetric, matching core/quant.py). Sub-8-bit
weights arrive either as int8 carriers whose values are range-limited
(W6/W8) or — the paper's actual memory win — as *packed* W4 (two nibble
codes per byte along N, `w_packed=True`): the packed block is what DMAs
HBM→VMEM, and the kernel sign-extends the nibbles on-chip right before the
int8xint8->int32 MXU dot, so HBM moves wl/8 bytes per weight while the MXU
still sees int8. Unpacking is exact, so packed and carrier runs are
bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.6 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def unpack_int4_block(wp):
    """Sign-extend a packed-nibble int8 block (B, C) -> int8 codes (B, 2C).

    Shift arithmetic runs in int32 (Mosaic lowers sub-word shifts through
    32-bit lanes anyway, and interpret mode matches exactly): byte b holds
    code 2i in bits 3..0 and code 2i+1 in bits 7..4, the layout written by
    core.quant.pack_int4.
    """
    w32 = wp.astype(jnp.int32)
    lo = (w32 << 28) >> 28                      # sign-extended low nibble
    hi = (w32 << 24) >> 28                      # sign-extended high nibble
    out = jnp.stack([lo, hi], axis=-1).astype(jnp.int8)
    return out.reshape(*wp.shape[:-1], wp.shape[-1] * 2)


def _kernel(xq_ref, sx_ref, wq_ref, sw_ref, o_ref, acc_ref, *, k_blocks,
            w_packed):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wq = unpack_int4_block(wq_ref[...]) if w_packed else wq_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        xq_ref[...], wq,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == k_blocks - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * sx_ref[...] * sw_ref[...]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "interpret", "out_dtype", "w_packed"),
)
def quant_matmul(
    xq: jax.Array,
    sx: jax.Array,
    wq: jax.Array,
    sw: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    bn: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
    w_packed: bool = False,
) -> jax.Array:
    """Y[M,N] = (Xq·sx) @ (Wq·sw) with int8 MXU arithmetic.

    w_packed=True: wq is (K, N//2) packed nibbles (core.quant.pack_int4
    layout along N); the kernel unpacks in VMEM. bn must then be even with
    bn//2 lane-aligned — `ops.choose_blocks` keeps bn >= 256 for packed
    weights. Shapes must be divisible by the block factors — `ops.py`
    handles padding (zero bytes unpack to zero codes, so padding in the
    packed domain is exact).
    """
    m, k = xq.shape
    k2, nw = wq.shape
    n = nw * 2 if w_packed else nw
    assert k == k2, (xq.shape, wq.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        (m, k, n), (bm, bk, bn))
    # packed half-blocks must stay 128-lane aligned (choose_blocks keeps
    # bn >= 256; caller-supplied blocks are checked here, not trusted)
    assert not w_packed or bn % 256 == 0, (
        f"packed weights need bn % 256 == 0, got bn={bn}")
    bnw = bn // 2 if w_packed else bn

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, k_blocks=k // bk, w_packed=w_packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk, bnw), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, sx, wq, sw)


def vmem_bytes(bm: int, bk: int, bn: int, *, w_packed: bool = False) -> int:
    """VMEM working set of one grid step (the BRAM analog, DESIGN.md §2).

    A packed weight block halves its DMA footprint but adds a transient
    unpacked int8 copy for the MXU, so on-chip it costs 1.5x the carrier
    block — the packing win is HBM bandwidth, not VMEM.
    """
    w_blk = (bk * bn // 2 + bk * bn) if w_packed else bk * bn
    return (
        bm * bk            # x block int8
        + w_blk            # w block (packed DMA + unpacked temp, or carrier)
        + bm * 4           # sx
        + bn * 4           # sw
        + bm * bn * 4      # out f32
        + bm * bn * 4      # acc int32
    )


def hbm_bytes_moved(m: int, k: int, n: int, bm: int, bn: int,
                    *, w_packed: bool = False) -> int:
    """Modeled HBM traffic of one quant_matmul launch.

    Per the grid order (i, j, kk): each X block is re-fetched for every
    N block column, each W block for every M block row; scales ride along
    with the same reuse; the f32 output is written once. Only bm/bn set
    the reuse counts — bk is not a parameter because it changes nothing
    here. This is the number the bytes-moved benchmark column reports —
    the W term is what packing halves.
    """
    n_rep = max(n // bn, 1)
    m_rep = max(m // bm, 1)
    w_bytes = (k * n // 2) if w_packed else k * n
    return (
        m * k * n_rep              # Xq int8, once per N column
        + m * 4 * n_rep            # sx
        + w_bytes * m_rep          # W (packed or carrier), once per M row
        + n * 4 * m_rep            # sw
        + m * n * 4                # Y f32 out
    )
