"""Fused cascade low-rank quantized matmul — TPU analog of the paper's
*Cascade SVD MatMul Engine* (§V-B, Fig. 6 right).

Computes Y = ((Xq @ W1q) @ W2q) with the (bm x R) intermediate tile held in
VMEM for its whole lifetime — the paper's constraint that "the entire
M_t x R tile of intermediate results [is buffered] on-chip", which is the
source of the cascade engine's bandwidth advantage (no HBM round-trip for
X·W1).

Mechanically this is a two-phase sequential grid: for each M-row-block i the
inner grid axis s runs K/bk accumulation steps (phase 1: T += Xq_blk @ W1_blk)
followed by N/bn emission steps (phase 2: Y_blk = Tq @ W2_blk). The
intermediate is re-quantized to an int8 carrier once, at the phase boundary
— the paper's Ay intermediate quantization between the two engines, clamped
to qmax(act_wl) (`act_qmax`; 127 == the historical A8 behavior) — with the
per-R scales of W2 (s2) folded into T before requantization so phase 2 needs
only a per-row scale.

Sub-8-bit residency: both factors may arrive *packed* (two int4 nibbles per
byte along their last axis — W1 along R, W2 along N; core.quant.pack_int4
layout). The packed blocks are what DMA HBM→VMEM; the kernel sign-extends
on-chip right before each MXU dot. Unpacking is exact, so packed and
carrier runs are bit-identical.

dimension_semantics = ("parallel", "arbitrary"): M-blocks are independent;
the s axis is order-dependent (accumulate -> requant -> emit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant_matmul import unpack_int4_block

# jax >= 0.6 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(
    xq_ref, sx_ref, w1_ref, s1_ref, w2_ref, s2_ref,  # inputs
    o_ref,                                           # output
    tacc_ref, tq_ref, st_ref,                        # scratch
    *, k_blocks, n_blocks, w1_packed, w2_packed, act_qmax,
):
    s = pl.program_id(1)

    # ---- phase 1: accumulate T = Xq @ W1q over K blocks -------------------
    @pl.when(s == 0)
    def _init():
        tacc_ref[...] = jnp.zeros_like(tacc_ref)

    @pl.when(s < k_blocks)
    def _accum():
        w1 = unpack_int4_block(w1_ref[...]) if w1_packed else w1_ref[...]
        tacc_ref[...] += jax.lax.dot_general(
            xq_ref[...], w1,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    # ---- phase boundary: dequant, fold s2, requantize per row to int8 -----
    @pl.when(s == k_blocks)
    def _requant():
        t = tacc_ref[...].astype(jnp.float32)
        t = t * sx_ref[...] * s1_ref[...] * s2_ref[...].reshape(1, -1)
        absmax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
        st = jnp.where(absmax > 0, absmax / act_qmax, 1.0)
        tq_ref[...] = jnp.clip(jnp.round(t / st),
                               -act_qmax, act_qmax).astype(jnp.int8)
        st_ref[...] = st.astype(jnp.float32)

    # ---- phase 2: emit Y n-block = Tq @ W2q ------------------------------
    @pl.when(s >= k_blocks)
    def _emit():
        w2 = unpack_int4_block(w2_ref[...]) if w2_packed else w2_ref[...]
        acc = jax.lax.dot_general(
            tq_ref[...], w2,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        o_ref[...] = (acc.astype(jnp.float32) * st_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "interpret", "out_dtype",
                     "w1_packed", "w2_packed", "act_qmax"),
)
def lowrank_qmm(
    xq: jax.Array,
    sx: jax.Array,
    w1q: jax.Array,
    s1: jax.Array,
    w2q: jax.Array,
    s2: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    bn: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
    w1_packed: bool = False,
    w2_packed: bool = False,
    act_qmax: int = 127,
) -> jax.Array:
    """Y[M,N] = dequant-cascade((Xq @ W1q) @ W2q).

    xq: (M, K) int8, sx: (M, 1) f32      — quantized activations
    w1q: (K, R) int8, s1: (1, R) f32     — ITERA factor 1 (R kept whole in VMEM)
    w2q: (R, N) int8, s2: (R, 1) f32     — ITERA factor 2
    w1_packed / w2_packed: the factor array carries packed W4 nibbles along
    its last axis (R resp. N) — shapes become (K, R//2) / (R, N//2); scales
    stay unpacked. act_qmax: clamp of the phase-boundary requant,
    qmax(act_wl).
    Dims must divide by blocks; R is not tiled (ranks are ≤ ~1k by design —
    that is the whole point of the decomposition).
    """
    m, k = xq.shape
    k2, r1 = w1q.shape
    r = r1 * 2 if w1_packed else r1
    r2, nw = w2q.shape
    n = nw * 2 if w2_packed else nw
    assert k == k2 and r == r2, (xq.shape, w1q.shape, w2q.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        (m, k, n), (bm, bk, bn))
    # packed half-blocks must stay 128-lane aligned: W2's N half-block
    # (ops keeps bn >= 256) and W1's untiled R half-width (ops pads R to
    # a multiple of 256 when W1 is packed)
    assert not w2_packed or bn % 256 == 0, (
        f"packed W2 needs bn % 256 == 0, got bn={bn}")
    assert not w1_packed or r % 256 == 0, (
        f"packed W1 needs padded R % 256 == 0, got R={r}")
    bnw = bn // 2 if w2_packed else bn

    k_blocks, n_blocks = k // bk, n // bn
    grid = (m // bm, k_blocks + n_blocks)

    def nmap(i, s):
        # during phase 1 park on block 0; phase 2 walks the N blocks
        return jnp.maximum(s - k_blocks, 0)

    return pl.pallas_call(
        functools.partial(_kernel, k_blocks=k_blocks, n_blocks=n_blocks,
                          w1_packed=w1_packed, w2_packed=w2_packed,
                          act_qmax=act_qmax),
        grid=grid,
        in_specs=[
            # phase-1 operands: clamp to the last K block during phase 2
            pl.BlockSpec((bm, bk),
                         lambda i, s: (i, jnp.minimum(s, k_blocks - 1))),
            pl.BlockSpec((bm, 1), lambda i, s: (i, 0)),
            pl.BlockSpec((bk, r1),
                         lambda i, s: (jnp.minimum(s, k_blocks - 1), 0)),
            pl.BlockSpec((1, r), lambda i, s: (0, 0)),
            # phase-2 operands: park on block 0 during phase 1
            pl.BlockSpec((r, bnw), lambda i, s: (0, nmap(i, s))),
            pl.BlockSpec((r, 1), lambda i, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, s: (i, nmap(i, s))),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, r), jnp.int32),   # T accumulator
            pltpu.VMEM((bm, r), jnp.int8),    # requantized T
            pltpu.VMEM((bm, 1), jnp.float32), # per-row T scale
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, sx, w1q, s1, w2q, s2)


def vmem_bytes(bm: int, bk: int, bn: int, r: int, *,
               w1_packed: bool = False, w2_packed: bool = False) -> int:
    """VMEM working set of one grid step (constraint for the DSE). Packed
    factor blocks DMA half the bytes but add a transient unpacked int8
    copy for the MXU (1.5x the carrier block on-chip — packing buys HBM
    bandwidth, not VMEM)."""
    w1_blk = (bk * r // 2 + bk * r) if w1_packed else bk * r
    w2_blk = (r * bn // 2 + r * bn) if w2_packed else r * bn
    return (
        bm * bk          # x block int8
        + w1_blk         # w1 block (packed DMA + unpacked temp, or carrier)
        + w2_blk         # w2 block
        + bm * r * 4     # T accumulator int32
        + bm * r         # Tq int8
        + bm * 4 * 2     # sx, st
        + r * 4 * 2      # s1, s2
        + bm * bn * 4    # out f32
    )


def hbm_bytes_moved(m: int, k: int, n: int, r: int, bm: int, *,
                    w1_packed: bool = False, w2_packed: bool = False) -> int:
    """Modeled HBM traffic of one fused cascade launch.

    Only the M row-blocking matters: X streams once (consecutive phase-2
    steps revisit the same X block, which stays resident); both factors
    are re-fetched per M row-block; the (bm x R) intermediate never
    leaves VMEM — the cascade's defining property; the f32 output is
    written once. bk/bn change nothing here, so they are not parameters.
    """
    m_rep = max(m // bm, 1)
    w1_bytes = (k * r // 2) if w1_packed else k * r
    w2_bytes = (r * n // 2) if w2_packed else r * n
    return (
        m * k                      # Xq int8, once
        + m * 4                    # sx
        + (w1_bytes + w2_bytes) * m_rep   # factors, once per M row
        + (r + r) * 4 * m_rep      # s1, s2
        + m * n * 4                # Y f32 out
    )
