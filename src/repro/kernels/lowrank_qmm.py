"""Fused cascade low-rank quantized matmul — TPU analog of the paper's
*Cascade SVD MatMul Engine* (§V-B, Fig. 6 right).

Computes Y = ((Xq @ W1q) @ W2q) with the (bm x R) intermediate tile held in
VMEM for its whole lifetime — the paper's constraint that "the entire
M_t x R tile of intermediate results [is buffered] on-chip", which is the
source of the cascade engine's bandwidth advantage (no HBM round-trip for
X·W1).

Mechanically this is a two-phase sequential grid: for each M-row-block i the
inner grid axis s runs K/bk accumulation steps (phase 1: T += Xq_blk @ W1_blk)
followed by N/bn emission steps (phase 2: Y_blk = Tq @ W2_blk). The
intermediate is re-quantized to int8 once, at the phase boundary — exactly
the paper's A8 intermediate quantization between the two engines — with the
per-R scales of W2 (s2) folded into T before requantization so phase 2 needs
only a per-row scale.

dimension_semantics = ("parallel", "arbitrary"): M-blocks are independent;
the s axis is order-dependent (accumulate -> requant -> emit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.6 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(
    xq_ref, sx_ref, w1_ref, s1_ref, w2_ref, s2_ref,  # inputs
    o_ref,                                           # output
    tacc_ref, tq_ref, st_ref,                        # scratch
    *, k_blocks, n_blocks,
):
    s = pl.program_id(1)

    # ---- phase 1: accumulate T = Xq @ W1q over K blocks -------------------
    @pl.when(s == 0)
    def _init():
        tacc_ref[...] = jnp.zeros_like(tacc_ref)

    @pl.when(s < k_blocks)
    def _accum():
        tacc_ref[...] += jax.lax.dot_general(
            xq_ref[...], w1_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    # ---- phase boundary: dequant, fold s2, requantize per row to int8 -----
    @pl.when(s == k_blocks)
    def _requant():
        t = tacc_ref[...].astype(jnp.float32)
        t = t * sx_ref[...] * s1_ref[...] * s2_ref[...].reshape(1, -1)
        absmax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
        st = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        tq_ref[...] = jnp.clip(jnp.round(t / st), -127, 127).astype(jnp.int8)
        st_ref[...] = st.astype(jnp.float32)

    # ---- phase 2: emit Y n-block = Tq @ W2q ------------------------------
    @pl.when(s >= k_blocks)
    def _emit():
        acc = jax.lax.dot_general(
            tq_ref[...], w2_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        o_ref[...] = (acc.astype(jnp.float32) * st_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret", "out_dtype")
)
def lowrank_qmm(
    xq: jax.Array,
    sx: jax.Array,
    w1q: jax.Array,
    s1: jax.Array,
    w2q: jax.Array,
    s2: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    bn: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Y[M,N] = dequant-cascade((Xq @ W1q) @ W2q).

    xq: (M, K) int8, sx: (M, 1) f32      — quantized activations
    w1q: (K, R) int8, s1: (1, R) f32     — ITERA factor 1 (R kept whole in VMEM)
    w2q: (R, N) int8, s2: (R, 1) f32     — ITERA factor 2
    Dims must divide by blocks; R is not tiled (ranks are ≤ ~1k by design —
    that is the whole point of the decomposition).
    """
    m, k = xq.shape
    k2, r = w1q.shape
    r2, n = w2q.shape
    assert k == k2 and r == r2, (xq.shape, w1q.shape, w2q.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        (m, k, n), (bm, bk, bn))

    k_blocks, n_blocks = k // bk, n // bn
    grid = (m // bm, k_blocks + n_blocks)

    def nmap(i, s):
        # during phase 1 park on block 0; phase 2 walks the N blocks
        return jnp.maximum(s - k_blocks, 0)

    return pl.pallas_call(
        functools.partial(_kernel, k_blocks=k_blocks, n_blocks=n_blocks),
        grid=grid,
        in_specs=[
            # phase-1 operands: clamp to the last K block during phase 2
            pl.BlockSpec((bm, bk),
                         lambda i, s: (i, jnp.minimum(s, k_blocks - 1))),
            pl.BlockSpec((bm, 1), lambda i, s: (i, 0)),
            pl.BlockSpec((bk, r),
                         lambda i, s: (jnp.minimum(s, k_blocks - 1), 0)),
            pl.BlockSpec((1, r), lambda i, s: (0, 0)),
            # phase-2 operands: park on block 0 during phase 1
            pl.BlockSpec((r, bn), lambda i, s: (0, nmap(i, s))),
            pl.BlockSpec((r, 1), lambda i, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, s: (i, nmap(i, s))),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, r), jnp.int32),   # T accumulator
            pltpu.VMEM((bm, r), jnp.int8),    # requantized T
            pltpu.VMEM((bm, 1), jnp.float32), # per-row T scale
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, sx, w1q, s1, w2q, s2)


def vmem_bytes(bm: int, bk: int, bn: int, r: int) -> int:
    """VMEM working set of one grid step (constraint for the DSE)."""
    return (
        bm * bk          # x block int8
        + bk * r         # w1 block int8
        + r * bn         # w2 block int8
        + bm * r * 4     # T accumulator int32
        + bm * r         # Tq int8
        + bm * 4 * 2     # sx, st
        + r * 4 * 2      # s1, s2
        + bm * bn * 4    # out f32
    )
