"""FlashAttention-style Pallas paged-attention kernel for the unified
serving step — the KV-cache counterpart of the packed-W4 discipline in
`quant_matmul.py`: stream only the bytes that hold real data, and do any
sub-8-bit decoding on-chip, right before the MXU dot.

The jnp serving path (`models.attention.span_attention_paged`, kept as the
selectable oracle) gathers the ENTIRE logical pool view
`pool["k"][block_table] -> (B, MB*bs, Hk, Dh)` every step, every layer:
O(max-context) HBM traffic and a full dense materialization regardless of
how much context each row actually holds — and with int8 KV it dequantizes
that whole window in jnp before the dot. This kernel instead:

  * runs a `(B, Hk, MB)` grid — one program per (row, kv-head, table slot)
    — with the block table, `ctx_lens`, `q_lens`, and the per-row
    valid-block counts (`runtime.kvblocks.valid_block_counts`) scalar-
    prefetched into SMEM, so the BlockSpec index maps can chase the table;
  * walks the block table and fetches ONLY blocks that hold valid context:
    grid step j DMAs physical block `block_table[r, min(j, nb[r]-1)]`, so
    every step past a row's valid count re-addresses the block already
    resident in VMEM — the Pallas pipeline skips the re-fetch. Trash-
    block-0 padding entries past a row's valid count are never addressed
    (pads sit at `j >= nb`); idle rows (`q_lens == 0`, `nb == 0`) clamp
    onto `block_table[r, 0]` — the trash block — so they fetch that one
    block and compute nothing (`stream_hbm_bytes` charges exactly that);
  * computes online softmax over (W-span queries x block keys) with the
    in-span causal mask `slot <= ctx_lens[r] + i` fused into the score
    tile (key position `j*bs + col` vs query position `ctx + row // G`),
    in f32 running (m, l, acc) VMEM scratch;
  * dequantizes int8 K/V tiles in VMEM right before the dot — the scale
    planes DMA alongside the codes, and the `code.astype(q.dtype) *
    scale.astype(q.dtype)` order mirrors the jnp oracle exactly — so int8
    KV streams 1 byte/element + a thin scale plane instead of a dense
    dequantized bf16 window;
  * accumulates the output per (row, head) without ever materializing the
    `(B, MB*bs, Hk, Dh)` gather.

GQA runs grouped: the G query heads of one kv head are flattened into the
query-row axis `(W*G, Dh)`, so K/V tiles are fetched once per kv head, not
per query head.

Like the matmul kernels, this runs compiled on TPU and bit-faithfully
under `interpret=True` on CPU (how the identity tests drive it).

Tensor parallelism: the kernel needs no TP awareness. Under the
shard_map serving step (api.engine with a "model"-axis mesh) it is
invoked per shard with the PER-SHARD config — `Hk` here is
num_kv_heads / tp and the pool ref is that shard's head-slice
(runtime.kvblocks.pool_pspecs), so the grid is (B, Hk/tp, MB) and each
chip streams only its own heads' KV blocks. Attention is head-local,
so no collective touches the kernel; the single psum per attention
boundary happens outside, after the wo projection
(models.transformer.unified_step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.6 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG = -2.3819763e38  # large negative for masking in f32 (models.attention)


def _softcap(s, cap: float):
    return (cap * jnp.tanh(s / cap)) if cap > 0 else s


def _kernel(nb_ref, bt_ref, ctx_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bs, g, scale, cap,
            ks_ref=None, vs_ref=None):
    r, j = pl.program_id(0), pl.program_id(2)
    nb = nb_ref[r]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < nb)
    def _block():
        q = q_ref[0, 0]                                   # (WG, Dh)
        k = k_ref[0, :, 0, :]                             # (bs, Dh)
        v = v_ref[0, :, 0, :]
        if ks_ref is not None:
            # in-VMEM dequant right before the dot, mirroring the oracle's
            # `codes.astype(q.dtype) * scales.astype(q.dtype)` order
            k = k.astype(q.dtype) * ks_ref[0, :, 0, :].astype(q.dtype)
            v = v.astype(q.dtype) * vs_ref[0, :, 0, :].astype(q.dtype)
        s = jax.lax.dot_general(                          # (WG, bs) f32
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale
        s = _softcap(s, cap)
        wg = s.shape[0]
        # fused in-span causal mask: key slot j*bs+col visible to query row
        # `row` (kv-head-grouped, q position row // G) iff slot <= ctx + pos
        qpos = ctx_ref[r] + jax.lax.broadcasted_iota(jnp.int32, (wg, bs), 0) // g
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (wg, bs), 1)
        s = jnp.where(kpos <= qpos, s, NEG)
        # online softmax update in f32
        m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(j == jnp.maximum(nb - 1, 0))
    def _finish():
        # idle rows (nb == 0) never accumulated: l == 0 -> emit zeros, the
        # caller discards them (same contract as the oracle's garbage rows)
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("logit_softcap", "interpret"))
def paged_attention(q, pool, block_table, ctx_lens, q_lens, *,
                    logit_softcap: float = 0.0,
                    interpret: bool = False):
    """Span queries against a blocked KV pool, streaming only valid blocks.

    q: (B, W, H, Dh) post-RoPE queries (row r valid in [:q_lens[r]]);
    pool: ONE layer's blocks {"k","v"[,"ks","vs"]} with leaves
    (NB, bs, Hk, *) — already holding this step's scattered span K/V;
    block_table: (B, MB) int32; ctx_lens / q_lens: (B,) int32.

    Returns (B, W, H, Dh) in q.dtype: attention output at every span
    position, numerically matching the jnp gather oracle
    (`span_attention_paged(..., impl="ref")`) on the valid region
    [:q_lens[r]] of every active row. Rows with q_lens == 0 return zeros.
    """
    b, w, h, dh = q.shape
    _, bs, hk, _ = pool["k"].shape
    mb = block_table.shape[1]
    g = h // hk
    wg = w * g
    quant = "ks" in pool

    from repro.runtime.kvblocks import valid_block_counts

    nb = valid_block_counts(ctx_lens, q_lens, bs, mb)
    # group queries by kv head: (B, Hk, W*G, Dh) — W major, G minor, so
    # flattened row i sits at query position i // G
    qh = (q.reshape(b, w, hk, g, dh).transpose(0, 2, 1, 3, 4)
          .reshape(b, hk, wg, dh))
    bt = block_table.astype(jnp.int32)

    def q_map(r, h_, j, nb_, bt_, ctx_):
        return (r, h_, 0, 0)

    def kv_map(r, h_, j, nb_, bt_, ctx_):
        # clamp past-the-end steps onto the last valid block: the index
        # map returns the same physical block as the previous step, so the
        # pipeline skips the DMA — only valid context ever streams
        jj = jnp.maximum(jnp.minimum(j, nb_[r] - 1), 0)
        return (bt_[r, jj], 0, h_, 0)

    kv_specs = [
        pl.BlockSpec((1, bs, 1, dh), kv_map),
        pl.BlockSpec((1, bs, 1, dh), kv_map),
    ]
    operands = [qh, pool["k"], pool["v"]]
    if quant:
        kv_specs += [pl.BlockSpec((1, bs, 1, 1), kv_map),
                     pl.BlockSpec((1, bs, 1, 1), kv_map)]
        operands += [pool["ks"], pool["vs"]]

    def kernel(*refs):
        if quant:
            nb_r, bt_r, ctx_r, q_r, k_r, v_r, ks_r, vs_r, o_r, m_r, l_r, a_r = refs
            _kernel(nb_r, bt_r, ctx_r, q_r, k_r, v_r, o_r, m_r, l_r, a_r,
                    bs=bs, g=g, scale=dh ** -0.5, cap=logit_softcap,
                    ks_ref=ks_r, vs_ref=vs_r)
        else:
            nb_r, bt_r, ctx_r, q_r, k_r, v_r, o_r, m_r, l_r, a_r = refs
            _kernel(nb_r, bt_r, ctx_r, q_r, k_r, v_r, o_r, m_r, l_r, a_r,
                    bs=bs, g=g, scale=dh ** -0.5, cap=logit_softcap)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,          # nb, block_table, ctx_lens
        grid=(b, hk, mb),
        in_specs=[pl.BlockSpec((1, 1, wg, dh), q_map)] + kv_specs,
        out_specs=pl.BlockSpec((1, 1, wg, dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((wg, 1), jnp.float32),    # running max m
            pltpu.VMEM((wg, 1), jnp.float32),    # running denom l
            pltpu.VMEM((wg, dh), jnp.float32),   # running numerator acc
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, wg, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(nb, bt, ctx_lens.astype(jnp.int32), *operands)
    return (out.reshape(b, hk, w, g, dh).transpose(0, 2, 1, 3, 4)
            .reshape(b, w, h, dh))


# ------------------------------------------------------------- byte model --
def kv_bytes_per_token(hk: int, dh: int, kv_bits: int) -> float:
    """HBM bytes one cached token position occupies across K and V: int8
    codes + per-(token, head) f32 scale planes at kv_bits == 8, else the
    model dtype (bf16/f32 treated as 2 B — the bandwidth-relevant case)."""
    if kv_bits == 8:
        return 2 * (hk * dh + hk * 4)
    return 2 * hk * dh * 2


def stream_hbm_bytes(ctx_lens, q_lens, block_size: int, hk: int, dh: int,
                     *, kv_bits: int = 16, n_q_heads: int | None = None
                     ) -> int:
    """Modeled HBM traffic of one paged_attention launch: each row streams
    ceil((ctx+q)/bs) KV blocks ONCE (idle q_lens == 0 rows stream just
    the single trash block their clamped index map lands on), plus the q
    tile in and the output tile back. This is the O(ctx) term the kernel
    converts serving attention to — compare `gather_hbm_bytes` for what
    the jnp path moves."""
    h = n_q_heads or hk
    per_tok = kv_bytes_per_token(hk, dh, kv_bits)
    total = 0
    for ctx, ql in zip(ctx_lens, q_lens):
        nb = 1 if ql <= 0 else -(-(int(ctx) + int(ql)) // block_size)
        total += nb * block_size * per_tok
    w = max((int(x) for x in q_lens), default=0)
    io = 2 * len(list(ctx_lens)) * w * h * dh * 2     # q in + o out (bf16)
    return int(total + io)


def gather_hbm_bytes(batch: int, max_blocks: int, block_size: int, hk: int,
                     dh: int, *, kv_bits: int = 16, w: int = 1,
                     n_q_heads: int | None = None) -> int:
    """Modeled HBM traffic of the jnp gather oracle: every row reads its
    FULL (MB*bs) logical pool view — valid or not — and the int8 case
    additionally writes + re-reads the dense dequantized view at compute
    dtype. Independent of ctx_lens: the term the kernel deletes."""
    h = n_q_heads or hk
    slots = batch * max_blocks * block_size
    total = slots * kv_bytes_per_token(hk, dh, kv_bits)
    if kv_bits == 8:
        # materialized dequantized (B, MB*bs, Hk, Dh) K and V views at
        # compute dtype: written once, read once by the einsum
        total += 2 * slots * hk * dh * 2 * 2
    io = 2 * batch * w * h * dh * 2
    return int(total + io)
