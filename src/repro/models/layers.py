"""Shared building blocks: norms, MLP flavors, RoPE, linear dispatch.

`apply_linear` is the single matmul entry point for the whole zoo — it
dispatches on the weight node type, so a model runs dense (Array),
quantized (QuantizedTensor) or ITERA low-rank (LowRankQ) without any model
code changes. Kernel usage is controlled by `repro.models.linear_mode`:

  "auto"     — Pallas kernels on TPU, jnp reference math elsewhere
  "kernel"   — force Pallas (interpret=True off-TPU; used by kernel tests)
  "ref"      — force the pure-jnp path (used inside dry-runs: identical
               numerics, SPMD-friendly HLO)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.itera import LowRankQ
from repro.core.quant import QuantizedTensor
from repro.kernels import ops as kops

_LINEAR_MODE = "auto"


def set_linear_mode(mode: str) -> None:
    global _LINEAR_MODE
    assert mode in ("auto", "kernel", "ref")
    _LINEAR_MODE = mode


def get_linear_mode() -> str:
    return _LINEAR_MODE


def apply_linear(x: jax.Array, w, out_dtype=None, *,
                 reduce_tp: bool = False) -> jax.Array:
    """y = x @ w for w: Array | QuantizedTensor | LowRankQ.

    reduce_tp marks the tensor-parallel REDUCTION sites (wo, mlp down):
    under shard_map serving their input features are row-split across
    shards, so the local product is a partial sum. With a TP axis bound
    (runtime.shardctx.tp_axis) this computes the partial in f32, psums
    it, and casts ONCE after the reduce — the same single rounding the
    unsharded dot performs on its f32 accumulator, which is what keeps
    bf16 TP serving token-identical to the single-device engine (bf16
    partials rounded before the psum would drift). With no TP axis
    bound (every non-serving path, single-device serving) the flag is
    inert and this is the plain dispatch below.
    """
    out_dtype = out_dtype or x.dtype
    if reduce_tp:
        from repro.runtime import shardctx

        if shardctx.get_tp_axis() is not None:
            if isinstance(w, (LowRankQ, QuantizedTensor)):
                # compressed K-sites requantize activations over LOCAL
                # features — numerically close, not bit-equal (see
                # launch.sharding); still reduce in f32.
                y = apply_linear(x, w, out_dtype=jnp.float32)
            else:
                y = jnp.matmul(x, w.astype(x.dtype),
                               preferred_element_type=jnp.float32)
            return shardctx.psum_tp(y).astype(out_dtype)
    if isinstance(w, LowRankQ):
        if _LINEAR_MODE == "ref" or (_LINEAR_MODE == "auto" and not kops.on_tpu()):
            return kops.lrmm(x, w, use_kernel=False, out_dtype=out_dtype)
        return kops.lrmm(x, w, use_kernel=True, out_dtype=out_dtype)
    if isinstance(w, QuantizedTensor):
        if _LINEAR_MODE == "ref" or (_LINEAR_MODE == "auto" and not kops.on_tpu()):
            return kops.qmm(x, w, use_kernel=False, out_dtype=out_dtype)
        return kops.qmm(x, w, use_kernel=True, out_dtype=out_dtype)
    return jnp.asarray(x @ w.astype(x.dtype), out_dtype)


def weight_shape(w) -> tuple:
    """(K, N) of a linear node regardless of representation — the LOGICAL
    shape (QuantizedTensor.shape unpacks the halved last dim of
    packed-nibble W4 storage)."""
    if isinstance(w, LowRankQ):
        return (w.w1.shape[0], w.w2.shape[1])
    if isinstance(w, QuantizedTensor):
        return tuple(w.shape)
    return tuple(w.shape)


# ----------------------------------------------------------------- norms --
def rmsnorm(x, gamma, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * (
        1.0 + gamma.astype(x.dtype)
    )


def layernorm(x, gamma, beta, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * gamma.astype(x.dtype) + beta.astype(x.dtype)


def apply_norm(x, p, kind: str, eps: float):
    if kind == "layernorm":
        return layernorm(x, p["gamma"], p["beta"], eps)
    return rmsnorm(x, p["gamma"], eps)


def init_norm(kind: str, d: int, dtype):
    if kind == "layernorm":
        return {"gamma": jnp.ones((d,), dtype), "beta": jnp.zeros((d,), dtype)}
    return {"gamma": jnp.zeros((d,), dtype)}   # rmsnorm stores gamma-1


# ------------------------------------------------------------------ MLPs --
def mlp_apply(x, p, act: str):
    if act in ("swiglu", "geglu"):
        g = apply_linear(x, p["gate"])
        u = apply_linear(x, p["up"])
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    elif act == "relu2":  # squared ReLU (Nemotron-4)
        h = jnp.square(jax.nn.relu(apply_linear(x, p["up"])))
    else:  # gelu
        h = jax.nn.gelu(apply_linear(x, p["up"]))
    return apply_linear(h, p["down"], reduce_tp=True)


def mlp_init(key, d: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    std_in, std_out = d ** -0.5, d_ff ** -0.5
    p = {
        "up": jax.random.normal(ks[0], (d, d_ff), dtype) * std_in,
        "down": jax.random.normal(ks[1], (d_ff, d), dtype) * std_out,
    }
    if act in ("swiglu", "geglu"):
        p["gate"] = jax.random.normal(ks[2], (d, d_ff), dtype) * std_in
    return p


# ------------------------------------------------------------------ RoPE --
def rope_freqs(head_dim: int, theta: float, rotary_pct: float = 1.0):
    rot = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, rotary_pct: float = 1.0):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, theta, rotary_pct)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


def sinusoidal_emb(positions, d_model: int, dtype):
    half = d_model // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def softcap(x, cap: float):
    return (cap * jnp.tanh(x / cap)) if cap > 0 else x
