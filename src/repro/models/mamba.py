"""Mamba blocks: Mamba1 selective scan (falcon-mamba) and a multi-head
Mamba2-style SSD block (zamba2). Both provide

  * mambaN_apply  — full-sequence form for training / prefill, with two scan
    engines: "sequential" (lax.scan over time; tiny memory) and "chunked"
    (intra-chunk associative scan + inter-chunk carry; the TPU-friendly
    parallel form — a perf option exercised in §Perf);
  * mambaN_step   — O(1) single-token decode carrying (ssm state, conv tail),
    which is what makes the long_500k cells sub-quadratic.

Simplifications vs the reference CUDA implementations (DESIGN.md §2): the
short causal conv is applied to x only (Mamba2 also convolves B/C), and
Mamba2 uses a single B/C group. Neither changes the systems behaviour
(state shapes, FLOPs structure, scan data flow).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_linear

Array = jax.Array


# ----------------------------------------------------------------- common --
def _causal_conv(x: Array, w: Array, tail: Array | None = None):
    """Depthwise causal conv. x: (B, S, C), w: (C, K). Returns (y, new_tail)."""
    k = w.shape[1]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    # sum of K shifted views: y[t] = sum_j w[:, j] * xp[t + j]
    y = sum(xp[:, j:j + x.shape[1], :] * w[:, j][None, None, :]
            for j in range(k))
    return y, xp[:, -(k - 1):, :] if k > 1 else tail


def _ssm_scan(make_ab, emit, xs, h0: Array, engine: str, chunk: int,
              seq_len: int):
    """h_t = dA_t * h_{t-1} + dBx_t along time.

    `make_ab(slice_of_xs) -> (dA, dBx)` builds the transition terms *inside*
    the scan body, so the (B, S, d_inner, d_state)-sized tensors are never
    materialized for the full sequence — only one step (sequential) or one
    chunk (chunked) exists at a time. This is what keeps the 4k-train SSM
    cells inside HBM (EXPERIMENTS.md §Perf: 805 GiB -> per-chunk).

    xs: pytree of (B, S, ...) per-step inputs.
    `emit(h, x) -> y` contracts the state against C *inside* the body (the
    (…, d_inner, d_state) hidden states are never stacked over time).
    Returns (ys (B, S, ...), hT).
    """
    if engine == "sequential":
        def step(h, x_t):
            a, b = make_ab(x_t)
            h = a * h + b
            return h, emit(h, x_t)

        xs_t = jax.tree_util.tree_map(lambda x: x.swapaxes(0, 1), xs)
        hT, ys = jax.lax.scan(step, h0, xs_t)
        return ys.swapaxes(0, 1), hT

    # chunked: associative scan inside fixed-size chunks, carry across them
    q = min(chunk, seq_len)
    while seq_len % q:
        q -= 1
    nc = seq_len // q

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, bl * ar + br

    xs_c = jax.tree_util.tree_map(
        lambda x: x.reshape(x.shape[0], nc, q, *x.shape[2:]).swapaxes(0, 1),
        xs)

    @jax.checkpoint
    def chunk_step(h, x_c):
        # checkpointed: the backward pass recomputes the intra-chunk
        # associative scan instead of saving its (B, Q, d_inner, d_state)
        # internals — the standard chunked-SSD memory/compute trade.
        a_c, b_c = make_ab(x_c)                         # (B, Q, ...)
        cumA, hin = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
        h_all = hin + cumA * h[:, None]
        return h_all[:, -1], emit(h_all, x_c)

    hT, ys = jax.lax.scan(chunk_step, h0, xs_c)
    ys = ys.swapaxes(0, 1)
    return ys.reshape(ys.shape[0], seq_len, *ys.shape[3:]), hT


# ----------------------------------------------------------------- mamba1 --
def mamba1_init(key, cfg, dtype):
    d = cfg.d_model
    c = cfg.ssm
    di = d * c.expand
    dtr = c.dt_rank or d // 16
    ks = jax.random.split(key, 6)
    # dt_in/bc_proj are the two halves of the reference x_proj, split so
    # each output dim shards cleanly (DESIGN.md §4).
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (di, c.d_conv), dtype) * 0.2,
        "dt_in": jax.random.normal(ks[2], (di, dtr), dtype) * di ** -0.5,
        "bc_proj": jax.random.normal(ks[5], (di, 2 * c.d_state), dtype)
        * di ** -0.5,
        "dt_proj": jax.random.normal(ks[3], (dtr, di), dtype) * dtr ** -0.5,
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, c.d_state + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d), dtype) * di ** -0.5,
    }


def _mamba1_core(p, x, z, cfg, h0, engine):
    """x, z: (B, S, Di) post-conv and gate. Returns (y, hT)."""
    c = cfg.ssm
    dt = apply_linear(x, p["dt_in"], out_dtype=jnp.float32)
    bc = apply_linear(x, p["bc_proj"], out_dtype=jnp.float32)
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(apply_linear(dt, p["dt_proj"],
                                      out_dtype=jnp.float32)
                         + p["dt_bias"])                       # (B,S,Di)
    a = -jnp.exp(p["A_log"])                                   # (Di, N)
    xf = x.astype(jnp.float32)

    def make_ab(xs):
        # works on per-step (B, Di)/(B, N) and per-chunk (B, Q, ...) slices
        dA = jnp.exp(xs["dt"][..., None] * a)                  # (...,Di,N)
        dBx = (xs["dt"] * xs["x"])[..., None] * xs["b"][..., None, :]
        return dA, dBx

    def emit(h, xs):
        return jnp.einsum("...dn,...n->...d", h, xs["c"])

    ys, hT = _ssm_scan(make_ab, emit,
                       {"dt": dt, "x": xf, "b": bmat, "c": cmat},
                       h0, engine, c.chunk, x.shape[1])
    y = ys + p["D"] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return apply_linear(y, p["out_proj"]), hT


def mamba1_apply(p, xin, cfg, *, engine="sequential"):
    b = xin.shape[0]
    di = cfg.d_model * cfg.ssm.expand
    xz = apply_linear(xin, p["in_proj"])
    x, z = jnp.split(xz, 2, axis=-1)
    x, _ = _causal_conv(x, p["conv_w"])
    x = jax.nn.silu(x)
    h0 = jnp.zeros((b, di, cfg.ssm.d_state), jnp.float32)
    y, _ = _mamba1_core(p, x, z, cfg, h0, engine)
    return y


def mamba1_prefill(p, xin, cfg, *, engine="sequential"):
    """Full-sequence pass that also returns the decode cache."""
    b = xin.shape[0]
    di = cfg.d_model * cfg.ssm.expand
    xz = apply_linear(xin, p["in_proj"])
    x, z = jnp.split(xz, 2, axis=-1)
    xc, tail = _causal_conv(x, p["conv_w"])
    xc = jax.nn.silu(xc)
    h0 = jnp.zeros((b, di, cfg.ssm.d_state), jnp.float32)
    y, hT = _mamba1_core(p, xc, z, cfg, h0, engine)
    return y, {"h": hT, "conv": tail}


def mamba1_init_cache(cfg, batch, dtype):
    di = cfg.d_model * cfg.ssm.expand
    return {
        "h": jnp.zeros((batch, di, cfg.ssm.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype),
    }


def mamba1_step(p, x1, cache, cfg):
    """Single-token decode. x1: (B, 1, D)."""
    xz = apply_linear(x1, p["in_proj"])
    x, z = jnp.split(xz, 2, axis=-1)
    x, tail = _causal_conv(x, p["conv_w"], cache["conv"])
    x = jax.nn.silu(x)
    y, hT = _mamba1_core(p, x, z, cfg, cache["h"], "sequential")
    return y, {"h": hT, "conv": tail}


# ----------------------------------------------------------------- mamba2 --
def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    c = cfg.ssm
    di = d * c.expand
    nh = di // c.head_dim
    ks = jax.random.split(key, 5)
    # zx_proj / bc_in / dt_lin are the reference in_proj split by output
    # segment so each dim shards cleanly (DESIGN.md §4).
    return {
        "zx_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * d ** -0.5,
        "bc_in": jax.random.normal(ks[3], (d, 2 * c.d_state), dtype)
        * d ** -0.5,
        "dt_lin": jax.random.normal(ks[4], (d, nh), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (di, c.d_conv), dtype) * 0.2,
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d), dtype) * di ** -0.5,
    }


def _m2_split(p, xin, cfg):
    c = cfg.ssm
    di = cfg.d_model * c.expand
    nh = di // c.head_dim
    zx = apply_linear(xin, p["zx_proj"])
    z, x = jnp.split(zx, 2, axis=-1)
    bc = apply_linear(xin, p["bc_in"])
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt = apply_linear(xin, p["dt_lin"], out_dtype=jnp.float32)
    return z, x, bmat, cmat, dt, nh


def _m2_core(p, x, z, bmat, cmat, dt, cfg, h0, engine, nh):
    c = cfg.ssm
    b, s = x.shape[:2]
    hd = c.head_dim
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])                                     # (H,)
    xh = x.astype(jnp.float32).reshape(b, s, nh, hd)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)

    def make_ab(xs):
        dA = jnp.exp(xs["dt"] * a)[..., None, None]     # (...,H,1,1)
        dBx = (xs["dt"][..., None] * xs["x"])[..., None] * \
            xs["b"][..., None, None, :]                 # (...,H,hd,N)
        return dA, dBx

    def emit(h, xs):
        return jnp.einsum("...hdn,...n->...hd", h, xs["c"])

    ys, hT = _ssm_scan(make_ab, emit,
                       {"dt": dt, "x": xh, "b": bf, "c": cf},
                       h0, engine, c.chunk, s)
    y = ys + p["D"][..., None] * xh
    y = y.reshape(b, s, nh * hd)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return apply_linear(y, p["out_proj"]), hT


def mamba2_apply(p, xin, cfg, *, engine="sequential"):
    b = xin.shape[0]
    c = cfg.ssm
    z, x, bmat, cmat, dt, nh = _m2_split(p, xin, cfg)
    x, _ = _causal_conv(x, p["conv_w"])
    x = jax.nn.silu(x)
    h0 = jnp.zeros((b, nh, c.head_dim, c.d_state), jnp.float32)
    y, _ = _m2_core(p, x, z, bmat, cmat, dt, cfg, h0, engine, nh)
    return y


def mamba2_prefill(p, xin, cfg, *, engine="sequential"):
    b = xin.shape[0]
    c = cfg.ssm
    z, x, bmat, cmat, dt, nh = _m2_split(p, xin, cfg)
    xc, tail = _causal_conv(x, p["conv_w"])
    xc = jax.nn.silu(xc)
    h0 = jnp.zeros((b, nh, c.head_dim, c.d_state), jnp.float32)
    y, hT = _m2_core(p, xc, z, bmat, cmat, dt, cfg, h0, engine, nh)
    return y, {"h": hT, "conv": tail}


def mamba2_init_cache(cfg, batch, dtype):
    c = cfg.ssm
    di = cfg.d_model * c.expand
    nh = di // c.head_dim
    return {
        "h": jnp.zeros((batch, nh, c.head_dim, c.d_state), jnp.float32),
        "conv": jnp.zeros((batch, c.d_conv - 1, di), dtype),
    }


def mamba2_step(p, x1, cache, cfg):
    z, x, bmat, cmat, dt, nh = _m2_split(p, x1, cfg)
    x, tail = _causal_conv(x, p["conv_w"], cache["conv"])
    x = jax.nn.silu(x)
    y, hT = _m2_core(p, x, z, bmat, cmat, dt, cfg, cache["h"], "sequential",
                     nh)
    return y, {"h": hT, "conv": tail}
