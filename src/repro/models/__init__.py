"""Model zoo: one decoder-only assembly covering dense / MoE / SSM / hybrid
layouts, with compression-aware linear dispatch (dense | quantized | ITERA
low-rank) throughout."""
from repro.models.layers import (
    apply_linear, set_linear_mode, get_linear_mode, weight_shape,
)
from repro.models.transformer import (
    init_params, forward, loss_fn, prefill, decode_step, init_cache,
    logits_for,
)

__all__ = [
    "apply_linear", "set_linear_mode", "get_linear_mode", "weight_shape",
    "init_params", "forward", "loss_fn", "prefill", "decode_step",
    "init_cache", "logits_for",
]
