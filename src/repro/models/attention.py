"""Attention for the whole zoo: GQA + RoPE, sliding windows, local/global
alternation, logit soft-capping, chunked (flash-style) prefill, and decode
with (optionally rolling, optionally int8-quantized) KV caches.

Implementations:
  * "full"    — plain masked einsum; right choice for short sequences.
  * "chunked" — python-unrolled q-block loop; each q block attends only to
    the kv prefix (or window) it can actually see, so the compiled FLOPs are
    triangular (≈S²/2) instead of rectangular (S²). This is the pure-JAX
    flash-attention analog used by the 32k prefill dry-run cells.

Serving attention over the blocked KV pool (`span_attention_paged`) has
its own backend pair selected by `cfg.paged_attn_impl`: the Pallas
paged-attention kernel (`kernels/paged_attention.py` — streams only
valid blocks, dequantizes int8 KV in VMEM) and the jnp gather oracle
(`_span_attend_gather`) it is identity-tested against.

GQA: KV is stored at num_kv_heads and broadcast to the query heads at
compute time (group-repeat), so cache memory stays at Hk while the einsum
runs at H. Head axes shard over the "model" mesh axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_linear, apply_rope, softcap

NEG = -2.3819763e38  # large negative for masking in f32


def attn_init(key, cfg, dtype):
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, h * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hk * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hk * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h * hd, d), dtype) * (h * hd) ** -0.5,
    }


def _group_q(q, hk):
    """(B, S, H, Dh) -> (B, S, Hk, G, Dh): group q heads by kv head.

    GQA runs *grouped* — K/V are never repeated to H heads, so cache-sized
    tensors never blow up by the group factor (critical for the 32k/500k
    decode cells)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, hk, h // hk, d)


def _scores(q, k, cap):
    """q: (B, Sq, Hk, G, Dh); k: (B, Sk, Hk, Dh) -> (B, Hk, G, Sq, Sk)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    return softcap(s, cap)


def _attend_block(q, k, v, mask, cap):
    """q grouped (B,Sq,Hk,G,Dh); k/v (B,Sk,Hk,Dh); mask (...,Sq,Sk)."""
    s = jnp.where(mask, _scores(q, k, cap), NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    b, sq, hk, g, d = o.shape
    return o.reshape(b, sq, hk * g, d)


def _causal_mask(q_pos, k_pos, window):
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def attention(params, x, cfg, *, window=None, positions=None,
              return_kv=False):
    """Causal self-attention for training / prefill. x: (B, S, D).

    return_kv=True additionally returns the (pre-expansion, post-RoPE)
    (k, v) pair at Hk heads — prefill uses it to populate the decode
    cache. In that mode, when `cfg.kv_cache_bits == 8`, attention runs
    over the *fake-quantized* K/V (dequantize(quantize(k))) — exactly the
    values decode will later read back from the int8 cache — so prefill
    logits agree bit-for-bit with chunked prefill through the paged pool
    (`span_attention_paged`), which stores each chunk quantized before
    the next chunk attends to it. The returned (k, v) stay full
    precision; `build_cache_from_kv` quantizes them once, yielding the
    identical codes and scales.
    """
    b, s, _ = x.shape
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(s)

    q = apply_linear(x, params["wq"]).reshape(b, s, h, hd)
    k = apply_linear(x, params["wk"]).reshape(b, s, hk, hd)
    v = apply_linear(x, params["wv"]).reshape(b, s, hk, hd)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    kv = (k, v)
    if return_kv and getattr(cfg, "kv_cache_bits", 16) == 8:
        k, v = _fake_quant_kv(k), _fake_quant_kv(v)
    qg = _group_q(q, hk)

    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if s > 2048 else "full"

    if impl == "full":
        mask = _causal_mask(positions, positions, window)[None, None, None]
        o = _attend_block(qg, k, v, mask, cfg.logit_softcap)
    else:
        o = _chunked_causal(qg, k, v, positions, window, cfg)
    y = apply_linear(o.reshape(b, s, h * hd), params["wo"])
    return (y, kv) if return_kv else y


def _chunked_causal(q, k, v, positions, window, cfg):
    """Flash-style q-block loop with static (python) block skipping.

    For q block i only kv blocks [lo_i, i] are materialized, where lo_i is 0
    (causal) or the first block inside the sliding window — compiled FLOPs
    are triangular / banded, not rectangular.

    q is grouped (B, S, Hk, G, Dh); k/v stay at (B, S, Hk, Dh).
    """
    b, s = q.shape[:2]
    c = min(cfg.attn_chunk, s)
    nb = (s + c - 1) // c
    outs = []
    for i in range(nb):
        q_sl = slice(i * c, min((i + 1) * c, s))
        lo = 0
        if window is not None:
            lo = max(0, (i * c - window) // c)
        k_sl = slice(lo * c, min((i + 1) * c, s))
        mask = _causal_mask(positions[q_sl], positions[k_sl],
                            window)[None, None, None]
        outs.append(
            _attend_block(q[:, q_sl], k[:, k_sl], v[:, k_sl], mask,
                          cfg.logit_softcap)
        )
    return jnp.concatenate(outs, axis=1)


# ------------------------------------------------------------------ cache --
def build_cache_from_kv(k, v, *, window=None, max_len=None, dtype=None,
                        quantized=False):
    """Lay prefill (k, v) (B, S, Hk, Dh) out as a decode cache.

    Non-rolling: slot i holds position i (cache sized max_len >= S).
    Rolling (window w): the last w positions land at slot p % w, matching
    decode_attention's rolling write. quantized=True stores int8 codes +
    per-(token, head) scales (cfg.kv_cache_bits == 8).
    """
    b, s, hk, hd = k.shape
    dtype = dtype or k.dtype
    parts = {"k": k, "v": v}
    if quantized:
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        parts = {"k": kq, "v": vq, "ks": ks, "vs": vs}

    def layout(x, fill_dtype):
        if window:
            size = min(window, max_len or s)
            take = min(size, s)
            tail = x[:, -take:]
            slots = ((s - take) + jnp.arange(take)) % size
            init = (jnp.ones if x.shape[-1] == 1 else jnp.zeros)(
                (b, size, hk, x.shape[-1]), fill_dtype)
            return init.at[:, slots].set(tail.astype(fill_dtype))
        size = max_len or s
        pad = size - s
        out = jnp.pad(x.astype(fill_dtype),
                      ((0, 0), (0, pad), (0, 0), (0, 0)),
                      constant_values=1 if x.shape[-1] == 1 else 0)
        return out

    if quantized:
        return {
            "k": layout(parts["k"], jnp.int8),
            "v": layout(parts["v"], jnp.int8),
            "ks": layout(parts["ks"], jnp.float32),
            "vs": layout(parts["vs"], jnp.float32),
        }
    return {"k": layout(parts["k"], dtype), "v": layout(parts["v"], dtype)}


def init_kv_cache(cfg, batch, max_len, *, window=None, dtype=None):
    """Cache for one attention site. Rolling when a window bounds it.

    cfg.kv_cache_bits == 8 stores int8 codes + per-(token, head) fp scales
    (~2x less HBM traffic per decode step — §Perf)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    size = min(window, max_len) if window else max_len
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    if getattr(cfg, "kv_cache_bits", 16) == 8:
        return {
            "k": jnp.zeros((batch, size, hk, hd), jnp.int8),
            "v": jnp.zeros((batch, size, hk, hd), jnp.int8),
            "ks": jnp.ones((batch, size, hk, 1), jnp.float32),
            "vs": jnp.ones((batch, size, hk, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, size, hk, hd), dtype),
        "v": jnp.zeros((batch, size, hk, hd), dtype),
    }


def _quant_kv(x):
    """Per-(token, head) symmetric int8 quantization of K/V rows."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127,
                 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _fake_quant_kv(x):
    """quantize->dequantize round trip: the values an int8 KV cache will
    hand back at decode time, in x's dtype."""
    q, scale = _quant_kv(x)
    return (q.astype(jnp.float32) * scale).astype(x.dtype)


def _paged_impl(cfg) -> str:
    """Resolve cfg.paged_attn_impl: "auto" follows the matmul-kernel
    dispatch rule — compiled Pallas on TPU, the jnp gather oracle on CPU
    (interpret-mode Pallas inside the big jitted serving step would bloat
    the HLO; the oracle is the numerics reference either way)."""
    import jax as _jax

    impl = getattr(cfg, "paged_attn_impl", "auto")
    if impl == "auto":
        return "kernel" if _jax.default_backend() == "tpu" else "ref"
    if impl not in ("kernel", "ref"):
        raise ValueError(f"paged_attn_impl must be auto|kernel|ref, "
                         f"got {impl!r}")
    return impl


def _span_attend_gather(q, pool, block_table, pos, cfg):
    """The jnp oracle: gather the FULL logical pool view
    block_table -> (B, MB*bs, Hk, Dh) (dequantized whole in jnp when the
    pool is int8) and run one masked softmax over it. O(MB*bs) HBM bytes
    and a dense materialization regardless of ctx_lens — the cost the
    Pallas kernel exists to delete; kept as the selectable reference."""
    b, w = q.shape[:2]
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    bs = pool["k"].shape[1]
    mb = block_table.shape[1]
    if "ks" in pool:
        ck = (pool["k"][block_table].reshape(b, mb * bs, hk, hd)
              .astype(q.dtype)
              * pool["ks"][block_table].reshape(b, mb * bs, hk, 1)
              .astype(q.dtype))
        cv = (pool["v"][block_table].reshape(b, mb * bs, hk, hd)
              .astype(q.dtype)
              * pool["vs"][block_table].reshape(b, mb * bs, hk, 1)
              .astype(q.dtype))
    else:
        ck = pool["k"][block_table].reshape(b, mb * bs, hk, hd).astype(q.dtype)
        cv = pool["v"][block_table].reshape(b, mb * bs, hk, hd).astype(q.dtype)

    # (B, W, S): query (r, i) sees slots at positions <= ctx_lens[r] + i
    valid = jnp.arange(mb * bs)[None, None, :] <= pos[:, :, None]
    qg = _group_q(q, hk)                                  # (B,W,Hk,G,Dh)
    s = _scores(qg, ck, cfg.logit_softcap)                # (B,Hk,G,W,S)
    s = jnp.where(valid[:, None, None, :, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cv.dtype), cv)
    return o.reshape(b, w, cfg.num_heads, hd)


def span_attention_paged(params, x, pool, block_table, ctx_lens, q_lens,
                         cfg, *, impl=None):
    """Variable-width query spans against a blocked (paged) KV pool — the
    serving primitive behind `transformer.unified_step`, generalizing
    one-token-per-row paged decode to each row advancing by a span of
    `q_lens[r]` new tokens: a prefill chunk, a single decode token
    (q_lens == 1), or nothing (q_lens == 0, idle/pad row). The unified
    step packs its token budget flat — one buffer row per TOKEN, a
    span's rows repeating their sequence's block table with increasing
    positions and width 1 — so the same math serves both layouts.

    x: (B, W, D) hidden, row r valid in [:q_lens[r]]; pool: ONE layer's
    blocks {"k","v"[,"ks","vs"]} with leaves (NB, bs, Hk, *);
    block_table: (B, MB) int32 physical block ids in logical order,
    padded with the reserved trash block 0; ctx_lens: (B,) int32 tokens
    already in the pool per row == the absolute position of x[:, 0]
    (per-row RoPE / mask).

    Span token (r, i) sits at position p = ctx_lens[r] + i. Its K/V is
    scattered to (block_table[r, p // bs], p % bs) *first*, then
    attention runs over the row's block-table view under the causal mask
    `slot <= p` — so queries see the pool prefix AND the earlier tokens
    of their own span, however the span is laid out (in-step causality
    falls out of write-then-attend; different sequences can never see
    each other — they read through disjoint block tables). Pad slots and
    idle rows write into trash block 0 and read garbage the caller
    discards — no control flow inside the jitted step, static in
    (B, W, MB).

    impl: None -> cfg.paged_attn_impl (see `_paged_impl`). "kernel" runs
    the Pallas paged-attention kernel (`kernels.paged_attention`):
    streams ONLY the ceil((ctx+q)/bs) valid blocks per row and
    dequantizes int8 K/V tiles in VMEM. "ref" runs the jnp gather oracle
    (`_span_attend_gather`): materializes the full (B, MB*bs, Hk, Dh)
    logical view — the numerics reference the kernel is tested against.
    """
    from repro.runtime.kvblocks import span_slots

    b, w, _ = x.shape
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    bs = pool["k"].shape[1]

    q = apply_linear(x, params["wq"]).reshape(b, w, h, hd)
    k = apply_linear(x, params["wk"]).reshape(b, w, hk, hd)
    v = apply_linear(x, params["wv"]).reshape(b, w, hk, hd)
    pos = ctx_lens[:, None] + jnp.arange(w)[None, :]            # (B, W)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, pos, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.rotary_pct)

    blk, off = span_slots(block_table, ctx_lens, q_lens, w, bs)  # (B, W)
    if "ks" in pool:
        kq, ks1 = _quant_kv(k)
        vq, vs1 = _quant_kv(v)
        pool = {
            "k": pool["k"].at[blk, off].set(kq),
            "v": pool["v"].at[blk, off].set(vq),
            "ks": pool["ks"].at[blk, off].set(ks1),
            "vs": pool["vs"].at[blk, off].set(vs1),
        }
    else:
        pool = {
            "k": pool["k"].at[blk, off].set(k.astype(pool["k"].dtype)),
            "v": pool["v"].at[blk, off].set(v.astype(pool["v"].dtype)),
        }

    impl = impl or _paged_impl(cfg)
    if impl == "kernel":
        from repro.kernels.paged_attention import paged_attention

        o = paged_attention(q, pool, block_table, ctx_lens, q_lens,
                            logit_softcap=cfg.logit_softcap,
                            interpret=jax.default_backend() != "tpu")
    else:
        o = _span_attend_gather(q, pool, block_table, pos, cfg)
    y = apply_linear(o.reshape(b, w, h * hd), params["wo"], reduce_tp=True)
    return y, pool


def decode_attention(params, x1, cache, pos, cfg, *, window=None):
    """One-token decode. x1: (B, 1, D); pos: scalar int32 current position.

    Returns (y (B,1,D), updated cache). The cache is rolling (mod window)
    when `window` is set, so SWA archs decode 500k-token contexts with an
    O(window) cache.
    """
    b = x1.shape[0]
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    size = cache["k"].shape[1]

    q = apply_linear(x1, params["wq"]).reshape(b, 1, h, hd)
    k = apply_linear(x1, params["wk"]).reshape(b, 1, hk, hd)
    v = apply_linear(x1, params["wv"]).reshape(b, 1, hk, hd)
    if cfg.pos_emb == "rope":
        p1 = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, p1, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rope(k, p1, cfg.rope_theta, cfg.rotary_pct)

    slot = jnp.mod(pos, size) if window else jnp.minimum(pos, size - 1)
    quant = "ks" in cache
    if quant:
        kq, ks1 = _quant_kv(k)
        vq, vs1 = _quant_kv(v)
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq,
                                              (0, slot, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq,
                                              (0, slot, 0, 0)),
            "ks": jax.lax.dynamic_update_slice(cache["ks"], ks1,
                                               (0, slot, 0, 0)),
            "vs": jax.lax.dynamic_update_slice(cache["vs"], vs1,
                                               (0, slot, 0, 0)),
        }
        ck = cache["k"].astype(q.dtype) * cache["ks"].astype(q.dtype)
        cv = cache["v"].astype(q.dtype) * cache["vs"].astype(q.dtype)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))

    # positions held in each physical slot (rolling-aware)
    idx = jnp.arange(size)
    if window:
        n_wraps = (pos + 1 + size - 1) // size
        slot_pos = jnp.where(idx <= slot, idx + (n_wraps - 1) * size,
                             idx + (n_wraps - 2) * size)
        valid = (slot_pos >= 0) & (slot_pos <= pos) & (slot_pos > pos - size)
    else:
        slot_pos = idx
        valid = idx <= jnp.minimum(pos, size - 1)

    qg = _group_q(q, hk)                               # (B,1,Hk,G,Dh)
    kx = ck.astype(q.dtype)
    vx = cv.astype(q.dtype)
    s = _scores(qg, kx, cfg.logit_softcap)             # (B,Hk,G,1,size)
    s = jnp.where(valid[None, None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vx.dtype), vx)
    y = apply_linear(o.reshape(b, 1, h * hd), params["wo"])
    if quant:
        return y, cache
    return y, {"k": ck, "v": cv}
