"""Mixture-of-Experts block: top-k routing with capacity-bounded
scatter/gather dispatch (collective-friendly under GSPMD), shared
(always-on) experts (DeepSeek-MoE), and an auxiliary load-balance loss.

Dispatch strategy (see DESIGN.md §4): tokens are scattered into per-expert
buffers (E, C, D) whose positions come from a cumsum over the routing mask —
no (T, E, C) one-hot tensor is ever materialized, so the memory footprint is
O(T·E + E·C·D), and under a sharded T the scatter/gather lowers to the
all-to-all-style collectives real expert parallelism uses. Expert FLOPs are
the *active* FLOPs (E·C·D·F with C ≈ T·k·cf/E), not the dense E× blow-up.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_linear, mlp_apply, mlp_init
from repro.runtime.shardctx import get_mesh, maybe_shard


def moe_init(key, cfg, dtype):
    d, f, m = cfg.d_model, cfg.d_ff, cfg.moe
    ks = jax.random.split(key, 4)
    e = m.num_experts
    mats = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    names = ["up", "down", "gate"][:mats]
    shapes = {"up": (d, f), "down": (f, d), "gate": (d, f)}
    experts = {
        n: jax.random.normal(ks[0], (e, *shapes[n]), dtype)
        * (shapes[n][0] ** -0.5)
        for n in names
    }
    p = {"router": jax.random.normal(ks[1], (d, e), jnp.float32) * d ** -0.5,
         "experts": experts}
    if m.num_shared:
        p["shared"] = mlp_init(ks[2], d, f * m.num_shared, cfg.mlp_act, dtype)
    return p


def _expert_ffn(xb, experts, act):
    """xb: (E, C, D); experts: dict of (E, K, N) stacks."""
    def one(x, up, down, gate=None):
        p = {"up": up, "down": down}
        if gate is not None:
            p["gate"] = gate
        return mlp_apply(x, p, act)

    if "gate" in experts:
        return jax.vmap(one)(xb, experts["up"], experts["down"], experts["gate"])
    return jax.vmap(lambda x, u, dn: one(x, u, dn))(
        xb, experts["up"], experts["down"])


def moe_apply(params, x, cfg, *, capacity: int | None = None):
    """x: (B, S, D) -> (B, S, D), aux load-balance loss."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    if capacity is None:
        capacity = max(1, int(t * k * m.capacity_factor / e))
        if capacity > 512:  # round for clean sharding of the C dim
            capacity = -(-capacity // 512) * 512

    xt = maybe_shard(x.reshape(t, d), "tokens", None)
    logits = apply_linear(xt.astype(jnp.float32), params["router"],
                          out_dtype=jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                    # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # positions within each expert buffer via cumsum over the routing mask
    mask = jax.nn.one_hot(idx, e, dtype=jnp.int32).sum(1)  # (T, E) in {0..k}
    pos_in_e = jnp.cumsum(mask, axis=0) - mask             # (T, E) 0-based
    pos = jnp.take_along_axis(pos_in_e, idx, axis=1)       # (T, k)
    ok = pos < capacity

    # scatter token copies into (E*C [+1 dump row], D)
    tgt = jnp.where(ok, idx * capacity + pos, e * capacity)
    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    x_rep = maybe_shard(jnp.repeat(xt, k, axis=0), "tokens", None)
    buf = buf.at[tgt.reshape(-1)].add(x_rep)
    buf3 = buf[:-1].reshape(e, capacity, d)
    # expert-parallel when E divides the model axis, else C over batch only
    mesh = get_mesh()
    ep = mesh is not None and e % mesh.shape["model"] == 0
    buf3 = maybe_shard(buf3, "model" if ep else None, "batch", None)
    yb = _expert_ffn(buf3, params["experts"], cfg.mlp_act)  # (E, C, D)

    # gather back with gates
    flat = yb.reshape(e * capacity, d)
    flat = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], 0)
    picked = maybe_shard(flat[tgt.reshape(-1)].reshape(t, k, d),
                         "tokens", None, None)
    y = maybe_shard(jnp.einsum("tk,tkd->td", gate.astype(x.dtype), picked),
                    "tokens", None)

    if "shared" in params:
        y = y + mlp_apply(xt, params["shared"], cfg.mlp_act)

    # Switch-style load-balance aux loss
    frac_tokens = mask.astype(jnp.float32).mean(0) * e / k
    frac_prob = probs.mean(0) * e
    aux = jnp.mean(frac_tokens * frac_prob)
    return y.reshape(b, s, d), aux
