"""Decoder-only LM assembly for every assigned architecture.

Layouts
  dense  — scan over L identical (attn + MLP) blocks; local/global
           alternating archs (Gemma2) scan over *pairs* so each member of
           the pair keeps a static window;
  moe    — scan over L (attn + MoE) blocks;
  ssm    — scan over L Mamba1 blocks (attention-free);
  hybrid — Zamba2: scan over groups of `hybrid_period` Mamba2 blocks, with
           one *shared-weight* transformer block invoked after each group
           (fresh KV cache per invocation, shared parameters).

All layer stacks are scan-stacked (leading L dim) so the dry-run compiles
one body regardless of depth. `forward` returns hidden states; the loss is
sequence-chunked so (T, vocab) logits never materialize at once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba
from repro.models import moe as moe_mod
from repro.models.layers import (
    apply_linear, apply_norm, init_norm, mlp_apply, mlp_init, softcap,
    sinusoidal_emb,
)
from repro.runtime.shardctx import maybe_shard


# ------------------------------------------------------------------ init --
def _stack_init(fn, key, n):
    """vmap a per-layer init over n layer keys -> scan-stacked params."""
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    p = {"embed": jax.random.normal(keys[0], (cfg.vocab_size, d), dtype) * 0.02,
         "final_norm": init_norm(cfg.norm, d, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(keys[1], (d, cfg.vocab_size),
                                         dtype) * d ** -0.5

    def dense_block(k):
        ks = jax.random.split(k, 2)
        return {
            "ln1": init_norm(cfg.norm, d, dtype),
            "attn": attn.attn_init(ks[0], cfg, dtype),
            "ln2": init_norm(cfg.norm, d, dtype),
            "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act, dtype),
        }

    def moe_block(k):
        ks = jax.random.split(k, 2)
        return {
            "ln1": init_norm(cfg.norm, d, dtype),
            "attn": attn.attn_init(ks[0], cfg, dtype),
            "ln2": init_norm(cfg.norm, d, dtype),
            "moe": moe_mod.moe_init(ks[1], cfg, dtype),
        }

    def mamba_block(k, version):
        init = mamba.mamba1_init if version == 1 else mamba.mamba2_init
        return {"ln": init_norm(cfg.norm, d, dtype),
                "mixer": init(k, cfg, dtype)}

    L = cfg.num_layers
    if cfg.layout == "dense":
        if cfg.local_global_period:
            assert L % 2 == 0
            p["layers"] = _stack_init(dense_block, keys[2], L)
        else:
            p["layers"] = _stack_init(dense_block, keys[2], L)
    elif cfg.layout == "moe":
        p["layers"] = _stack_init(moe_block, keys[2], L)
    elif cfg.layout == "ssm":
        p["layers"] = _stack_init(
            functools.partial(mamba_block, version=cfg.ssm.version), keys[2], L)
    elif cfg.layout == "hybrid":
        assert L % cfg.hybrid_period == 0
        p["layers"] = _stack_init(
            functools.partial(mamba_block, version=cfg.ssm.version), keys[2], L)
        p["shared_block"] = dense_block(keys[3])
    else:
        raise ValueError(cfg.layout)
    return p


# --------------------------------------------------------------- forward --
def embed(params, inputs, cfg, pos0=0):
    """inputs: int tokens (B, S) or precomputed embeddings (B, S, D).
    pos0: absolute position of inputs[:, 0] — a scalar (rectangular
    decode passes the step) or a (B,) vector (continuous batching, where
    every row sits at its own position)."""
    dtype = jnp.dtype(cfg.dtype)
    if inputs.ndim == 3:  # modality-frontend stub: embeddings arrive directly
        h = inputs.astype(dtype)
    else:
        h = jnp.take(params["embed"], inputs, axis=0)
        if cfg.layout != "ssm":
            h = h * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if cfg.pos_emb == "sinusoidal":
        pos = jnp.asarray(pos0)[..., None] + jnp.arange(h.shape[1])
        emb = sinusoidal_emb(pos, cfg.d_model, dtype)  # (S,D) or (B,S,D)
        h = h + (emb if emb.ndim == 3 else emb[None])
    return maybe_shard(h, "batch", "seq", None)


def _window_for_layer(cfg, which):
    if cfg.local_global_period:
        return cfg.local_window if which == "local" else None
    return cfg.attn_window


def _dense_body(cfg, h, lp, *, window, return_kv=False):
    hn = apply_norm(h, lp["ln1"], cfg.norm, cfg.norm_eps)
    if return_kv:
        a, kv = attn.attention(lp["attn"], hn, cfg, window=window,
                               return_kv=True)
    else:
        a = attn.attention(lp["attn"], hn, cfg, window=window)
        kv = None
    h = maybe_shard(h + a, "batch", "seq", None)
    hn = apply_norm(h, lp["ln2"], cfg.norm, cfg.norm_eps)
    if "moe" in lp:
        y, aux = moe_mod.moe_apply(lp["moe"], hn, cfg)
    else:
        y, aux = mlp_apply(hn, lp["mlp"], cfg.mlp_act), 0.0
    h = maybe_shard(h + y, "batch", "seq", None)
    return (h, aux, kv) if return_kv else (h, aux)


def _mamba_body(cfg, h, lp, *, engine, return_state=False):
    hn = apply_norm(h, lp["ln"], cfg.norm, cfg.norm_eps)
    apply = mamba.mamba1_apply if cfg.ssm.version == 1 else mamba.mamba2_apply
    y = apply(lp["mixer"], hn, cfg, engine=engine)
    return maybe_shard(h + y, "batch", "seq", None)


def _maybe_remat(cfg, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        # save matmul outputs; recompute only cheap elementwise ops in the
        # backward pass — trades activation memory for ~25% less recompute
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params, inputs, cfg, *, ssm_engine="sequential"):
    """Returns (final hidden (B,S,D), aux_loss)."""
    h = embed(params, inputs, cfg)
    L = cfg.num_layers

    if cfg.layout in ("dense", "moe"):
        if cfg.local_global_period:
            pair = jax.tree_util.tree_map(
                lambda x: x.reshape(L // 2, 2, *x.shape[1:]), params["layers"])

            def body(carry, lp):
                h, aux = carry
                lp0 = jax.tree_util.tree_map(lambda x: x[0], lp)
                lp1 = jax.tree_util.tree_map(lambda x: x[1], lp)
                h, a0 = _dense_body(cfg, h, lp0, window=cfg.local_window)
                h, a1 = _dense_body(cfg, h, lp1, window=None)
                return (h, aux + a0 + a1), None

            (h, aux), _ = jax.lax.scan(_maybe_remat(cfg, body), (h, 0.0), pair)
        else:
            def body(carry, lp):
                h, aux = carry
                h, a = _dense_body(cfg, h, lp, window=cfg.attn_window)
                return (h, aux + a), None

            (h, aux), _ = jax.lax.scan(_maybe_remat(cfg, body), (h, 0.0),
                                       params["layers"])
    elif cfg.layout == "ssm":
        def body(h, lp):
            return _mamba_body(cfg, h, lp, engine=ssm_engine), None

        h, _ = jax.lax.scan(_maybe_remat(cfg, body), h, params["layers"])
        aux = 0.0
    elif cfg.layout == "hybrid":
        p_per = cfg.hybrid_period
        groups = jax.tree_util.tree_map(
            lambda x: x.reshape(L // p_per, p_per, *x.shape[1:]),
            params["layers"])
        shared = params["shared_block"]

        def group_body(h, gp):
            def inner(h, lp):
                return _mamba_body(cfg, h, lp, engine=ssm_engine), None
            h, _ = jax.lax.scan(inner, h, gp)
            h, _ = _dense_body(cfg, h, shared, window=cfg.attn_window)
            return h, None

        h, _ = jax.lax.scan(_maybe_remat(cfg, group_body), h, groups)
        aux = 0.0
    else:
        raise ValueError(cfg.layout)

    return apply_norm(h, params["final_norm"], cfg.norm, cfg.norm_eps), aux


def lm_head_weight(params, cfg):
    return (params["embed"].T if cfg.tie_embeddings else params["lm_head"])


def logits_for(params, h, cfg):
    w = lm_head_weight(params, cfg)
    out = apply_linear(h, w, out_dtype=jnp.float32)
    return softcap(out, cfg.final_softcap)


# ------------------------------------------------------------------ loss --
def chunked_loss(params, h, labels, cfg):
    """Mean token cross-entropy, scanning over sequence chunks so the
    (B, S, V) logits tensor never exists whole."""
    b, s, d = h.shape
    c = min(cfg.loss_chunk, s)
    while s % c:
        c -= 1
    nc = s // c
    w = lm_head_weight(params, cfg)

    def body(acc, xs):
        hc, yc = xs                                   # (nc axis) (B,c,D),(B,c)
        logits = softcap(
            apply_linear(hc, w, out_dtype=jnp.float32), cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    hs = h.reshape(b, nc, c, d).swapaxes(0, 1)
    ys = labels.reshape(b, nc, c).swapaxes(0, 1)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys))
    return total / (b * s)


def loss_fn(params, batch, cfg, *, aux_weight=0.01, ssm_engine="sequential"):
    inputs = batch.get("inputs_embeds", batch.get("tokens"))
    h, aux = forward(params, inputs, cfg, ssm_engine=ssm_engine)
    ce = chunked_loss(params, h, batch["labels"], cfg)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------- cache --
def init_cache(cfg, batch, max_len, dtype=None):
    """Decode cache pytree. Shapes are static given (cfg, batch, max_len)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.num_layers

    def stack(tree, n):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), tree)

    if cfg.layout in ("dense", "moe"):
        if cfg.local_global_period:
            loc = attn.init_kv_cache(cfg, batch, max_len,
                                     window=cfg.local_window, dtype=dtype)
            glo = attn.init_kv_cache(cfg, batch, max_len, dtype=dtype)
            return {"local": stack(loc, L // 2), "global": stack(glo, L // 2)}
        kv = attn.init_kv_cache(cfg, batch, max_len, window=cfg.attn_window,
                                dtype=dtype)
        return {"kv": stack(kv, L)}
    if cfg.layout == "ssm":
        mc = (mamba.mamba1_init_cache if cfg.ssm.version == 1
              else mamba.mamba2_init_cache)(cfg, batch, dtype)
        return {"ssm": stack(mc, L)}
    if cfg.layout == "hybrid":
        g = L // cfg.hybrid_period
        mc = (mamba.mamba1_init_cache if cfg.ssm.version == 1
              else mamba.mamba2_init_cache)(cfg, batch, dtype)
        kv = attn.init_kv_cache(cfg, batch, max_len, window=cfg.attn_window,
                                dtype=dtype)
        return {"ssm": stack(mc, L), "shared_kv": stack(kv, g)}
    raise ValueError(cfg.layout)


def prefill(params, inputs, cfg, *, max_len=None, cache_dtype=None,
            ssm_engine="sequential", last_pos=None):
    """Process a prompt; return (last-position logits (B,1,V), decode cache).

    This is the `prefill_32k` serving entry point: one forward pass that
    also lays out every layer's KV / SSM state for subsequent decode.

    last_pos: optional *traced* scalar — the index whose logits to return
    (default: the final column). Length-bucketed prompts are right-padded
    to a power of two before jit, so the true last token is mid-sequence;
    passing its index as a traced value keeps the bucket's compilation
    shared across every real length inside it.
    """
    h = embed(params, inputs, cfg)
    L = cfg.num_layers
    s = h.shape[1]
    max_len = max_len or s
    cdt = cache_dtype or jnp.dtype(cfg.dtype)

    def dense_with_kv(h, lp, window):
        h2, aux, kv = _dense_body(cfg, h, lp, window=window, return_kv=True)
        kvc = attn.build_cache_from_kv(
            kv[0], kv[1], window=window, max_len=max_len, dtype=cdt,
            quantized=cfg.kv_cache_bits == 8)
        return h2, kvc

    if cfg.layout in ("dense", "moe"):
        if cfg.local_global_period:
            pair = jax.tree_util.tree_map(
                lambda x: x.reshape(L // 2, 2, *x.shape[1:]), params["layers"])

            def body(h, lp):
                lp0 = jax.tree_util.tree_map(lambda x: x[0], lp)
                lp1 = jax.tree_util.tree_map(lambda x: x[1], lp)
                h, cl = dense_with_kv(h, lp0, cfg.local_window)
                h, cg = dense_with_kv(h, lp1, None)
                return h, (cl, cg)

            h, (cl, cg) = jax.lax.scan(_maybe_remat(cfg, body), h, pair)
            cache = {"local": cl, "global": cg}
        else:
            def body(h, lp):
                return dense_with_kv(h, lp, cfg.attn_window)

            h, kv = jax.lax.scan(_maybe_remat(cfg, body), h, params["layers"])
            cache = {"kv": kv}
    elif cfg.layout == "ssm":
        pre = (mamba.mamba1_prefill if cfg.ssm.version == 1
               else mamba.mamba2_prefill)

        def body(h, lp):
            hn = apply_norm(h, lp["ln"], cfg.norm, cfg.norm_eps)
            y, mc = pre(lp["mixer"], hn, cfg, engine=ssm_engine)
            return maybe_shard(h + y, "batch", "seq", None), mc

        h, mc = jax.lax.scan(_maybe_remat(cfg, body), h, params["layers"])
        cache = {"ssm": mc}
    elif cfg.layout == "hybrid":
        p_per = cfg.hybrid_period
        groups = jax.tree_util.tree_map(
            lambda x: x.reshape(L // p_per, p_per, *x.shape[1:]),
            params["layers"])
        shared = params["shared_block"]
        pre = (mamba.mamba1_prefill if cfg.ssm.version == 1
               else mamba.mamba2_prefill)

        def body(h, gp):
            def inner(h, lp):
                hn = apply_norm(h, lp["ln"], cfg.norm, cfg.norm_eps)
                y, mc = pre(lp["mixer"], hn, cfg, engine=ssm_engine)
                return maybe_shard(h + y, "batch", "seq", None), mc

            h, mcs = jax.lax.scan(inner, h, gp)
            h, kvc = dense_with_kv(h, shared, cfg.attn_window)
            return h, (mcs, kvc)

        h, (mcs, kv) = jax.lax.scan(_maybe_remat(cfg, body), h, groups)
        cache = {"ssm": jax.tree_util.tree_map(
            lambda x: x.reshape(L, *x.shape[2:]), mcs), "shared_kv": kv}
    else:
        raise ValueError(cfg.layout)

    h = apply_norm(h, params["final_norm"], cfg.norm, cfg.norm_eps)
    if last_pos is None:
        h1 = h[:, -1:]
    else:
        h1 = jax.lax.dynamic_slice_in_dim(h, last_pos, 1, axis=1)
    logits = logits_for(params, h1, cfg)
    return logits, cache


def unified_step(params, pool, block_tables, ctx_lens, q_lens, inputs, cfg,
                 verify_width: int = 0):
    """ONE token-budget serving step over a blocked KV pool: every active
    row advances by a span of `q_lens[r]` tokens — a prefill chunk, a
    single decode token, or nothing — in a single forward pass.

    inputs: (B, W) tokens, row r valid in [:q_lens[r]]; block_tables:
    (B, MB) int32; ctx_lens: (B,) int32 tokens already in the pool per
    row (== the position of inputs[:, 0]); pool:
    runtime.kvblocks.init_paged_cache leaves (L, NB, bs, Hk, *), scanned
    over layers exactly like the monolithic cache. Returns
    (logits (B, 1, V) f32 at each row's LAST valid span position,
    updated pool) — exactly the logits that sample the row's next token
    when its span completes the prompt or decodes. Idle rows compute
    garbage the caller discards; shapes are static in (B, W, MB) so the
    one jitted step covers the whole serve loop regardless of
    admissions, evictions, or the prefill/decode mix (W is bucketed to a
    power of two by the driver, so at most O(log budget) shapes exist,
    and W == 1 — the decode-only steady state — is exactly the classic
    one-token paged decode). The row-major span layout keeps the KV
    reads per ROW (each row reads its block-table view once however wide
    its span is), which is what makes chunked prefill affordable at real
    model sizes.

    Attention per layer goes through `attn.span_attention_paged`, whose
    backend is cfg.paged_attn_impl: on TPU ("auto"/"kernel") the Pallas
    paged-attention kernel streams only each row's
    ceil((ctx+q)/block_size) valid blocks — O(ctx) HBM bytes per step —
    and dequantizes int8 KV in VMEM; "ref" (the CPU default) runs the
    jnp gather oracle the kernel is identity-tested against.

    verify_width > 0 is the multi-token speculative-verify mode
    (runtime/speculation.py): logits come back for span positions
    0..verify_width-1 PLUS each row's last-valid position appended —
    shape (B, verify_width + 1, V) — so one step both verifies a k-token
    draft span (positions 0..k-1 predict tokens 1..k) and still yields
    the last-position logits prefill-finishing rows sample from. The lm
    head runs on verify_width + 1 positions regardless of W, so wide
    prefill chunks pay nothing extra. verify_width must be <= W.

    Tensor parallelism: the step is shard_map-compatible. When
    api.engine wraps it with `shardctx.tp_axis("model")` bound, `cfg`
    is the PER-SHARD config (num_heads/num_kv_heads divided by the mesh
    model axis), params arrive column/row-sliced per
    launch.sharding._TP_RULES, and the pool arrives head-sliced
    (kvblocks.pool_pspecs). Attention and MLP then compute partial
    results over local heads / hidden columns, and exactly one
    `shardctx.psum_tp` fires per attention/MLP boundary — inside the wo
    and down projections (`apply_linear(..., reduce_tp=True)`), which
    reduce their f32 partials BEFORE the single cast to the residual
    dtype, keeping bf16 TP bit-identical to the unsharded step. 2L
    psums per step, the only collectives. With no TP axis bound the
    reduce_tp flag is inert and this is the single-device step
    unchanged.
    """
    from repro.runtime.kvblocks import check_paged_support

    check_paged_support(cfg)
    h = embed(params, inputs, cfg, pos0=ctx_lens)

    def body(h, xs):
        lp, pl = xs
        hn = apply_norm(h, lp["ln1"], cfg.norm, cfg.norm_eps)
        a, pl = attn.span_attention_paged(lp["attn"], hn, pl, block_tables,
                                          ctx_lens, q_lens, cfg)
        h = h + a
        hn = apply_norm(h, lp["ln2"], cfg.norm, cfg.norm_eps)
        if "moe" in lp:
            y, _ = moe_mod.moe_apply(lp["moe"], hn, cfg)
        else:
            y = mlp_apply(hn, lp["mlp"], cfg.mlp_act)
        h = h + y
        return h, pl

    h, pool = jax.lax.scan(body, h, (params["layers"], pool))
    h = apply_norm(h, params["final_norm"], cfg.norm, cfg.norm_eps)
    last = jnp.maximum(q_lens - 1, 0)[:, None, None]      # (B, 1, 1)
    h1 = jnp.take_along_axis(h, jnp.broadcast_to(
        last, (h.shape[0], 1, h.shape[2])), axis=1)       # (B, 1, D)
    if verify_width:
        if verify_width > h.shape[1]:
            raise ValueError(f"verify_width {verify_width} exceeds span "
                             f"width {h.shape[1]}")
        h1 = jnp.concatenate([h[:, :verify_width], h1], axis=1)
    return logits_for(params, h1, cfg), pool


def serve_step(params, pool, block_tables, step_buf, prev, recent,
               stop_seqs, cfg, *, sample: bool = False, stop: bool = False):
    """One fused serving dispatch: `unified_step` plus the logits→token
    path (sampling) and device stop evaluation, all in one jit.

    step_buf: (B, W + 3 + runtime.sampling.SAMP_COLS) int32 — the
    host-built span tokens (B, W), three scheduling columns (ctx_lens,
    q_lens, use_prev), then the packed per-row sampling/stop metadata
    (see runtime/sampling.py), so the hot loop still uploads ONE array
    per step. Decode rows' first token column is spliced from `prev`
    (the previous step's device-resident sampled tokens) so token
    values never round-trip through the host. `recent` is the per-row
    ring of the last S emitted tokens (device-resident, carried across
    steps like `prev`); `stop_seqs` is the (B, NS, S) right-aligned
    stop-sequence buffer (refreshed on admission, like block tables).

    `sample` / `stop` are STATIC: the engine traces one variant per
    (any-row-samples, any-stop-criteria) pair for a serve call, so an
    all-greedy, no-stop serve runs a program with no sort, no PRNG, and
    no ring update — exactly the previous greedy step. Within a sampled
    variant, rows with temperature <= 0 still take the raw-logits
    argmax (bit-identical to greedy; see sampling.sample_tokens).

    Returns (toks (B, 1) int32, finished (B,) int32, recent, pool).
    `finished` flags rows whose emission this step completed the
    request (eos / stop sequence / max_tokens); the engine reads it off
    the already-pipelined readback — no extra host sync.
    """
    from repro.runtime import sampling as smp

    meta = step_buf[:, -(3 + smp.SAMP_COLS):]
    tokens = step_buf[:, :-(3 + smp.SAMP_COLS)]
    ctx_lens, q_lens, use_prev = meta[:, 0], meta[:, 1], meta[:, 2]
    tokens = tokens.at[:, 0].set(
        jnp.where(use_prev.astype(bool), prev[:, 0], tokens[:, 0]))
    logits, pool = unified_step(params, pool, block_tables, ctx_lens,
                                q_lens, tokens, cfg)
    last = logits[:, -1]
    if sample:
        sp = smp.unpack_meta(step_buf)
        keys = smp.row_keys(sp["seed"], sp["rid"], sp["counter"])
        toks = smp.sample_tokens(last, sp["temperature"], sp["top_k"],
                                 sp["top_p"], keys)[:, None]
    else:
        toks = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
    if stop:
        sp = smp.unpack_meta(step_buf)
        recent = smp.push_recent(recent, toks)
        fin = smp.finished_mask(toks[:, 0], recent, sp, stop_seqs)
    else:
        fin = jnp.zeros((toks.shape[0],), jnp.int32)
    return toks, fin, recent, pool


def decode_step(params, cache, inputs, pos, cfg):
    """One decode step. inputs: (B, 1) tokens or (B, 1, D) embeds.
    Returns (logits (B, 1, V) f32, new cache)."""
    h = embed(params, inputs, cfg, pos0=pos)
    L = cfg.num_layers

    def dense_step(h, lp, kvc, window):
        hn = apply_norm(h, lp["ln1"], cfg.norm, cfg.norm_eps)
        a, kvc = attn.decode_attention(lp["attn"], hn, kvc, pos, cfg,
                                       window=window)
        h = h + a
        hn = apply_norm(h, lp["ln2"], cfg.norm, cfg.norm_eps)
        if "moe" in lp:
            y, _ = moe_mod.moe_apply(lp["moe"], hn, cfg)
        else:
            y = mlp_apply(hn, lp["mlp"], cfg.mlp_act)
        return h + y, kvc

    def mamba_step(h, lp, mc):
        hn = apply_norm(h, lp["ln"], cfg.norm, cfg.norm_eps)
        step = mamba.mamba1_step if cfg.ssm.version == 1 else mamba.mamba2_step
        y, mc = step(lp["mixer"], hn, mc, cfg)
        return h + y, mc

    if cfg.layout in ("dense", "moe"):
        if cfg.local_global_period:
            pair = jax.tree_util.tree_map(
                lambda x: x.reshape(L // 2, 2, *x.shape[1:]), params["layers"])

            def body(h, xs):
                lp, cl, cg = xs
                lp0 = jax.tree_util.tree_map(lambda x: x[0], lp)
                lp1 = jax.tree_util.tree_map(lambda x: x[1], lp)
                h, cl = dense_step(h, lp0, cl, cfg.local_window)
                h, cg = dense_step(h, lp1, cg, None)
                return h, (cl, cg)

            h, (cl, cg) = jax.lax.scan(body, h,
                                       (pair, cache["local"], cache["global"]))
            cache = {"local": cl, "global": cg}
        else:
            def body(h, xs):
                lp, kvc = xs
                h, kvc = dense_step(h, lp, kvc, cfg.attn_window)
                return h, kvc

            h, kv = jax.lax.scan(body, h, (params["layers"], cache["kv"]))
            cache = {"kv": kv}
    elif cfg.layout == "ssm":
        def body(h, xs):
            lp, mc = xs
            h, mc = mamba_step(h, lp, mc)
            return h, mc

        h, mc = jax.lax.scan(body, h, (params["layers"], cache["ssm"]))
        cache = {"ssm": mc}
    elif cfg.layout == "hybrid":
        p_per = cfg.hybrid_period
        groups = jax.tree_util.tree_map(
            lambda x: x.reshape(L // p_per, p_per, *x.shape[1:]),
            params["layers"])
        ssm_groups = jax.tree_util.tree_map(
            lambda x: x.reshape(L // p_per, p_per, *x.shape[1:]), cache["ssm"])
        shared = params["shared_block"]

        def body(h, xs):
            gp, mcs, kvc = xs

            def inner(h, ys):
                lp, mc = ys
                h, mc = mamba_step(h, lp, mc)
                return h, mc

            h, mcs = jax.lax.scan(inner, h, (gp, mcs))
            h, kvc = dense_step(h, shared, kvc, cfg.attn_window)
            return h, (mcs, kvc)

        h, (mcs, kv) = jax.lax.scan(body, h,
                                    (groups, ssm_groups, cache["shared_kv"]))
        cache = {"ssm": jax.tree_util.tree_map(
            lambda x: x.reshape(L, *x.shape[2:]), mcs), "shared_kv": kv}
    else:
        raise ValueError(cfg.layout)

    h = apply_norm(h, params["final_norm"], cfg.norm, cfg.norm_eps)
    return logits_for(params, h, cfg), cache
