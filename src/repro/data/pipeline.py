"""Data pipeline: deterministic synthetic token streams, device sharding,
and a double-buffered prefetcher.

Two generators:
  * `hash_stream`   — uniform pseudo-random tokens, fully deterministic in
    (seed, step); used by dry-runs and throughput benches.
  * `markov_stream` — tokens from a seeded sparse Markov chain. This task
    is *learnable* (a trained model reaches far-below-uniform loss), which
    is what the SRA calibration metric and the compression-quality Pareto
    benchmarks need: quality differences between compression methods are
    invisible on pure noise.

For the modality-frontend archs the same streams are lifted to embedding
space by a frozen random projection table ("precomputed frame/patch
embeddings" per the stub contract).
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.shardctx import get_mesh, logical_spec


def hash_batch(seed: int, step: int, batch: int, seq: int, vocab: int):
    """Deterministic uniform tokens for (seed, step)."""
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed),
                                                step), 0xDA7A)
    toks = jax.random.randint(key, (batch, seq + 1), 0, vocab, jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MarkovTask:
    """Seeded sparse Markov chain over `vocab` states (numpy, host-side)."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        logits = rng.standard_normal((vocab, branching))
        e = np.exp(logits - logits.max(-1, keepdims=True))
        self.probs = e / e.sum(-1, keepdims=True)

    def batch(self, step: int, batch: int, seq: int):
        rng = np.random.default_rng((hash((step, 0xC0FFEE)) & 0x7FFFFFFF))
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            cur = toks[:, t]
            choice = (rng.random(batch)[:, None] >
                      np.cumsum(self.probs[cur], -1)).sum(-1)
            choice = np.minimum(choice, self.probs.shape[1] - 1)
            toks[:, t + 1] = self.succ[cur, choice]
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def entropy_floor(self) -> float:
        """Mean conditional entropy (nats) — the best achievable loss."""
        p = self.probs
        return float(-(p * np.log(p)).sum(-1).mean())


class LatentMarkovTask(MarkovTask):
    """Markov chain whose transition structure factors through `classes`
    latent classes: successor distribution depends only on class(token).

    The optimal predictor therefore has intrinsic rank ~= classes — the
    regime real language models sit in (decaying weight spectra), and the
    reason SVD compression works on OPUS-MT at all (DESIGN.md §7). Trained
    proxies on this task develop low-rank-compressible weights, unlike
    flat-spectrum uniform chains.
    """

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4,
                 classes: int = 16):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.classes = classes
        cls_succ = rng.integers(0, classes, size=(classes, branching))
        logits = rng.standard_normal((classes, branching))
        e = np.exp(logits - logits.max(-1, keepdims=True))
        cls_probs = e / e.sum(-1, keepdims=True)
        # per-token successor = a fixed representative of the target class
        reps = rng.integers(0, vocab // classes, size=(classes, branching))
        tok_cls = np.arange(vocab) % classes
        self.succ = np.empty((vocab, branching), np.int64)
        self.probs = np.empty((vocab, branching))
        for t in range(vocab):
            c = tok_cls[t]
            self.succ[t] = cls_succ[c] + classes * reps[c]
            self.probs[t] = cls_probs[c]
        self.succ = np.clip(self.succ, 0, vocab - 1)


def lift_to_embeddings(batch, table: jax.Array):
    """Frontend stub: replace int tokens with precomputed embeddings."""
    emb = jnp.take(table, batch["tokens"], axis=0)
    return {"inputs_embeds": emb, "labels": batch["labels"]}


def shard_batch(batch, mesh=None):
    """Place a host batch onto the mesh (batch dim over pod+data axes)."""
    mesh = mesh or get_mesh()
    if mesh is None:
        return batch

    def put(x):
        names = ["batch"] + [None] * (x.ndim - 1)
        s = jax.sharding.NamedSharding(mesh, logical_spec(names, mesh))
        return jax.device_put(x, s)

    return jax.tree_util.tree_map(put, batch)


class Prefetcher:
    """Background-thread prefetch of `make(step)` batches (depth-bounded)."""

    def __init__(self, make, start_step: int = 0, depth: int = 2):
        self._make = make
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            s = start_step
            while not self._stop.is_set():
                try:
                    self._q.put((s, make(s)), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            try:
                return self._q.get(timeout=1.0)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration

    def close(self):
        self._stop.set()
