"""Pure-JAX AdamW with schedules, global-norm clipping, ZeRO-1 sharding
specs, and an 8-bit (blockwise-int8) state variant.

No optax in this environment — this is a complete implementation. The 8-bit
variant quantizes the first and second moments blockwise (256-element
blocks, fp32 absmax per block) after every update: a 4x optimizer-memory
cut that is one of the distributed-memory levers in §Perf (it is what lets
the 340B train cell fit a 16 GB/chip pod — see EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"        # cosine | linear | constant
    state_bits: int = 32            # 32 | 8


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


# --------------------------------------------------- blockwise int8 state --
_BLK = 256


def _q8(x: jax.Array):
    """Symmetric linear int8 (for the signed first moment m)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLK)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dq8(s, shape):
    flat = (s["q"].astype(jnp.float32) * s["scale"]).reshape(-1)
    n = 1
    for d in shape:
        n *= int(d)
    return flat[:n].reshape(shape)


_VLOG_FLOOR = 1e-16


def _q8log(x: jax.Array):
    """Log-space int8 (for the non-negative second moment v).

    Linear quantization zero-crushes small v inside blocks that contain
    large values -> 1/sqrt(0)+eps update spikes and divergence. Log-space
    codes bound the *relative* error instead (bitsandbytes-style)."""
    flat = jnp.maximum(x.reshape(-1), 0.0)
    pad = (-flat.size) % _BLK
    flat = jnp.pad(flat, (0, pad))
    blocks = jnp.log(flat.reshape(-1, _BLK) + _VLOG_FLOOR)
    lo = jnp.min(blocks, axis=1, keepdims=True)
    hi = jnp.max(blocks, axis=1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-6) / 254.0
    q = jnp.clip(jnp.round((blocks - lo) / scale) - 127, -127,
                 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32),
            "off": lo.astype(jnp.float32)}


def _dq8log(s, shape):
    blocks = jnp.exp((s["q"].astype(jnp.float32) + 127.0) * s["scale"]
                     + s["off"]) - _VLOG_FLOOR
    flat = jnp.maximum(blocks, 0.0).reshape(-1)
    n = 1
    for d in shape:
        n *= int(d)
    return flat[:n].reshape(shape)


# ----------------------------------------------------------------- adamw --
def init(params, cfg: AdamWConfig):
    def zeros(p, log=False):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.state_bits == 8:
            return _q8log(z) if log else _q8(z)
        return z

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(lambda p: zeros(p, log=True), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


@partial(jax.jit, static_argnames=("cfg",))
def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule_lr(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        if cfg.state_bits == 8:
            m = _dq8(m, g.shape)
            v = _dq8log(v, g.shape)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** count.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** count.astype(jnp.float32))
        upd = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = (p.astype(jnp.float32) * (1 - lr * decay) - lr * upd)
        if cfg.state_bits == 8:
            m, v = _q8(m), _q8log(v)
        return newp.astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [leaf(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


def zero1_pspec(param_spec, shape, mesh, axis: str = "data"):
    """ZeRO-1: shard an optimizer-state leaf over `axis` along the first
    dimension the param spec leaves unsharded and divisible."""
    if axis not in mesh.axis_names:
        return param_spec
    size = mesh.shape[axis]
    specs = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = {a for s in specs if s is not None
            for a in (s if isinstance(s, tuple) else (s,))}
    if axis in used:          # param spec already consumes this axis
        return param_spec
    for i, (s, d) in enumerate(zip(specs, shape)):
        if s is None and d % size == 0:
            specs[i] = axis
            return jax.sharding.PartitionSpec(*specs)
    return param_spec
