"""Fused in-device sampling and stop evaluation for the serving step.

Serving throughput on small steps is bounded by host dispatch overhead,
so per-request sampling must ride the ONE packed buffer the serve loop
already uploads per step — never a second upload, never a host round
trip. This module owns that contract:

  * **Packed sampling metadata** — every dispatch buffer ends in
    `SAMP_COLS` int32 columns per row: temperature / top_p as float32
    *bit patterns* (the buffer stays a single int32 array), top_k, the
    request's seed / rid / emission counter for key derivation, and the
    eos id + max_tokens for the stop mask. `write_row_meta` packs a row
    host-side; `unpack_meta` bitcasts it back inside the jitted step.

  * **Counter-based PRNG keys** — row r samples its c-th output token
    with `fold_in(fold_in(PRNGKey(seed_r), rid_r), c)`. Keys are a pure
    function of (request, emission index): NOT of batch composition,
    batch row, prefix-cache hits, TP mesh size, or speculation — which
    is the whole reproducibility story. Seeded runs replay token-for-
    token across all of those, and `generate()` derives keys the same
    way so the rectangular and continuous-batching paths agree.

  * **One shared sampler** — `sample_tokens` applies temperature
    scaling, per-row top-k, then top-p *in that order* inside a static
    top-`TOPK_CAP` candidate window (one `lax.top_k` serves both
    truncations; no full-vocab sort), then a per-row-keyed categorical.
    Rows with temperature <= 0 return the raw-logits argmax —
    bit-identical to the greedy serving path.

  * **Device stop evaluation** — a per-row ring of the last S emitted
    tokens (`push_recent`, carried across steps like the engine's
    `prev_toks`) lets `finished_mask` match eos / stop sequences /
    max_tokens entirely on device; the engine reads the mask off the
    already-pipelined completion path. Stop sequences are right-aligned
    in a (-1)-padded (B, NS, S) buffer; a length-l match additionally
    requires l <= counter + 1, which provably ignores ring content left
    behind by a row's previous occupant (the newest counter + 1 slots
    are exactly this request's emissions, because once a row decodes it
    emits every step until it finishes).

Stop semantics are *inclusive*: generation stops AFTER emitting the
token that completes the eos/stop match, and the matched tokens stay in
the output (streaming front doors forward tokens as they complete, so
un-emitting is not an option). `match_stop_host` is the numpy oracle
with the same semantics — tests diff device truncation against it, and
the synchronous speculative loop (which reads tokens back every step
anyway) uses it directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# --- packed sampling metadata: the last SAMP_COLS columns of every ---
# --- serve dispatch buffer, one int32 block per row ------------------
SAMP_COLS = 8
# column offsets inside the block (negative-indexed from the buffer end)
TEMP, TOPK, TOPP, SEED, RID, COUNTER, EOS, MAXTOK = range(SAMP_COLS)


def f32_bits(x: float) -> int:
    """Host-side float32 -> int32 bit pattern (the exact inverse of the
    device-side bitcast in `unpack_meta`)."""
    return int(np.float32(x).view(np.int32))


def write_row_meta(buf: np.ndarray, row: int, req, counter: int) -> None:
    """Pack one row's sampling/stop metadata into the buffer's trailing
    SAMP_COLS columns. `req` is a resolved `runtime.scheduler.Request`
    (temperature/top_k/top_p/seed all concrete); `counter` is the index
    of the output token this dispatch samples (seq.n_emitted at build
    time — 0 for rows still mid-prompt, whose logits nobody reads)."""
    m = buf[row, -SAMP_COLS:]
    m[TEMP] = f32_bits(req.temperature)
    m[TOPK] = int(req.top_k)
    m[TOPP] = f32_bits(req.top_p)
    m[SEED] = int(req.seed)
    m[RID] = int(req.rid)
    m[COUNTER] = int(counter)
    m[EOS] = -1 if req.eos_id is None else int(req.eos_id)
    m[MAXTOK] = int(req.max_tokens)


def unpack_meta(step_buf):
    """Bitcast the trailing SAMP_COLS columns back into per-row arrays
    (inside the jitted step; pure slicing + bitcasts, no data movement).
    All-zero metadata (idle rows) decodes to temperature 0.0 / eos 0 /
    max_tokens 0 — harmless, because the mask guards below and the
    engine never credits tokens from rows it did not schedule."""
    m = step_buf[:, -SAMP_COLS:]
    return {
        "temperature": jax.lax.bitcast_convert_type(m[:, TEMP], jnp.float32),
        "top_k": m[:, TOPK],
        "top_p": jax.lax.bitcast_convert_type(m[:, TOPP], jnp.float32),
        "seed": m[:, SEED],
        "rid": m[:, RID],
        "counter": m[:, COUNTER],
        "eos": m[:, EOS],
        "max_tokens": m[:, MAXTOK],
    }


# ------------------------------------------------------------- keys --
def row_keys(seed, rid, counter):
    """(B,) ints -> (B,) PRNG keys: fold_in(fold_in(PRNGKey(seed), rid),
    counter). A pure function of the request and the emission index, so
    a seeded run replays identically whatever the batch around it did."""

    def one(s, r, c):
        return jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(s), r), c)

    return jax.vmap(one)(seed, rid, counter)


# ---------------------------------------------------------- sampler --
# Static candidate-window bound (cf. TensorRT-LLM's TOP_K_MAX): the
# fused sampler draws from the top TOPK_CAP scaled logits per row, so
# per-row traced top_k/top_p need one O(V log cap) lax.top_k instead of
# a full-vocab sort — on the CPU proxy that is the difference between
# sampled serving riding the greedy step (~1ms extra at 32k vocab) and
# losing 25% of it. top_k requests are clamped to the window; top_k==0
# / top_p==1.0 mean "no tighter truncation than the window".
TOPK_CAP = 256


def _token_gumbel(keys, token_ids):
    """(B,) keys + (B, cap) int32 token ids -> (B, cap) Gumbel noise that
    is a pure function of (row key, token id). Indexing the noise by
    token id — not by the token's rank in the candidate window — is
    what keeps a seeded draw stable when reduction order (TP mesh,
    prefix-cache skips) permutes near-tied candidates."""
    tiny = jnp.finfo(jnp.float32).tiny

    def per_row(key, ids):
        u = jax.vmap(lambda i: jax.random.uniform(
            jax.random.fold_in(key, i), minval=tiny))(ids)
        return -jnp.log(-jnp.log(u))

    return jax.vmap(per_row)(keys, token_ids)


def sample_tokens(logits, temperature, top_k, top_p, keys):
    """Per-row temperature / top-k / top-p sampling over (B, V) f32
    logits; `keys` from `row_keys`. Returns (B,) int32 next tokens.

    Order (shared verbatim by generate() and the fused serve step, so
    the two paths agree token-for-token under one seed): scale by
    temperature, take the top min(V, TOPK_CAP) candidates, keep the
    top-k of them (k == 0 or k >= cap keeps the whole window), keep the
    smallest prefix of the remainder whose cumulative probability
    reaches top_p (the top token always survives; mass is normalized
    over the FULL vocabulary, so top_p means what it says even at the
    window edge), Gumbel-max over what is left. Rows with temperature
    <= 0 bypass all of it and return the raw-logits argmax —
    bit-identical to the greedy path.

    The Gumbel noise is derived per TOKEN ID (`fold_in(key, token)`),
    not per window rank: candidate order inside the window is
    irrelevant, so runs whose logits differ only by reduction order
    (TP mesh sizes, prefix-cache skips) pick the same token unless the
    perturbation flips an actual logit+noise argmax. Rank-indexed noise
    (what `jax.random.categorical` over the window would do) breaks
    exactly that — near-tied bf16 candidates permute across meshes and
    drag the noise with them.

    top_k and top_p are per-row *traced* values, so the one static
    lax.top_k provides both thresholds; that window is the entire extra
    cost of the sampled variant.
    """
    v = logits.shape[-1]
    cap = min(v, TOPK_CAP)
    greedy = temperature <= 0.0
    scaled = logits / jnp.where(greedy, 1.0, temperature)[:, None]
    cand, cand_idx = jax.lax.top_k(scaled, cap)             # (B, cap) desc
    k = jnp.where((top_k <= 0) | (top_k > cap), cap, top_k)     # (B,)
    kth = jnp.take_along_axis(cand, (k - 1)[:, None], axis=-1)
    # top-p inside the top-k survivors, evaluated in sorted space (rank
    # < k), with probabilities normalized over the full vocabulary
    ranks = jnp.arange(cap)[None, :]
    in_k = ranks < k[:, None]
    lse = jax.scipy.special.logsumexp(scaled, axis=-1, keepdims=True)
    probs = jnp.where(in_k, jnp.exp(cand - lse), 0.0)
    before = jnp.cumsum(probs, axis=-1) - probs     # cumulative mass above
    n_keep = jnp.maximum(
        jnp.sum((before < top_p[:, None]) & in_k, axis=-1), 1)
    pth = jnp.take_along_axis(cand, (n_keep - 1)[:, None], axis=-1)
    masked = jnp.where((cand < kth) | (cand < pth), -jnp.inf, cand)
    gumbel = _token_gumbel(keys, cand_idx)
    choice = jnp.argmax(masked + gumbel, axis=-1)
    sampled = jnp.take_along_axis(cand_idx, choice[:, None], axis=-1)[:, 0]
    return jnp.where(greedy, jnp.argmax(logits, axis=-1),
                     sampled).astype(jnp.int32)


# ---------------------------------------------------- stop criteria --
def push_recent(recent, toks):
    """Shift this step's sampled tokens into the per-row ring of the
    last S emissions. Unconditional for every row every step — rows
    that did not emit push garbage, which `finished_mask`'s counter
    guard provably never reads."""
    return jnp.concatenate([recent[:, 1:], toks], axis=1)


def finished_mask(toks, recent, meta, stop_seqs):
    """(B,) int32: 1 where this step's emission finishes the row.

    toks (B,) — this step's sampled tokens; recent (B, S) — the ring
    AFTER `push_recent` (a stop match includes the just-emitted token);
    meta — `unpack_meta` output; stop_seqs (B, NS, S) int32 — each
    row's stop sequences right-aligned with -1 padding on the left.

    A length-l stop matches only when l <= counter + 1: the newest
    counter + 1 ring slots are exactly this request's emitted tokens
    (a decoding row emits every step until it finishes, so nothing
    interleaves), and everything older — the previous occupant's tokens
    or prefill-step garbage — is out of reach without any ring reset.
    eos < 0 disables the eos check; max_tokens <= 0 disables the length
    check (idle rows carry all-zero metadata)."""
    counter = meta["counter"]
    fin = (meta["eos"] >= 0) & (toks == meta["eos"])
    fin |= (meta["max_tokens"] > 0) & (counter + 1 >= meta["max_tokens"])
    pad = stop_seqs < 0                                       # (B, NS, S)
    lens = jnp.sum(~pad, axis=-1)                             # (B, NS)
    hit = (jnp.all(pad | (stop_seqs == recent[:, None, :]), axis=-1)
           & (lens >= 1) & (lens <= counter[:, None] + 1))
    return (fin | jnp.any(hit, axis=-1)).astype(jnp.int32)


def pack_stop_seqs(stops, n_stops: int, max_len: int) -> np.ndarray:
    """Host helper: one row's stop sequences -> (n_stops, max_len) int32,
    right-aligned, -1-padded (the layout `finished_mask` matches
    against). `stops` is a tuple of token-id tuples."""
    out = np.full((n_stops, max_len), -1, np.int32)
    for j, s in enumerate(stops):
        out[j, max_len - len(s):] = np.asarray(s, np.int32)
    return out


def match_stop_host(tokens, eos_id, stops, max_tokens) -> int | None:
    """Numpy oracle for the device stop path: the output length at which
    generation stops (inclusive of the matching token), or None if the
    stream never stops within `tokens`. Same semantics as
    `finished_mask` consumed step-by-step; the speculative serve loop
    (synchronous, tokens already on host) uses it directly and the
    tests diff fused-serve truncation against it."""
    stops = [tuple(int(t) for t in s) for s in (stops or ())]
    for j, t in enumerate(tokens):
        t = int(t)
        if eos_id is not None and t == int(eos_id):
            return j + 1
        for s in stops:
            l = len(s)
            if l and l <= j + 1 and tuple(
                    int(x) for x in tokens[j + 1 - l:j + 1]) == s:
                return j + 1
        if max_tokens is not None and j + 1 >= int(max_tokens):
            return j + 1
    return None
