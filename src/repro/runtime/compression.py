"""Gradient compression for data-parallel all-reduce: int8 quantization
with error feedback (EF-SGD style).

At 1000+ nodes the DP gradient all-reduce is the dominant inter-pod
collective; int8 halves-to-quarters its bytes. Error feedback keeps the
*long-run* gradient unbiased: the residual e of each quantization is added
back before the next one, so convergence matches fp32 (validated on a
quadratic in tests, and available to train.py via --grad-compress).

Usage inside a shard_map'd train step:
    g_q, new_err = compress_with_feedback(g, err)
    g_sync = psum_compressed(g_q, axis_names)     # int8 on the wire
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant_leaf(g: jax.Array):
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_with_feedback(grads, err):
    """Quantize (grads + err) to int8; return (compressed, new_err).

    compressed is a pytree of {"q": int8, "scale": f32[]} mirrors.
    """
    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quant_leaf(gf)
        deq = q.astype(jnp.float32) * scale
        return {"q": q, "scale": scale}, gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return comp, new_err


def psum_compressed(comp, axis_name):
    """All-reduce compressed gradients inside shard_map.

    int8 codes are summed in int32 (wire format stays 8-bit per element;
    the reduction upcast happens on-switch/on-chip), scales are averaged —
    each shard's contribution is dequantized with its own scale bound.
    For exactness we psum q*scale; bytes-on-wire accounting in the roofline
    uses the int8 payload size.
    """
    def leaf(c):
        return jax.lax.psum(c["q"].astype(jnp.float32) * c["scale"],
                            axis_name)

    return jax.tree_util.tree_map(leaf, comp,
                                  is_leaf=lambda x: isinstance(x, dict)
                                  and "q" in x)


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_bytes(params) -> int:
    """Wire bytes per all-reduce with int8 compression (vs 4x for fp32):
    one int8 code per element plus each leaf's fp32 scale — omitting the
    scale payload undercounts wire bytes and skews roofline accounting."""
    return sum(int(p.size) + 4 for p in jax.tree_util.tree_leaves(params))
