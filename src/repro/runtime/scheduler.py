"""Continuous-batching request scheduler (bookkeeping only, no compute).

Production serving never sees rectangular batches: requests arrive at
arbitrary times with arbitrary prompt/output lengths. The standard answer
(TensorRT-LLM "inflight batching", vLLM) is a shared decode batch that
gains a row the moment a request is admitted and loses it the moment the
request finishes — the GPU never idles waiting for the longest row. This
module is the policy half of that loop:

  * `Request`  — what a caller submits: prompt tokens + max_tokens (per
    request; a mixed workload is the whole point);
  * `Sequence` — a request bound to a decode row and a set of KV blocks;
  * `Scheduler` — FCFS waiting queue + admission + eviction. A request is
    admitted when a batch row is free AND the `BlockPool` can reserve its
    *worst-case* block count up front (prompt + every generated token), so
    a running sequence can never be starved of cache mid-decode and
    overflow queues instead of crashing.

Admission is strictly FCFS: if the head request does not fit, later ones
do not jump it (no starvation of long prompts). The compute half — prefill
into blocks, the masked fixed-capacity decode step — lives in
`api.InferenceEngine.serve`, which drives this object step by step;
`runtime.kvblocks` owns the cache layout. The scheduler itself touches no
jax arrays, which is what makes it unit-testable under random admit/evict
sequences (see tests/test_scheduler.py).
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.runtime.kvblocks import BlockPool, blocks_needed


@dataclasses.dataclass
class Request:
    """One generation request. max_tokens=None defers to the engine-level
    SamplingParams; rid is assigned by the engine (submission order)."""

    tokens: np.ndarray
    max_tokens: int | None = None
    rid: int | None = None

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError("empty prompt")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")


@dataclasses.dataclass
class Sequence:
    """A live request: bound to decode row `row`, owning `block_ids`."""

    req: Request
    row: int
    block_ids: list[int]
    out: list[int] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.req.tokens.size)

    @property
    def max_tokens(self) -> int:
        return int(self.req.max_tokens)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_tokens


class Scheduler:
    """FCFS admission over `max_batch` decode rows and a `BlockPool`."""

    def __init__(self, pool: BlockPool, max_batch: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.pool = pool
        self.max_batch = max_batch
        self.waiting: collections.deque[Request] = collections.deque()
        self.rows: list[Sequence | None] = [None] * max_batch
        self.max_queue_depth = 0

    # ------------------------------------------------------------ submit --
    def submit(self, req: Request) -> None:
        """Queue a request. Raises if it can never fit the pool (worst-case
        block need exceeds total capacity) — that is a config error, not a
        load condition."""
        if req.max_tokens is None:
            raise ValueError(
                "request max_tokens is unresolved (None); fill it in before "
                "submitting — engine.serve resolves it from SamplingParams")
        need = blocks_needed(req.tokens.size, req.max_tokens,
                             self.pool.block_size)
        if need > self.pool.capacity:
            raise ValueError(
                f"request rid={req.rid} needs {need} KV blocks but the pool "
                f"only has {self.pool.capacity}; raise num_blocks or "
                f"block_size")
        self.waiting.append(req)
        self.max_queue_depth = max(self.max_queue_depth, len(self.waiting))

    # --------------------------------------------------------- admission --
    def _free_row(self) -> int | None:
        for i, s in enumerate(self.rows):
            if s is None:
                return i
        return None

    def try_admit(self) -> Sequence | None:
        """Admit the head-of-queue request if a row is free and its full
        block budget is available; None when nothing is admissible now."""
        if not self.waiting:
            return None
        row = self._free_row()
        if row is None:
            return None
        req = self.waiting[0]
        need = blocks_needed(req.tokens.size, req.max_tokens,
                             self.pool.block_size)
        if not self.pool.can_alloc(need):
            return None
        self.waiting.popleft()
        seq = Sequence(req=req, row=row, block_ids=self.pool.alloc(need))
        self.rows[row] = seq
        return seq

    # ---------------------------------------------------------- eviction --
    def finish(self, seq: Sequence) -> None:
        """Retire a sequence: release its blocks and free its row."""
        self.pool.free(seq.block_ids)
        seq.block_ids = []
        self.rows[seq.row] = None

    # ------------------------------------------------------------- state --
    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.rows)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.num_active > 0
