"""Continuous-batching request scheduler (bookkeeping only, no compute).

Production serving never sees rectangular batches: requests arrive at
arbitrary times with arbitrary prompt/output lengths. The standard answer
(TensorRT-LLM "inflight batching" with chunked prefill, vLLM) is a shared
batch that gains a row the moment a request is admitted and loses it the
moment the request finishes — and whose every step mixes prefill *chunks*
of newly admitted prompts with in-flight decode tokens under one token
budget, so admissions never stall the batch. This module is the policy
half of that loop:

  * `Request`  — what a caller submits: prompt tokens + max_tokens (per
    request; a mixed workload is the whole point);
  * `Sequence` — a request bound to a batch row and a set of KV blocks,
    tracking how much of its prompt has been chunk-prefilled;
  * `Scheduler` — FCFS waiting queue + admission + eviction, plus
    `schedule(token_budget)`: the per-step work plan (`ScheduleOutput`)
    naming which rows get a prefill chunk and which a decode token. A
    request is admitted when a batch row is free AND the `BlockPool` can
    reserve its *worst-case* block count up front (prompt + every
    generated token), so a running sequence can never be starved of cache
    mid-decode and overflow queues instead of crashing.

Admission is strictly FCFS: if the head request does not fit, later ones
do not jump it (no starvation of long prompts); within a step, decode
rows claim budget first (they always advance), then prefilling rows
receive chunks oldest-first. The compute half — the unified token-budget
step — lives in `api.InferenceEngine.serve`, which drives this object
step by step; `runtime.kvblocks` owns the cache layout. The scheduler
itself touches no jax arrays, which is what makes it unit-testable under
random admit/evict sequences (see tests/test_scheduler.py).

Two relaxations of plain FCFS-with-worst-case-reservation:

  * Prefix caching (`prefix_cache=True`): admission digests the prompt's
    full blocks (`kvblocks.prefix_digests`), walks the pool's content
    index for the longest cached position-aligned prefix, maps those
    blocks into the block table *by reference* (refcount++), charges the
    pool only for the new blocks, and starts chunked prefill at the
    first uncached position. A prompt whose every block is cached still
    needs the logits of its last position, so its final block is
    copy-on-write: share all but the last matched block, allocate a
    private `cow_dst`, and have the engine device-copy `cow_src`→
    `cow_dst` before the next dispatch (prefill then recomputes exactly
    position prompt_len-1 — bit-identical K/V, private block). Completed
    full prompt blocks are registered back into the index by
    `advance_prefill` as chunked prefill crosses each block boundary.
    Shared blocks are always the leading `n_shared` table entries and
    writes only ever target positions >= prefilled >= n_shared*bs, so
    no sequence — speculative rollback included — can touch a block
    another sequence holds.

  * Pool-pressure preemption: when the head request cannot be admitted
    even though a row is free (the pool cannot give enough blocks after
    evicting every refcount-0 cached block), the scheduler preempts the
    newest zero-output sequence(s) (policy: `runtime.elastic`), frees
    their blocks, admits the head, and requeues each victim's request
    immediately behind it. Victims are only taken when the arithmetic
    proves the head then fits, and a request yields at most once, so
    preemption always makes forward progress.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.runtime import elastic
from repro.runtime.kvblocks import (BlockPool, blocks_for_positions,
                                    blocks_needed, prefix_digests)


@dataclasses.dataclass
class Request:
    """One generation request. max_tokens=None defers to the engine-level
    SamplingParams; rid is assigned by the engine (submission order).
    `requeued` is set by pool-pressure preemption — a request yields its
    blocks at most once.

    Per-request sampling / stop controls are plain fields (floats, ints,
    tuples — this module must stay jax-free) with None meaning "defer to
    the engine-level SamplingParams"; `engine.serve` resolves every
    field to a concrete value before `submit`. temperature <= 0 is
    greedy; `stop` is a tuple of token-id tuples matched inclusively
    (the matching tokens stay in the output)."""

    tokens: np.ndarray
    max_tokens: int | None = None
    rid: int | None = None
    requeued: bool = False
    temperature: float | None = None
    top_k: int | None = None
    top_p: float | None = None
    seed: int | None = None
    eos_id: int | None = None
    stop: tuple = ()

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError("empty prompt")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.top_k is not None and self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.top_p is not None and not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.eos_id is not None and self.eos_id < 0:
            raise ValueError(f"eos_id must be >= 0, got {self.eos_id}")
        self.stop = tuple(tuple(int(t) for t in s) for s in self.stop)
        if any(len(s) == 0 for s in self.stop):
            raise ValueError("empty stop sequence")


@dataclasses.dataclass
class Sequence:
    """A live request: bound to batch row `row`, owning `block_ids`.
    `prefilled` counts prompt tokens already written to the KV pool by
    chunked prefill; the row decodes once the whole prompt is in.
    `n_emitted` counts output tokens the engine has *dispatched* for this
    row — a count, not values: with per-request max_tokens and no early
    stopping, scheduling never depends on what the tokens turn out to
    be, which is what lets the engine pipeline steps without waiting for
    device results."""

    req: Request
    row: int
    block_ids: list[int]
    prefilled: int = 0
    n_emitted: int = 0
    # KV blocks provisionally allocated for a speculative draft span
    # beyond the row's committed holdings (tail of block_ids, position
    # order). Rolled back by commit_speculation after verify; empty
    # whenever admission reserved the worst case up front.
    draft_blocks: list[int] = dataclasses.field(default_factory=list)
    # --- prefix-cache bookkeeping (all zero/empty with the cache off) ---
    # leading block_ids entries mapped by reference from the content
    # index; this row never writes them (its writes start at position
    # prefilled >= n_shared * block_size)
    n_shared: int = 0
    # chained digests of the prompt's full blocks (kvblocks.prefix_digests)
    digests: list[bytes] = dataclasses.field(default_factory=list)
    # pending copy-on-write: the engine device-copies cow_src -> cow_dst
    # before the next dispatch, then releases the cow_src pin. Set only
    # for fully-cached prompts (the last matched block must be rewritten
    # privately so its final position's logits can be recomputed).
    cow_src: int | None = None
    cow_dst: int | None = None
    # next full prompt-block index advance_prefill may register
    reg_next: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.req.tokens.size)

    @property
    def max_tokens(self) -> int:
        return int(self.req.max_tokens)

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prompt_len

    @property
    def done(self) -> bool:
        return self.n_emitted >= self.max_tokens

    @property
    def sampled(self) -> bool:
        """True when this row decodes with temperature > 0. Sampled rows
        never draft: greedy speculative acceptance verifies an argmax
        chain, which a stochastic target makes worthless (acceptance
        would be the chance the sample equals the argmax)."""
        t = self.req.temperature
        return t is not None and t > 0.0


@dataclasses.dataclass
class ScheduleOutput:
    """One step's work plan under the token budget: which rows run a
    prefill chunk (and how wide), which rows decode one token, and what
    was newly admitted this step (rows whose block tables the engine
    must install before the forward pass)."""

    admitted: list[Sequence]
    prefill: dict[int, int]       # row -> prompt-chunk width this step
    decode: list[int]             # rows advancing by one decode token
    # row -> draft tokens to speculate this step (subset of decode rows;
    # the row's verify span is 1 + spec[row] wide). Empty dict when
    # speculation is off or no budget was left for it.
    spec: dict[int, int] = dataclasses.field(default_factory=dict)
    # rows whose sequence was preempted under pool pressure this step —
    # the engine must reset their block tables to trash before the next
    # dispatch (then install any admitted sequence that reuses the row)
    preempted: list[int] = dataclasses.field(default_factory=list)

    @property
    def total_tokens(self) -> int:
        return (sum(self.prefill.values()) + len(self.decode)
                + sum(self.spec.values()))

    @property
    def max_span(self) -> int:
        """Widest per-row span this step (the forward pass's W)."""
        d = 0
        if self.decode:
            d = 1 + max((self.spec.get(r, 0) for r in self.decode),
                        default=0)
        return max(max(self.prefill.values(), default=0), d)

    @property
    def is_mixed(self) -> bool:
        return bool(self.prefill) and bool(self.decode)


class Scheduler:
    """FCFS admission over `max_batch` batch rows and a `BlockPool`,
    optionally with prefix-cache sharing and pool-pressure preemption."""

    def __init__(self, pool: BlockPool, max_batch: int, *,
                 prefix_cache: bool = False, fingerprint: bytes = b"",
                 preempt: bool = True):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.pool = pool
        self.max_batch = max_batch
        self.prefix_cache = prefix_cache
        self.fingerprint = fingerprint
        self.preempt_under_pressure = preempt
        self.waiting: collections.deque[Request] = collections.deque()
        self.rows: list[Sequence | None] = [None] * max_batch
        self.max_queue_depth = 0
        # prefix-cache / preemption counters (ServeResult surfaces these)
        self.cache_lookup_blocks = 0
        self.cache_hit_blocks = 0
        self.cache_hit_tokens = 0
        self.cache_cow_blocks = 0
        self.preemptions = 0

    # ------------------------------------------------------------ submit --
    def submit(self, req: Request) -> None:
        """Queue a request. Raises if it can never fit the pool (worst-case
        block need exceeds total capacity) — that is a config error, not a
        load condition."""
        if req.max_tokens is None:
            raise ValueError(
                "request max_tokens is unresolved (None); fill it in before "
                "submitting — engine.serve resolves it from SamplingParams")
        need = blocks_needed(req.tokens.size, req.max_tokens,
                             self.pool.block_size)
        if need > self.pool.capacity:
            raise ValueError(
                f"request rid={req.rid} needs {need} KV blocks but the pool "
                f"only has {self.pool.capacity}; raise num_blocks or "
                f"block_size")
        self.waiting.append(req)
        self.max_queue_depth = max(self.max_queue_depth, len(self.waiting))

    # --------------------------------------------------------- admission --
    def _free_row(self) -> int | None:
        for i, s in enumerate(self.rows):
            if s is None:
                return i
        return None

    def _request_digests(self, req: Request) -> list[bytes]:
        """Chained full-block digests of a prompt, memoized on the
        request (a preempted request keeps its digests across requeue)."""
        if not self.prefix_cache:
            return []
        cached = getattr(req, "_prefix_digests", None)
        if cached is None:
            cached = prefix_digests(req.tokens, self.pool.block_size,
                                    self.fingerprint)
            req._prefix_digests = cached
        return cached

    def _match_prefix(self, req: Request):
        """(digests, n_hit, cow): longest cached position-aligned prefix
        of `req`'s full blocks, and whether admission must copy-on-write
        (every block cached — the final block is shared as a COW source,
        not mapped, so position prompt_len-1 can be recomputed for its
        logits into a private copy)."""
        digests = self._request_digests(req)
        n_hit = 0
        for d in digests:
            if self.pool.lookup(d) is None:
                break
            n_hit += 1
        cow = n_hit > 0 and n_hit * self.pool.block_size >= req.tokens.size
        return digests, n_hit, cow

    def try_admit(self) -> Sequence | None:
        """Admit the head-of-queue request if a row is free and its block
        budget is available; None when nothing is admissible now. With
        prefix caching on, cached full prompt blocks are mapped by
        reference and only the remaining blocks are charged to the
        pool."""
        if not self.waiting:
            return None
        row = self._free_row()
        if row is None:
            return None
        req = self.waiting[0]
        need = blocks_needed(req.tokens.size, req.max_tokens,
                             self.pool.block_size)
        digests, n_hit, cow = self._match_prefix(req)
        n_share = n_hit - 1 if cow else n_hit
        # Pin the matched blocks first: a share revives idle cached
        # blocks, so the availability check below no longer counts them.
        shared = [self.pool.share(d) for d in digests[:n_share]]
        cow_src = self.pool.share(digests[n_hit - 1]) if cow else None
        new_need = need - n_share
        if not self.pool.can_alloc(new_need):
            self.pool.free(shared)              # unwind; head stays queued
            if cow_src is not None:
                self.pool.free([cow_src])
            return None
        self.waiting.popleft()
        new_ids = self.pool.alloc(new_need)
        bs = self.pool.block_size
        seq = Sequence(
            req=req, row=row, block_ids=shared + new_ids,
            prefilled=req.tokens.size - 1 if cow else n_share * bs,
            n_shared=n_share, digests=digests,
            cow_src=cow_src, cow_dst=new_ids[0] if cow else None,
            reg_next=n_hit)
        self.rows[row] = seq
        self.cache_lookup_blocks += min(n_hit + 1, len(digests))
        self.cache_hit_blocks += n_hit
        self.cache_hit_tokens += seq.prefilled
        self.cache_cow_blocks += int(cow)
        return seq

    def advance_prefill(self, seq: Sequence, width: int) -> None:
        """Record `width` more prompt tokens written to the pool, and
        register each newly completed full prompt block into the content
        index (first writer wins; blocks this row itself mapped from the
        cache are skipped via `reg_next`). The engine calls this exactly
        when it dispatches the row's prefill chunk — device-stream order
        then guarantees any later admission reading the block runs after
        the write."""
        seq.prefilled += width
        if not self.prefix_cache:
            return
        bs = self.pool.block_size
        n_full = min(len(seq.digests), seq.prompt_len // bs)
        while (seq.reg_next < n_full
               and (seq.reg_next + 1) * bs <= seq.prefilled):
            self.pool.register(seq.block_ids[seq.reg_next],
                               seq.digests[seq.reg_next])
            seq.reg_next += 1

    def release_cow(self, seq: Sequence) -> None:
        """Drop the copy-on-write source pin once the engine has
        dispatched the device copy into `seq.cow_dst`."""
        if seq.cow_src is not None:
            self.pool.free([seq.cow_src])
            seq.cow_src = None

    # ---------------------------------------------------------- schedule --
    def schedule(self, token_budget: int, spec_k: int = 0) -> ScheduleOutput:
        """Plan one unified step: admit FCFS, then split `token_budget`
        tokens across the active rows. Decode rows (prompt fully in the
        pool, request unfinished) always advance — one token each, even
        when prefill chunks run in the same step — then the remaining
        budget is dealt to prefilling rows as prompt chunks of at most
        ceil(budget / #prefilling) tokens each, oldest-first. The
        balanced cap matters because the forward pass is a rectangular
        (rows, max_span) batch: one row hogging the budget widens every
        other row's padding, while even chunks keep the span — and the
        step's compute — near the useful-token count. Budget a
        short-remaining row leaves unused simply idles this step; the
        next step re-budgets from scratch.

        spec_k > 0 offers each decode row up to spec_k speculative draft
        tokens out of whatever budget prefill chunks left over — drafts
        rank below admission latency, so speculation ramps up exactly
        when the batch turns decode-bound (where it pays). Per-row
        grants are clamped by `reserve_speculation` (never past the
        request's final token, never past the block pool)."""
        if token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        admitted = []
        while (seq := self.try_admit()) is not None:
            admitted.append(seq)
        preempted_rows: list[int] = []
        if (self.preempt_under_pressure and not admitted and self.waiting
                and self._free_row() is not None):
            preempted_rows = self._preempt_for_head()
            if preempted_rows:
                while (seq := self.try_admit()) is not None:
                    admitted.append(seq)
        live = [s for s in self.rows if s is not None]
        decoding = [s for s in live if s.prefill_done and not s.done]
        decode = [s.row for s in decoding]
        budget = max(0, token_budget - len(decode))
        prefill: dict[int, int] = {}
        filling = sorted((s for s in live if not s.prefill_done),
                         key=lambda s: (s.req.rid is None, s.req.rid, s.row))
        if filling and budget > 0:
            cap = -(-budget // len(filling))
            for seq in filling:
                chunk = min(seq.prompt_len - seq.prefilled, cap, budget)
                if chunk > 0:
                    prefill[seq.row] = chunk
                    budget -= chunk
        spec: dict[int, int] = {}
        if spec_k > 0:
            for seq in decoding:
                if budget <= 0:
                    break
                if seq.sampled:     # sampled rows never draft (greedy
                    continue        # acceptance verifies argmax chains)
                kr = self.reserve_speculation(seq, min(spec_k, budget))
                if kr > 0:
                    spec[seq.row] = kr
                    budget -= kr
        return ScheduleOutput(admitted=admitted, prefill=prefill,
                              decode=decode, spec=spec,
                              preempted=preempted_rows)

    # --------------------------------------------------------- preemption --
    def _preempt_for_head(self) -> list[int]:
        """Preempt the fewest newest zero-output sequences whose freed
        blocks provably let the head request admit; [] (and no side
        effects) when no victim set suffices. Victim policy lives in
        runtime.elastic; freeing victims only grows the cache, so the
        head's block need computed here can only shrink by admission
        time — the fit check is conservative."""
        req = self.waiting[0]
        need = blocks_needed(req.tokens.size, req.max_tokens,
                             self.pool.block_size)
        _, n_hit, cow = self._match_prefix(req)
        need_new = need - (n_hit - 1 if cow else n_hit)
        if self.pool.can_alloc(need_new):
            return []                # head admissible; nothing to preempt
        gain = 0
        chosen = []
        for victim in elastic.preemption_victims(self.rows):
            gain += elastic.reclaimable_blocks(self.pool, victim)
            chosen.append(victim)
            if self.pool.available + gain >= need_new:
                break
        else:
            return []          # even preempting every candidate won't fit
        rows = []
        for victim in chosen:
            self.preempt(victim)
            rows.append(victim.row)
        return rows

    def preempt(self, seq: Sequence) -> None:
        """Evict a zero-output sequence mid-prefill: free its blocks (and
        COW pin), clear its row, and requeue its request just behind the
        current queue head (the request it yields to). Its registered
        prompt blocks stay in the content index as idle cached blocks, so
        re-admission typically resumes from the last registered block
        rather than from scratch."""
        if seq.n_emitted:
            raise ValueError(
                f"cannot preempt rid={seq.req.rid}: it has emitted "
                f"{seq.n_emitted} tokens (only zero-output rows preempt)")
        if seq.cow_src is not None:
            self.pool.free([seq.cow_src])
            seq.cow_src = None
        self.pool.free(seq.block_ids)
        seq.block_ids = []
        self.rows[seq.row] = None
        seq.req.requeued = True
        self.waiting.insert(min(1, len(self.waiting)), seq.req)
        self.max_queue_depth = max(self.max_queue_depth, len(self.waiting))
        self.preemptions += 1

    # ------------------------------------------------------- speculation --
    def reserve_speculation(self, seq: Sequence, k: int) -> int:
        """Clamp a draft offer to what the row can legally speculate and
        provisionally allocate any KV blocks the draft span needs beyond
        the row's current holdings. The clamp `k <= remaining - 1` keeps
        the (k+1)-wide verify span from writing past position
        prompt_len + max_tokens - 2 — inside the admission-time
        worst-case reservation AND the static block-table width, so a
        fully-accepted round never outruns either. Returns the granted k
        (possibly shrunk to what the pool can back); newly allocated
        blocks are recorded in `seq.draft_blocks` as the rollback
        watermark for commit_speculation."""
        k = max(0, min(int(k), seq.max_tokens - seq.n_emitted - 1))
        while k > 0:
            # last pool position the verify span writes: the span covers
            # [C, C + k] and caches all but its newest token
            end = seq.prompt_len + seq.n_emitted - 1 + k
            need = (blocks_for_positions(end + 1, self.pool.block_size)
                    - len(seq.block_ids))
            if need <= 0:
                return k
            if self.pool.can_alloc(need):
                got = self.pool.alloc(need)
                seq.block_ids.extend(got)
                seq.draft_blocks.extend(got)
                return k
            k -= 1          # shrink the draft until the pool can back it
        return 0

    def commit_speculation(self, seq: Sequence) -> list[int]:
        """Accept/reject rollback after a verify: with `seq.n_emitted`
        already advanced by the accepted tokens, free every provisional
        draft block the committed context does not reach. Draft blocks
        the accepted prefix DID reach become permanent holdings; the
        rollback never releases below the row's pre-draft holdings (the
        admission-time worst case, when it was reservable) and can never
        touch the reserved trash block 0 (the pool never hands it out).
        Returns the released block ids. Rejected positions need no data
        rewind: span reads mask to `slot <= position` so stale K/V past
        the committed context is never read, and the next span's
        write-then-attend overwrites it."""
        if not seq.draft_blocks:
            return []
        base = len(seq.block_ids) - len(seq.draft_blocks)
        committed = max(seq.prompt_len + seq.n_emitted - 1, 0)
        keep = max(blocks_for_positions(committed, self.pool.block_size),
                   base)
        released = seq.block_ids[keep:]
        seq.block_ids = seq.block_ids[:keep]
        seq.draft_blocks = []
        self.pool.free(released)
        return released

    # ---------------------------------------------------------- eviction --
    def finish(self, seq: Sequence) -> None:
        """Retire a sequence: release its blocks (refcount decrement —
        shared prefix blocks stay resident for their other holders, and
        this row's registered blocks go idle-cached) and free its row."""
        if seq.cow_src is not None:        # finished before the COW copy
            self.pool.free([seq.cow_src])  # was dispatched (engine bug
            seq.cow_src = None             # guard; normally released)
        self.pool.free(seq.block_ids)
        seq.block_ids = []
        self.rows[seq.row] = None

    # ------------------------------------------------------------- state --
    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.rows)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.num_active > 0
