"""Continuous-batching request scheduler (bookkeeping only, no compute).

Production serving never sees rectangular batches: requests arrive at
arbitrary times with arbitrary prompt/output lengths. The standard answer
(TensorRT-LLM "inflight batching" with chunked prefill, vLLM) is a shared
batch that gains a row the moment a request is admitted and loses it the
moment the request finishes — and whose every step mixes prefill *chunks*
of newly admitted prompts with in-flight decode tokens under one token
budget, so admissions never stall the batch. This module is the policy
half of that loop:

  * `Request`  — what a caller submits: prompt tokens + max_tokens (per
    request; a mixed workload is the whole point);
  * `Sequence` — a request bound to a batch row and a set of KV blocks,
    tracking how much of its prompt has been chunk-prefilled;
  * `Scheduler` — FCFS waiting queue + admission + eviction, plus
    `schedule(token_budget)`: the per-step work plan (`ScheduleOutput`)
    naming which rows get a prefill chunk and which a decode token. A
    request is admitted when a batch row is free AND the `BlockPool` can
    reserve its *worst-case* block count up front (prompt + every
    generated token), so a running sequence can never be starved of cache
    mid-decode and overflow queues instead of crashing.

Admission is strictly FCFS: if the head request does not fit, later ones
do not jump it (no starvation of long prompts); within a step, decode
rows claim budget first (they always advance), then prefilling rows
receive chunks oldest-first. The compute half — the unified token-budget
step — lives in `api.InferenceEngine.serve`, which drives this object
step by step; `runtime.kvblocks` owns the cache layout. The scheduler
itself touches no jax arrays, which is what makes it unit-testable under
random admit/evict sequences (see tests/test_scheduler.py).
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.runtime.kvblocks import (BlockPool, blocks_for_positions,
                                    blocks_needed)


@dataclasses.dataclass
class Request:
    """One generation request. max_tokens=None defers to the engine-level
    SamplingParams; rid is assigned by the engine (submission order)."""

    tokens: np.ndarray
    max_tokens: int | None = None
    rid: int | None = None

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError("empty prompt")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")


@dataclasses.dataclass
class Sequence:
    """A live request: bound to batch row `row`, owning `block_ids`.
    `prefilled` counts prompt tokens already written to the KV pool by
    chunked prefill; the row decodes once the whole prompt is in.
    `n_emitted` counts output tokens the engine has *dispatched* for this
    row — a count, not values: with per-request max_tokens and no early
    stopping, scheduling never depends on what the tokens turn out to
    be, which is what lets the engine pipeline steps without waiting for
    device results."""

    req: Request
    row: int
    block_ids: list[int]
    prefilled: int = 0
    n_emitted: int = 0
    # KV blocks provisionally allocated for a speculative draft span
    # beyond the row's committed holdings (tail of block_ids, position
    # order). Rolled back by commit_speculation after verify; empty
    # whenever admission reserved the worst case up front.
    draft_blocks: list[int] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.req.tokens.size)

    @property
    def max_tokens(self) -> int:
        return int(self.req.max_tokens)

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prompt_len

    @property
    def done(self) -> bool:
        return self.n_emitted >= self.max_tokens


@dataclasses.dataclass
class ScheduleOutput:
    """One step's work plan under the token budget: which rows run a
    prefill chunk (and how wide), which rows decode one token, and what
    was newly admitted this step (rows whose block tables the engine
    must install before the forward pass)."""

    admitted: list[Sequence]
    prefill: dict[int, int]       # row -> prompt-chunk width this step
    decode: list[int]             # rows advancing by one decode token
    # row -> draft tokens to speculate this step (subset of decode rows;
    # the row's verify span is 1 + spec[row] wide). Empty dict when
    # speculation is off or no budget was left for it.
    spec: dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def total_tokens(self) -> int:
        return (sum(self.prefill.values()) + len(self.decode)
                + sum(self.spec.values()))

    @property
    def max_span(self) -> int:
        """Widest per-row span this step (the forward pass's W)."""
        d = 0
        if self.decode:
            d = 1 + max((self.spec.get(r, 0) for r in self.decode),
                        default=0)
        return max(max(self.prefill.values(), default=0), d)

    @property
    def is_mixed(self) -> bool:
        return bool(self.prefill) and bool(self.decode)


class Scheduler:
    """FCFS admission over `max_batch` batch rows and a `BlockPool`."""

    def __init__(self, pool: BlockPool, max_batch: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.pool = pool
        self.max_batch = max_batch
        self.waiting: collections.deque[Request] = collections.deque()
        self.rows: list[Sequence | None] = [None] * max_batch
        self.max_queue_depth = 0

    # ------------------------------------------------------------ submit --
    def submit(self, req: Request) -> None:
        """Queue a request. Raises if it can never fit the pool (worst-case
        block need exceeds total capacity) — that is a config error, not a
        load condition."""
        if req.max_tokens is None:
            raise ValueError(
                "request max_tokens is unresolved (None); fill it in before "
                "submitting — engine.serve resolves it from SamplingParams")
        need = blocks_needed(req.tokens.size, req.max_tokens,
                             self.pool.block_size)
        if need > self.pool.capacity:
            raise ValueError(
                f"request rid={req.rid} needs {need} KV blocks but the pool "
                f"only has {self.pool.capacity}; raise num_blocks or "
                f"block_size")
        self.waiting.append(req)
        self.max_queue_depth = max(self.max_queue_depth, len(self.waiting))

    # --------------------------------------------------------- admission --
    def _free_row(self) -> int | None:
        for i, s in enumerate(self.rows):
            if s is None:
                return i
        return None

    def try_admit(self) -> Sequence | None:
        """Admit the head-of-queue request if a row is free and its full
        block budget is available; None when nothing is admissible now."""
        if not self.waiting:
            return None
        row = self._free_row()
        if row is None:
            return None
        req = self.waiting[0]
        need = blocks_needed(req.tokens.size, req.max_tokens,
                             self.pool.block_size)
        if not self.pool.can_alloc(need):
            return None
        self.waiting.popleft()
        seq = Sequence(req=req, row=row, block_ids=self.pool.alloc(need))
        self.rows[row] = seq
        return seq

    # ---------------------------------------------------------- schedule --
    def schedule(self, token_budget: int, spec_k: int = 0) -> ScheduleOutput:
        """Plan one unified step: admit FCFS, then split `token_budget`
        tokens across the active rows. Decode rows (prompt fully in the
        pool, request unfinished) always advance — one token each, even
        when prefill chunks run in the same step — then the remaining
        budget is dealt to prefilling rows as prompt chunks of at most
        ceil(budget / #prefilling) tokens each, oldest-first. The
        balanced cap matters because the forward pass is a rectangular
        (rows, max_span) batch: one row hogging the budget widens every
        other row's padding, while even chunks keep the span — and the
        step's compute — near the useful-token count. Budget a
        short-remaining row leaves unused simply idles this step; the
        next step re-budgets from scratch.

        spec_k > 0 offers each decode row up to spec_k speculative draft
        tokens out of whatever budget prefill chunks left over — drafts
        rank below admission latency, so speculation ramps up exactly
        when the batch turns decode-bound (where it pays). Per-row
        grants are clamped by `reserve_speculation` (never past the
        request's final token, never past the block pool)."""
        if token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        admitted = []
        while (seq := self.try_admit()) is not None:
            admitted.append(seq)
        live = [s for s in self.rows if s is not None]
        decoding = [s for s in live if s.prefill_done and not s.done]
        decode = [s.row for s in decoding]
        budget = max(0, token_budget - len(decode))
        prefill: dict[int, int] = {}
        filling = sorted((s for s in live if not s.prefill_done),
                         key=lambda s: (s.req.rid is None, s.req.rid, s.row))
        if filling and budget > 0:
            cap = -(-budget // len(filling))
            for seq in filling:
                chunk = min(seq.prompt_len - seq.prefilled, cap, budget)
                if chunk > 0:
                    prefill[seq.row] = chunk
                    budget -= chunk
        spec: dict[int, int] = {}
        if spec_k > 0:
            for seq in decoding:
                if budget <= 0:
                    break
                kr = self.reserve_speculation(seq, min(spec_k, budget))
                if kr > 0:
                    spec[seq.row] = kr
                    budget -= kr
        return ScheduleOutput(admitted=admitted, prefill=prefill,
                              decode=decode, spec=spec)

    # ------------------------------------------------------- speculation --
    def reserve_speculation(self, seq: Sequence, k: int) -> int:
        """Clamp a draft offer to what the row can legally speculate and
        provisionally allocate any KV blocks the draft span needs beyond
        the row's current holdings. The clamp `k <= remaining - 1` keeps
        the (k+1)-wide verify span from writing past position
        prompt_len + max_tokens - 2 — inside the admission-time
        worst-case reservation AND the static block-table width, so a
        fully-accepted round never outruns either. Returns the granted k
        (possibly shrunk to what the pool can back); newly allocated
        blocks are recorded in `seq.draft_blocks` as the rollback
        watermark for commit_speculation."""
        k = max(0, min(int(k), seq.max_tokens - seq.n_emitted - 1))
        while k > 0:
            # last pool position the verify span writes: the span covers
            # [C, C + k] and caches all but its newest token
            end = seq.prompt_len + seq.n_emitted - 1 + k
            need = (blocks_for_positions(end + 1, self.pool.block_size)
                    - len(seq.block_ids))
            if need <= 0:
                return k
            if self.pool.can_alloc(need):
                got = self.pool.alloc(need)
                seq.block_ids.extend(got)
                seq.draft_blocks.extend(got)
                return k
            k -= 1          # shrink the draft until the pool can back it
        return 0

    def commit_speculation(self, seq: Sequence) -> list[int]:
        """Accept/reject rollback after a verify: with `seq.n_emitted`
        already advanced by the accepted tokens, free every provisional
        draft block the committed context does not reach. Draft blocks
        the accepted prefix DID reach become permanent holdings; the
        rollback never releases below the row's pre-draft holdings (the
        admission-time worst case, when it was reservable) and can never
        touch the reserved trash block 0 (the pool never hands it out).
        Returns the released block ids. Rejected positions need no data
        rewind: span reads mask to `slot <= position` so stale K/V past
        the committed context is never read, and the next span's
        write-then-attend overwrites it."""
        if not seq.draft_blocks:
            return []
        base = len(seq.block_ids) - len(seq.draft_blocks)
        committed = max(seq.prompt_len + seq.n_emitted - 1, 0)
        keep = max(blocks_for_positions(committed, self.pool.block_size),
                   base)
        released = seq.block_ids[keep:]
        seq.block_ids = seq.block_ids[:keep]
        seq.draft_blocks = []
        self.pool.free(released)
        return released

    # ---------------------------------------------------------- eviction --
    def finish(self, seq: Sequence) -> None:
        """Retire a sequence: release its blocks and free its row."""
        self.pool.free(seq.block_ids)
        seq.block_ids = []
        self.rows[seq.row] = None

    # ------------------------------------------------------------- state --
    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.rows)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.num_active > 0
