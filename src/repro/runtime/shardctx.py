"""Logical-axis sharding context.

Model code never mentions meshes: it calls `maybe_shard(x, "batch", None,
"model")` with *logical* axis names. When a mesh is installed (launcher /
dry-run) the names resolve to physical mesh axes and become
with_sharding_constraint; with no mesh installed (unit tests, CPU smoke
runs) the call is a no-op.

Logical -> physical:
  batch  -> ("pod", "data") on a multi-pod mesh, ("data",) single-pod
  model  -> ("model",)
  data   -> ("data",)
  None   -> unsharded

Two distinct mechanisms live here, and they are never active together:

  * the GSPMD context (`use_mesh` + `maybe_shard`) — whole-array
    programs, the compiler partitions; used by training and `generate`.
  * the shard_map tensor-parallel context (`tp_axis` + `psum_tp`) —
    per-shard programs for the serving step: `api.engine` wraps
    `transformer.unified_step` in shard_map and binds the mesh axis the
    layer boundaries must all-reduce over; model code calls `psum_tp` at
    exactly the attention-output and MLP-output boundaries, which is the
    identity when no TP axis is bound (single-device serving, training,
    unit tests). Inside a shard_map body the GSPMD mesh must NOT be
    installed — `maybe_shard` constraints are meaningless over manual
    axes — so the serve loop leaves `_MESH` unset on the TP path.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH: jax.sharding.Mesh | None = None
_TP_AXIS: str | None = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _MESH = prev


@contextlib.contextmanager
def tp_axis(name: str):
    """Bind `name` (a shard_map mesh axis, normally "model") as the
    tensor-parallel all-reduce axis while the wrapped model code traces.
    The binding is consulted at trace time, so it must wrap the *body*
    passed to shard_map — the psums it enables become part of the jaxpr
    and survive jit caching."""
    global _TP_AXIS
    prev = _TP_AXIS
    _TP_AXIS = name
    try:
        yield
    finally:
        _TP_AXIS = prev


def get_tp_axis() -> str | None:
    return _TP_AXIS


def psum_tp(x):
    """All-reduce a tensor-parallel partial sum over the bound TP axis.

    This is THE collective of the sharded serving step: with attention
    heads and MLP hidden dims column/row-split per shard, each layer's
    wo and down projections produce partial sums over the local slice,
    and one psum per boundary (2L per step) restores the replicated
    residual stream. Identity when no TP axis is bound, so model code
    calls it unconditionally."""
    if _TP_AXIS is None:
        return x
    return jax.lax.psum(x, _TP_AXIS)


def tp_shard_map(fn, mesh, *, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions.

    The serving body's outputs are replicated by construction (every
    shard computes identical logits after the boundary psums), but the
    static rep-checker cannot always prove that through the pool
    scatter/gather, so it is disabled — the TP identity tests in
    tests/test_tp_serving.py are the real check. jax renamed the flag
    (check_rep -> check_vma) after 0.4.x; accept either."""
    from jax.experimental.shard_map import shard_map

    try:
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def resolve_axis(name, mesh):
    if name is None:
        return None
    if name == "batch":
        return ("pod", "data") if "pod" in mesh.axis_names else "data"
    if name == "seq":        # sequence parallelism rides the model axis
        return "model"
    if name == "tokens":     # flattened (batch*seq) dim: all axes merged
        return tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    if name in mesh.axis_names:
        return name
    return None


def logical_spec(names, mesh) -> P:
    return P(*(resolve_axis(n, mesh) for n in names))


def _axis_size(axis, mesh):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def maybe_shard(x, *names):
    """Logical sharding constraint; axes that don't divide the dim are
    dropped (no silent GSPMD padding on activations)."""
    if _MESH is None:
        return x
    axes = [resolve_axis(n, _MESH) for n in names]
    axes = [a if a is not None and d % _axis_size(a, _MESH) == 0 else None
            for a, d in zip(axes, x.shape)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*axes)))
