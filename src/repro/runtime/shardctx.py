"""Logical-axis sharding context.

Model code never mentions meshes: it calls `maybe_shard(x, "batch", None,
"model")` with *logical* axis names. When a mesh is installed (launcher /
dry-run) the names resolve to physical mesh axes and become
with_sharding_constraint; with no mesh installed (unit tests, CPU smoke
runs) the call is a no-op.

Logical -> physical:
  batch  -> ("pod", "data") on a multi-pod mesh, ("data",) single-pod
  model  -> ("model",)
  data   -> ("data",)
  None   -> unsharded
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH: jax.sharding.Mesh | None = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _MESH = prev


def resolve_axis(name, mesh):
    if name is None:
        return None
    if name == "batch":
        return ("pod", "data") if "pod" in mesh.axis_names else "data"
    if name == "seq":        # sequence parallelism rides the model axis
        return "model"
    if name == "tokens":     # flattened (batch*seq) dim: all axes merged
        return tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    if name in mesh.axis_names:
        return name
    return None


def logical_spec(names, mesh) -> P:
    return P(*(resolve_axis(n, mesh) for n in names))


def _axis_size(axis, mesh):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def maybe_shard(x, *names):
    """Logical sharding constraint; axes that don't divide the dim are
    dropped (no silent GSPMD padding on activations)."""
    if _MESH is None:
        return x
    axes = [resolve_axis(n, _MESH) for n in names]
    axes = [a if a is not None and d % _axis_size(a, _MESH) == 0 else None
            for a, d in zip(axes, x.shape)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*axes)))
