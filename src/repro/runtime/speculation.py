"""Self-speculative decoding: the low-rank cascade as a free draft model.

ITERA-LLM's iterative decomposition (core/itera.py, paper §III) has a
property no post-hoc quantization stack has: a rank-r cascade's first
r' < r components ARE the rank-r' ITERA model (greedy prefix
consistency — `itera.truncate`). Every compressed layer therefore
already contains a cheaper approximation of itself, which is exactly a
draft model for speculative decoding — same resident weights, no second
checkpoint, no extra HBM:

  1. **draft** — for each in-flight decode row, run k single-token steps
     with the TRUNCATED cascade (and/or a lower activation word length),
     chaining greedy argmax tokens. Draft K/V lands in the same blocked
     pool at the positions the tokens would occupy.
  2. **verify** — ONE full-model `unified_step` over the (k+1)-wide span
     [last committed token, d_1 .. d_k]. The span scatter overwrites
     every draft-written K/V slot with full-model values
     (write-then-attend), so the pool never retains draft numerics.
  3. **accept/reject** — greedy acceptance: the longest prefix of drafts
     matching the full model's argmax chain is kept, plus the full
     model's own token at the first mismatch (or the bonus token after a
     full accept). Emitted tokens are always the FULL model's argmax, so
     speculative serve is token-identical to non-speculative serve; a
     rejected draft costs nothing but the wasted draft compute —
     rejected positions are masked out of every later read and
     overwritten by the next span.

The whole round — k draft passes + the verify pass + acceptance — is a
single jitted dispatch (`speculative_step`); only (tokens, n_accept) is
read back per step. Scheduling (per-row clamping, provisional KV-block
reserve/rollback) lives in `runtime.scheduler`; the serve-loop driver in
`api.engine`. `hw/tpu_model.speculation_point` prices the trade for the
DSE; docs/serving.md walks the whole round.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.itera import LowRankQ, truncate
from repro.core.quant import QuantizedTensor, pack_weights, unpack_weights


@dataclasses.dataclass(frozen=True)
class DraftSpec:
    """How to derive the draft model from the served weights.

    k             : draft tokens proposed per decode row per round.
    rank_fraction : the draft keeps round(rank_fraction * r) components
                    of every rank-r cascade node (prefix consistency
                    makes this the lower-rank ITERA model, not an ad-hoc
                    approximation). 1.0 keeps the full cascade.
    act_wl        : optional activation word length override for the
                    draft pass (e.g. A8 serve, A6 draft); None inherits
                    the plan's act_wl.

    Carried on `CompressionPlan.draft` (serialized with the plan) or
    passed to `InferenceEngine.build(speculate=...)`.
    """

    k: int = 4
    rank_fraction: float = 0.5
    act_wl: int | None = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"draft k must be >= 1, got {self.k}")
        if not 0.0 < self.rank_fraction <= 1.0:
            raise ValueError(f"rank_fraction must be in (0, 1], got "
                             f"{self.rank_fraction}")
        if self.act_wl is not None and not 2 <= self.act_wl <= 8:
            raise ValueError(f"draft act_wl={self.act_wl} outside [2, 8]")

    def to_dict(self) -> dict:
        d = {"k": self.k, "rank_fraction": self.rank_fraction}
        if self.act_wl is not None:
            d["act_wl"] = int(self.act_wl)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "DraftSpec":
        return cls(k=int(d.get("k", 4)),
                   rank_fraction=float(d.get("rank_fraction", 0.5)),
                   act_wl=None if d.get("act_wl") is None
                   else int(d["act_wl"]))


def draft_rank(rank: int, fraction: float) -> int:
    """Draft rank for a full cascade rank: round(fraction * rank),
    floored to the kernels' 64-lane rank granularity when the full rank
    is large enough to care (mirrors CompressionConfig.rank_for, so a
    draft rank is always one the cascade kernels accept)."""
    rd = max(1, int(round(fraction * rank)))
    if rank >= 256 and rd >= 64:
        rd = (rd // 64) * 64
    return min(rd, rank)


def derive_draft_params(params, spec: DraftSpec):
    """The "free draft model": a parameter tree for the draft pass that
    SHARES every dense array (embeddings, lm head, norms, un-decomposed
    quantized weights) with the served tree by reference, and replaces
    each `LowRankQ` cascade node with its first-`draft_rank` components
    (`itera.truncate` on the unpacked carrier, repacked if the serving
    node was packed). With `spec.act_wl` set, quantized leaves are
    restamped to the draft activation word length — an aux-only change
    that copies no device memory.

    A tree with no LowRankQ nodes and act_wl=None derives an exact copy
    (acceptance 1.0, zero draft savings) — allowed, because it exercises
    the machinery on dense engines, but pointless in production; the
    engine warns in that case.
    """

    def is_node(x):
        return isinstance(x, (LowRankQ, QuantizedTensor))

    def f(leaf):
        if isinstance(leaf, LowRankQ):
            lr = LowRankQ(unpack_weights(leaf.w1), unpack_weights(leaf.w2))
            # logical rank from the w2 carrier: (..., r, N) — robust for
            # scan-stacked (L, r, N) leaves where `.rank` (== shape[1] of
            # w1) would read the K axis
            r = int(lr.w2.values.shape[-2])
            rd = draft_rank(r, spec.rank_fraction)
            if rd < r:
                lr = truncate(lr, rd)
            w1, w2 = lr.w1, lr.w2
            if spec.act_wl is not None:
                w1 = dataclasses.replace(w1, act_wl=spec.act_wl)
                w2 = dataclasses.replace(w2, act_wl=spec.act_wl)
            if leaf.w1.packed:
                w1 = pack_weights(w1)
            if leaf.w2.packed:
                w2 = pack_weights(w2)
            return LowRankQ(w1, w2)
        if isinstance(leaf, QuantizedTensor) and spec.act_wl is not None:
            return dataclasses.replace(leaf, act_wl=spec.act_wl)
        return leaf

    return jax.tree_util.tree_map(f, params, is_leaf=is_node)


def is_exact_draft(params, draft_params) -> bool:
    """True when the derived draft is semantically identical to the
    served tree (no cascade was truncated, no act_wl changed) — i.e.
    speculation will accept everything and save nothing."""
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(draft_params)):
        if a is not b:
            return False
    la = [l for l in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)]
    lb = [l for l in jax.tree_util.tree_leaves(
        draft_params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)]
    return all(x.act_wl == y.act_wl for x, y in zip(la, lb))


def speculative_step(params, draft_params, pool, block_tables, step_buf,
                     prev, cfg, k: int, sample: bool = False):
    """One fused draft->verify->accept serving dispatch.

    step_buf: (B, W + 4 + sampling.SAMP_COLS) int32 — span tokens (B, W)
    with four metadata columns appended — ctx_lens, q_lens, use_prev,
    spec_lens — followed by the packed per-row sampling block
    (`runtime.sampling.write_row_meta`). Decode rows carry
    q_lens = 1 + spec_lens (the previous token plus their draft span);
    prefill rows carry their chunk width and spec_lens = 0. W is
    bucketed by the driver and must be >= k + 1 when k > 0.

    With `sample=True` (a static trace variant, like k), rows whose
    packed temperature is > 0 replace their emitted token with a
    temperature/top-k/top-p sample from the verify pass's last-valid
    logits, keyed by the same counter-based derivation as the plain
    serve step. Sampled rows never draft (the scheduler gives them
    spec_lens = 0), so their accept count is naturally 0 and the one
    sampled token is the round's whole emission; greedy rows are
    untouched — bit-identical to sample=False.

    Phases (all inside one jit, so the host pays ONE dispatch per round):
      draft  — k unrolled width-1 `unified_step` calls with
               `draft_params` over the SAME pool; row r participates in
               draft step i iff i < spec_lens[r] (others idle through
               the trash block). The chain starts from `prev` (the
               row's last committed token, device-resident) and each
               step feeds its argmax to the next.
      verify — one full-model `unified_step` over the whole span batch:
               decode rows' spans are [prev, d_1 .. d_k'], prefill rows
               their prompt chunk. The span scatter overwrites every
               draft-written K/V position with full-model values.
               `verify_width = k + 1` returns logits at span positions
               0..k PLUS each row's last-valid position.
      accept — n_acc[r] = length of the matching draft prefix;
               full_toks[r, 0 : n_acc+1] are the row's emitted tokens
               (greedy: always the full model's argmax chain).

    Returns (full_toks (B, k+2), n_acc (B,), next_prev (B, 1), pool):
      * decode rows emit full_toks[r, :n_acc[r]+1] (n_acc == 0 for
        rows with spec_lens == 0 — the plain decode degenerate case);
      * prefill-finishing rows emit full_toks[r, k+1] (the appended
        last-valid-position column);
      * next_prev is each row's newest token (not yet in the pool).

    k == 0 degenerates to the plain serving step in this calling
    convention (no draft passes, verify_width 1).
    """
    from repro.models import transformer as tfm
    from repro.runtime import sampling as smp

    b = step_buf.shape[0]
    m = smp.SAMP_COLS
    tokens = step_buf[:, :-(4 + m)]
    ctx_lens, q_lens, use_prev, spec_lens = (
        step_buf[:, -(m + 4)], step_buf[:, -(m + 3)],
        step_buf[:, -(m + 2)], step_buf[:, -(m + 1)])

    # ---- draft: k chained single-token passes with the truncated model
    drafts = []
    d = prev
    for i in range(k):
        ql = (spec_lens > i).astype(jnp.int32)
        dlogits, pool = tfm.unified_step(draft_params, pool, block_tables,
                                         ctx_lens + i, ql, d, cfg)
        d = jnp.argmax(dlogits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        drafts.append(d)

    # ---- verify: splice prev + drafts into the span, one full pass
    tokens = tokens.at[:, 0].set(
        jnp.where(use_prev.astype(bool), prev[:, 0], tokens[:, 0]))
    if k:
        draft_mat = jnp.concatenate(drafts, axis=1)              # (B, k)
        spec_cols = jnp.arange(k)[None, :] < spec_lens[:, None]  # (B, k)
        tokens = tokens.at[:, 1:k + 1].set(
            jnp.where(spec_cols, draft_mat, tokens[:, 1:k + 1]))
    logits, pool = tfm.unified_step(params, pool, block_tables, ctx_lens,
                                    q_lens, tokens, cfg, verify_width=k + 1)
    full_toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)    # (B, k+2)
    if sample:
        # sampled rows (temperature > 0; never drafting, so n_acc will
        # be 0) emit one token drawn from the last-valid-position logits
        # — column k+1, which for a q = 1 decode row is the same
        # position as column 0. Override both emission columns so the
        # host readback and next_prev agree whichever one a row uses.
        meta = smp.unpack_meta(step_buf)
        keys = smp.row_keys(meta["seed"], meta["rid"], meta["counter"])
        samp = smp.sample_tokens(logits[:, -1], meta["temperature"],
                                 meta["top_k"], meta["top_p"], keys)
        srow = meta["temperature"] > 0.0
        full_toks = full_toks.at[:, 0].set(
            jnp.where(srow, samp, full_toks[:, 0]))
        full_toks = full_toks.at[:, k + 1].set(
            jnp.where(srow, samp, full_toks[:, k + 1]))

    # ---- accept: longest matching draft prefix (cumprod of matches)
    if k:
        match = (draft_mat == full_toks[:, :k]) & spec_cols
        n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1),
                        axis=1).astype(jnp.int32)
    else:
        n_acc = jnp.zeros((b,), jnp.int32)
    # newest token: accepted-prefix end for decode rows, the last-valid
    # column (k+1) for prefill rows — plain decode rows (n_acc == 0,
    # q_lens == 1) read column 0, which IS their last-valid position
    last_idx = jnp.where(use_prev.astype(bool), n_acc,
                         jnp.full((b,), k + 1, jnp.int32))
    next_prev = jnp.take_along_axis(full_toks, last_idx[:, None], axis=1)
    return full_toks, n_acc, next_prev, pool


class SpeculationController:
    """Engine-side owner of the draft execution mode: derives and holds
    the draft parameter tree and hands the serve loop a jitted
    `speculative_step` per static draft width. Stateless across serve()
    calls — per-serve acceptance stats live in `ServeResult`."""

    def __init__(self, spec: DraftSpec, cfg, params, draft_params=None, *,
                 mesh=None):
        self.spec = spec
        self.cfg = cfg
        self.draft_params = (derive_draft_params(params, spec)
                             if draft_params is None else draft_params)
        self.exact = is_exact_draft(params, self.draft_params)
        self._steps: dict[tuple[int, bool], object] = {}
        # tensor-parallel speculation: same recipe as the engine's plain
        # TP step — shard-map the whole fused round (draft chain +
        # verify + accept), draft params sliced with the SAME rules as
        # the served params (truncate acts on the rank axis, the TP
        # slice on heads/hidden columns — they commute), pool
        # head-sliced, accept bookkeeping replicated.
        self.mesh = mesh
        self._tp = (int(mesh.shape["model"])
                    if mesh is not None and "model" in mesh.axis_names
                    else 0)
        if self._tp:
            from repro.launch import sharding as shd

            shd.check_tp_geometry(cfg, self._tp)
            self._local_cfg = shd.tp_local_config(cfg, self._tp)
            self._pspecs = shd.tp_param_specs(params, self._tp)
            self._dspecs = shd.tp_param_specs(self.draft_params, self._tp)
            from jax.sharding import NamedSharding, PartitionSpec as P

            self.draft_params = jax.device_put(
                self.draft_params,
                jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), self._dspecs,
                    is_leaf=lambda x: isinstance(x, P)))

    def step_fn(self, k: int, sample: bool = False):
        """Jitted speculative_step specialized on draft width k and the
        sampling mode (the serve loop uses k == spec.k on rounds with
        any drafting row and k == 0 otherwise, and one sample flag per
        serve call, so at most two variants trace per serve)."""
        fn = self._steps.get((k, sample))
        if fn is None:
            if self._tp:
                from jax.sharding import PartitionSpec as P

                from repro.runtime import kvblocks, shardctx

                pool_specs = kvblocks.pool_pspecs(self.cfg)

                def tp_body(p, dp, pool, bt, buf, prev, _k=k, _s=sample):
                    with shardctx.tp_axis("model"):
                        return speculative_step(p, dp, pool, bt, buf, prev,
                                                self._local_cfg, _k,
                                                sample=_s)

                fn = jax.jit(shardctx.tp_shard_map(
                    tp_body, self.mesh,
                    in_specs=(self._pspecs, self._dspecs, pool_specs,
                              P(), P(), P()),
                    out_specs=(P(), P(), P(), pool_specs)))
            else:
                fn = jax.jit(
                    lambda p, dp, pool, bt, buf, prev, _k=k, _s=sample:
                    speculative_step(p, dp, pool, bt, buf, prev, self.cfg,
                                     _k, sample=_s))
            self._steps[(k, sample)] = fn
        return fn
