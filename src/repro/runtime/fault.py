"""Fault tolerance & straggler mitigation for the training loop.

`ResilientLoop` wraps a step function with:
  * checkpoint/restart — on any step failure the loop restores the latest
    committed checkpoint and replays from there (bounded retries);
  * failure injection — tests/chaos drills raise at a chosen step via
    `inject_failure_at`;
  * straggler detection — per-step wall-time EMA; a step slower than
    `straggler_factor` x EMA is flagged; `straggler_patience` consecutive
    flags fire the mitigation callback (in production: exclude the slow
    host and elastically resume on the reduced mesh — see elastic.py; the
    single-process analog re-meshes and restores, which we exercise in
    tests).

The loop is deliberately synchronous-SPMD shaped: one step = one jitted
call; failures between steps lose at most (step - last_ckpt) steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    straggler_events: int = 0
    remesh_events: int = 0
    losses: list = dataclasses.field(default_factory=list)


class ResilientLoop:
    def __init__(
        self,
        step_fn: Callable[[Any, int], tuple],   # (state, step) -> (state, metrics)
        save_fn: Callable[[Any, int], None],
        restore_fn: Callable[[], tuple],        # () -> (state, step)
        *,
        ckpt_every: int = 50,
        max_failures: int = 3,
        straggler_factor: float = 3.0,
        straggler_patience: int = 3,
        on_straggler: Optional[Callable[[], None]] = None,
        inject_failure_at: Optional[int] = None,
    ):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.ckpt_every = ckpt_every
        self.max_failures = max_failures
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self.on_straggler = on_straggler
        self.inject_failure_at = inject_failure_at
        self.report = LoopReport()

    def run(self, state, start_step: int, num_steps: int):
        step = start_step
        failures = 0
        ema = None
        slow_streak = 0
        injected = False
        r = self.report

        while step < start_step + num_steps:
            try:
                if (self.inject_failure_at is not None
                        and step == self.inject_failure_at and not injected):
                    injected = True
                    raise InjectedFailure(f"injected failure at step {step}")

                t0 = time.monotonic()
                state, metrics = self.step_fn(state, step)
                dt = time.monotonic() - t0

                # straggler tracking
                if ema is None:
                    ema = dt
                elif dt > self.straggler_factor * ema:
                    slow_streak += 1
                    r.straggler_events += 1
                    if (slow_streak >= self.straggler_patience
                            and self.on_straggler is not None):
                        self.on_straggler()
                        r.remesh_events += 1
                        slow_streak = 0
                else:
                    slow_streak = 0
                    ema = 0.9 * ema + 0.1 * dt

                if "loss" in metrics:
                    r.losses.append(float(metrics["loss"]))
                step += 1
                r.steps_run += 1
                if step % self.ckpt_every == 0:
                    self.save_fn(state, step)
            except Exception as e:  # noqa: BLE001 — any step failure
                failures += 1
                r.failures += 1
                if failures > self.max_failures:
                    raise RuntimeError(
                        f"exceeded {self.max_failures} failures") from e
                state, step = self.restore_fn()
                r.restores += 1
        return state, step
