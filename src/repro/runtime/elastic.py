"""Elastic scaling: resume a checkpoint on a different mesh.

A checkpoint stores *global* arrays (path-keyed npz). Resuming on a new
mesh is therefore only a question of (a) rebuilding shardings for the new
mesh from the same logical rules and (b) device_put-ing each restored
array with them — checkpoint/ckpt.restore already takes a shardings
pytree. This module adds the policy layer:

  * `viable_meshes(n_devices)` — the (data, model) factorizations a given
    surviving-device count supports;
  * `shrink_mesh(mesh, lost_axis_index)` — the mesh you re-form after
    excluding a failed/straggling slice (drop the pod, halve data, ...);
  * `elastic_restore(...)` — end-to-end: new mesh -> new shardings ->
    restored state, asserting divisibility of every global shape.

Tests exercise save-on-mesh-A / restore-on-mesh-B with different axis
sizes and check bit-identical global arrays.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint import ckpt as ckpt_lib


def viable_meshes(n_devices: int):
    """(data, model) factorizations, largest model-parallel first."""
    out = []
    m = 1
    while m <= n_devices:
        if n_devices % m == 0:
            out.append((n_devices // m, m))
        m *= 2
    return out


def make_mesh(shape, axes):
    devs = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)


def shrink_mesh(mesh: Mesh, *, drop_axis: str):
    """Re-form the mesh without one slice of `drop_axis` (failed pod/host).

    Keeps every other axis; the dropped axis loses one slice (size-1 axes
    disappear entirely) — the single-process analog of re-forming the ICI
    mesh around a dead pod.
    """
    names = list(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    if sizes[drop_axis] <= 1:
        names.remove(drop_axis)
        new_shape = [sizes[n] for n in names]
        devs = mesh.devices.reshape(-1)[: int(np.prod(new_shape))]
        return Mesh(devs.reshape(new_shape), tuple(names))
    idx = [slice(None)] * len(names)
    idx[names.index(drop_axis)] = slice(0, sizes[drop_axis] - 1)
    return Mesh(mesh.devices[tuple(idx)], tuple(names))


def elastic_restore(ckpt_dir: str, like, mesh: Mesh, spec_fn, step=None):
    """Restore `like`-shaped state onto `mesh` using spec_fn(path, leaf)->
    PartitionSpec. Raises if any global shape does not divide."""
    flat = jax.tree_util.tree_flatten_with_path(like)
    shardings = []
    for path, leaf in flat[0]:
        spec = spec_fn(path, leaf)
        for dim, axis in zip(leaf.shape, spec):
            if axis is None:
                continue
            size = (np.prod([mesh.shape[a] for a in axis])
                    if isinstance(axis, tuple) else mesh.shape[axis])
            if dim % size:
                raise ValueError(
                    f"{path}: dim {dim} not divisible by axis {axis}={size}"
                    " on the new mesh")
        shardings.append(NamedSharding(mesh, spec))
    shard_tree = jax.tree_util.tree_unflatten(flat[1], shardings)
    return ckpt_lib.restore(ckpt_dir, like, step, shardings=shard_tree)
