"""Elastic capacity: resume on a different mesh; yield KV under pressure.

A checkpoint stores *global* arrays (path-keyed npz). Resuming on a new
mesh is therefore only a question of (a) rebuilding shardings for the new
mesh from the same logical rules and (b) device_put-ing each restored
array with them — checkpoint/ckpt.restore already takes a shardings
pytree. This module adds the policy layer:

  * `viable_meshes(n_devices)` — the (data, model) factorizations a given
    surviving-device count supports;
  * `shrink_mesh(mesh, lost_axis_index)` — the mesh you re-form after
    excluding a failed/straggling slice (drop the pod, halve data, ...);
  * `elastic_restore(...)` — end-to-end: new mesh -> new shardings ->
    restored state, asserting divisibility of every global shape.

Tests exercise save-on-mesh-A / restore-on-mesh-B with different axis
sizes and check bit-identical global arrays.

The same "shrink to fit, then recover" idea applies one level down, to
KV-pool pressure during serving: when admission cannot claim enough
blocks even after evicting every refcount-0 cached block, the scheduler
preempts a live sequence and requeues its request rather than stalling
the queue behind a full pool. The victim-selection policy lives here
(`preemption_victims`, `reclaimable_blocks`) and is deliberately dumb
and bounded:

  * newest request first (max rid) — it has the least sunk prefill work
    and, with prefix caching on, its completed prompt blocks stay in the
    cache so re-admission resumes from the last registered block;
  * only sequences that have emitted nothing — dropping a pure-prefill
    row loses no user-visible output and keeps the engine's count-based
    pipeline bookkeeping exact;
  * each request yields at most once (`Request.requeued`), so the FCFS
    inversion a preemption introduces is bounded and two requests can
    never ping-pong each other's blocks.
"""
from __future__ import annotations

import numpy as np

# jax (and the checkpoint module, which imports it) is pulled in lazily
# by the mesh-surgery functions below: the preemption-policy half of
# this module sits on the scheduler's per-step hot path, and
# `from repro.runtime.elastic import preemption_victims` must stay
# importable — and fast — without initializing a device runtime.


def preemption_victims(live_seqs):
    """Live sequences eligible for pool-pressure preemption, in eviction
    order (newest request first). Eligibility: zero emitted tokens, no
    in-flight speculative draft, not already requeued once."""
    eligible = [s for s in live_seqs
                if s is not None and s.n_emitted == 0
                and not s.draft_blocks
                and not getattr(s.req, "requeued", False)]
    return sorted(
        eligible,
        key=lambda s: -1 if s.req.rid is None else s.req.rid,
        reverse=True)


def reclaimable_blocks(pool, seq) -> int:
    """Blocks the pool gets back if `seq` is preempted now: holdings (and
    any copy-on-write pin) no other sequence shares. Shared prefix blocks
    with refcount > 1 stay resident for their other holders, so they do
    not count."""
    held = set(seq.block_ids)
    n = sum(1 for b in held if pool.refcount(b) == 1)
    cow = getattr(seq, "cow_src", None)
    if cow is not None and cow not in held and pool.refcount(cow) == 1:
        n += 1
    return n


def viable_meshes(n_devices: int):
    """(data, model) factorizations, largest model-parallel first."""
    out = []
    m = 1
    while m <= n_devices:
        if n_devices % m == 0:
            out.append((n_devices // m, m))
        m *= 2
    return out


def make_mesh(shape, axes):
    import jax
    from jax.sharding import Mesh

    devs = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)


def shrink_mesh(mesh: Mesh, *, drop_axis: str):
    """Re-form the mesh without one slice of `drop_axis` (failed pod/host).

    Keeps every other axis; the dropped axis loses one slice (size-1 axes
    disappear entirely) — the single-process analog of re-forming the ICI
    mesh around a dead pod.
    """
    from jax.sharding import Mesh

    names = list(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    if sizes[drop_axis] <= 1:
        names.remove(drop_axis)
        new_shape = [sizes[n] for n in names]
        devs = mesh.devices.reshape(-1)[: int(np.prod(new_shape))]
        return Mesh(devs.reshape(new_shape), tuple(names))
    idx = [slice(None)] * len(names)
    idx[names.index(drop_axis)] = slice(0, sizes[drop_axis] - 1)
    return Mesh(mesh.devices[tuple(idx)], tuple(names))


def elastic_restore(ckpt_dir: str, like, mesh: Mesh, spec_fn, step=None):
    """Restore `like`-shaped state onto `mesh` using spec_fn(path, leaf)->
    PartitionSpec. Raises if any global shape does not divide."""
    import jax
    from jax.sharding import NamedSharding

    from repro.checkpoint import ckpt as ckpt_lib

    flat = jax.tree_util.tree_flatten_with_path(like)
    shardings = []
    for path, leaf in flat[0]:
        spec = spec_fn(path, leaf)
        for dim, axis in zip(leaf.shape, spec):
            if axis is None:
                continue
            size = (np.prod([mesh.shape[a] for a in axis])
                    if isinstance(axis, tuple) else mesh.shape[axis])
            if dim % size:
                raise ValueError(
                    f"{path}: dim {dim} not divisible by axis {axis}={size}"
                    " on the new mesh")
        shardings.append(NamedSharding(mesh, spec))
    shard_tree = jax.tree_util.tree_unflatten(flat[1], shardings)
    return ckpt_lib.restore(ckpt_dir, like, step, shardings=shard_tree)
