"""Blocked (paged) KV-cache pool for continuous-batching decode.

The monolithic decode cache sizes every row at `max_len`, so a batch pays
for its longest request and a finished row's memory is stranded until the
whole batch retires. This module replaces it, for the shared serving
batch, with the paged layout production servers use (vLLM /
TensorRT-LLM style):

  * a physical pool of fixed-size blocks per layer —
    `(L, num_blocks, block_size, Hk, Dh)` for K and V, plus per-(token,
    head) scale planes when `cfg.kv_cache_bits == 8`;
  * a host-side `BlockPool` free-list allocator. Block 0 is reserved as
    the *trash block*: inactive batch rows write there and nothing ever
    reads it back, so the jitted step needs no control flow;
  * per-sequence block tables mapping logical position `p` to physical
    slot `(table[p // block_size], p % block_size)`. Tables are dense,
    append-only, and padded with the trash block;
  * refcounted prefix sharing: a fully-written block can be *registered*
    under a chained content digest (`prefix_digests`), after which later
    sequences with the same token prefix `share` it by reference instead
    of recomputing it. Freeing decrements the refcount; a registered
    block whose refcount reaches zero is parked in an LRU side pool (it
    still counts as `available`) and is evicted — digest dropped, block
    reused — only when the free list runs dry. `copy_block` is the
    copy-on-write primitive: the scheduler materializes a private copy
    before the first divergent write into a shared block.

Tokens enter the pool a *span* at a time: `span_slots` maps a batch of
per-row token spans (a chunk of prompt during chunked prefill, or a
single decode token) to physical (block, offset) scatter targets;
`models.attention.span_attention_paged` does the span write + gather.
Admission/eviction policy lives in `runtime.scheduler`; this module is
pure layout + accounting.

Supported: dense / MoE layouts with global causal attention. Sliding
windows, local/global alternation, and SSM state are not paged yet (their
decode state is O(window) / O(1) per row, so paging buys much less).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np

# jax.numpy is imported lazily inside the device-facing functions
# (init_paged_cache, valid_block_counts, span_slots): the allocator /
# digest half of this module is on the scheduler's host path, and
# `from repro.runtime.kvblocks import BlockPool` must not initialize a
# device runtime. Function-local imports are trace-safe — they run at
# trace time, not per step.


def check_paged_support(cfg) -> None:
    """Raise when `cfg` cannot decode through the blocked KV pool."""
    if cfg.layout not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged KV decode supports dense/moe layouts, not {cfg.layout!r}"
            " (SSM/hybrid decode state is O(1) per row and is not paged)")
    if cfg.local_global_period or cfg.attn_window:
        raise NotImplementedError(
            "paged KV decode does not support windowed or local/global "
            "attention yet — their rolling caches are already O(window)")


def blocks_needed(prompt_len: int, max_tokens: int, block_size: int) -> int:
    """Blocks a request occupies at peak. Chunked prefill writes every
    prompt position into the pool, and decode caches every generated
    token except the last (which is returned, never attended), so the
    footprint is prompt_len + max_tokens - 1 positions."""
    return -(-(prompt_len + max(max_tokens, 1) - 1) // block_size)


def blocks_for_positions(n_positions: int, block_size: int) -> int:
    """Block-table entries covering the first `n_positions` pool slots —
    the committed-context footprint the speculative rollback rewinds to
    (scheduler.Scheduler.commit_speculation)."""
    return -(-max(n_positions, 0) // block_size)


class BlockPool:
    """Host-side refcounting allocator over `num_blocks` KV blocks.

    Block 0 is reserved (the trash block for inactive rows) and is never
    handed out, so `capacity == num_blocks - 1`. Freeing a block nobody
    holds is a hard error — the scheduler tests lean on this to prove
    admit/evict sequences never leak.

    Prefix caching layers three states on top of the plain free list:

      free      — on `_free`, content unknown, refcount 0;
      live      — refcount >= 1 holder (one owner, or owner + sharers);
      idle      — refcount 0 but *registered* under a content digest.
                  Idle blocks sit in an LRU (`_idle`), still answer
                  `lookup`/`share`, still count as `available`, and are
                  evicted oldest-first only when `alloc` drains the free
                  list.

    With no `register` calls the pool degenerates to the PR-2 free-list
    allocator: every alloc returns refcount-1 blocks and every free
    returns them straight to the free list.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is reserved), got "
                             f"{num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}          # block -> refcount >= 1
        self._index: dict[bytes, int] = {}      # digest -> block
        self._digest: dict[int, bytes] = {}     # block -> digest
        self._idle: OrderedDict[int, None] = OrderedDict()  # LRU, old first
        self.evictions = 0

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1

    @property
    def available(self) -> int:
        """Blocks an alloc can claim right now: free + evictable idle."""
        return len(self._free) + len(self._idle)

    @property
    def cached_blocks(self) -> int:
        """Blocks currently indexed by digest (live sharers + idle)."""
        return len(self._index)

    @property
    def idle_cached_blocks(self) -> int:
        return len(self._idle)

    def can_alloc(self, n: int) -> bool:
        return n <= self.available

    def alloc(self, n: int) -> list[int]:
        if not self.can_alloc(n):
            raise RuntimeError(
                f"block pool exhausted: want {n}, have {self.available} "
                f"(callers must check can_alloc and queue instead)")
        ids = []
        for _ in range(n):
            if self._free:
                b = self._free.pop()
            else:  # evict the least-recently-idle cached block
                b, _ = self._idle.popitem(last=False)
                del self._index[self._digest.pop(b)]
                self.evictions += 1
            self._ref[b] = 1
            ids.append(b)
        return ids

    def free(self, ids) -> None:
        """Drop one reference per listed block. The last holder's free
        parks registered blocks in the idle LRU (newest end) and returns
        unregistered ones to the free list."""
        for b in ids:
            if self._ref.get(b, 0) < 1:
                raise RuntimeError(f"double free / foreign block {b}")
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                if b in self._digest:
                    self._idle[b] = None
                else:
                    self._free.append(b)

    def refcount(self, block_id: int) -> int:
        return self._ref.get(block_id, 0)

    def register(self, block_id: int, digest: bytes) -> bool:
        """Index a fully-written, currently-held block under its content
        digest. First writer wins: if the digest is already indexed (or
        the block already registered) this is a no-op returning False —
        the duplicate block simply stays private. Trash block 0 can never
        get here because it is never handed out by `alloc`."""
        if self._ref.get(block_id, 0) < 1:
            raise RuntimeError(
                f"register of unheld block {block_id} (only live blocks "
                f"can be indexed)")
        if digest in self._index or block_id in self._digest:
            return False
        self._index[digest] = block_id
        self._digest[block_id] = digest
        return True

    def lookup(self, digest: bytes):
        """Block currently indexed under `digest`, or None. Does not take
        a reference — pair with `share` before relying on the block."""
        return self._index.get(digest)

    def share(self, digest: bytes):
        """Take one reference on the block cached under `digest`,
        reviving it from the idle LRU if nobody holds it. None on miss."""
        b = self._index.get(digest)
        if b is None:
            return None
        if b in self._idle:
            del self._idle[b]
        self._ref[b] = self._ref.get(b, 0) + 1
        return b


def prefix_digests(tokens, block_size: int, fingerprint: bytes = b"") \
        -> list[bytes]:
    """Chained content digests for every FULL block of a token prefix.

    digest[i] commits to (fingerprint, block_size, tokens[0 : (i+1)*bs]):
    the chain folds each block's token ids into the previous digest, so
    equal digests mean equal position-aligned prefixes under the same
    model/plan fingerprint. Partial tail blocks get no digest — they are
    never shared. Host-side only (SHA-256 over int64 token bytes)."""
    toks = np.asarray(tokens, dtype=np.int64)
    if toks.ndim != 1:
        raise ValueError(f"tokens must be 1-D, got shape {toks.shape}")
    prev = hashlib.sha256(
        b"kvprefix:%d:" % block_size + fingerprint).digest()
    out = []
    for i in range(toks.size // block_size):
        blk = toks[i * block_size:(i + 1) * block_size]
        prev = hashlib.sha256(prev + blk.astype("<i8").tobytes()).digest()
        out.append(prev)
    return out


def copy_block(pool, src, dst):
    """Copy-on-write primitive: duplicate physical block `src` into `dst`
    across every pool leaf (codes and int8 scale planes alike). jit-safe
    with traced src/dst, and TP-safe — the copy moves along the block
    axis 1 while `pool_pspecs` shards the KV-head axis 3, so each shard
    copies exactly its own head slice."""
    return {key: leaf.at[:, dst].set(leaf[:, src])
            for key, leaf in pool.items()}


def init_paged_cache(cfg, num_blocks: int, block_size: int, dtype=None):
    """Physical pool arrays for every layer: {"k","v"} of shape
    (L, num_blocks, block_size, Hk, Dh), plus {"ks","vs"} f32 scale planes
    when cfg.kv_cache_bits == 8 (same int8 code + scale convention as
    attention.init_kv_cache)."""
    import jax.numpy as jnp

    check_paged_support(cfg)
    dtype = dtype or jnp.dtype(cfg.dtype)
    L, hk, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    shape = (L, num_blocks, block_size, hk, hd)
    if getattr(cfg, "kv_cache_bits", 16) == 8:
        sshape = (L, num_blocks, block_size, hk, 1)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.ones(sshape, jnp.float32),
                "vs": jnp.ones(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def pool_pspecs(cfg):
    """shard_map PartitionSpecs for the paged pool under tensor-parallel
    serving: every pool leaf — (L, NB, bs, Hk, Dh) codes and the int8
    (L, NB, bs, Hk, 1) scale planes — is sliced on the KV-head axis 3
    over the mesh "model" axis, so each shard owns the KV blocks for
    exactly the heads it computes. Block tables, step buffers, and all
    scheduler state stay host-side/replicated (P()); only the pool
    shards. int8 KV quantization is per-(token, head), so head slicing
    is bit-exact — shard r's codes and scales equal rows
    [r*Hk/tp, (r+1)*Hk/tp) of the single-device pool."""
    from jax.sharding import PartitionSpec as P
    spec = P(None, None, None, "model", None)
    keys = ("k", "v", "ks", "vs") if getattr(cfg, "kv_cache_bits", 16) == 8 \
        else ("k", "v")
    return {k: spec for k in keys}


def shard_pool(pool, tp: int, shard: int):
    """The head-slice of `pool` that TP shard `shard` of `tp` owns —
    the reference the property tests compare shard_map's placement
    against. Pure slicing, no device semantics."""
    if not 0 <= shard < tp:
        raise ValueError(f"shard {shard} out of range for tp={tp}")
    out = {}
    for key, leaf in pool.items():
        hk = leaf.shape[3]
        if hk % tp:
            raise ValueError(
                f"pool leaf {key!r} has {hk} KV heads, not divisible by "
                f"tp={tp}")
        n = hk // tp
        out[key] = leaf[:, :, :, shard * n:(shard + 1) * n]
    return out


def valid_block_counts(ctx_lens, q_lens, block_size, max_blocks):
    """Per-row count of block-table entries holding valid context THIS
    step — the grid metadata the Pallas paged-attention kernel walks.

    Row r's span writes its K/V first, so after the scatter the pool holds
    `ctx_lens[r] + q_lens[r]` valid positions = the first
    ceil((ctx + q) / block_size) table entries; everything past that is
    trash-block-0 padding the kernel must never fetch. Idle rows
    (q_lens == 0) count zero — the kernel skips them entirely. jit-safe
    (pure index math); clamped to the table width for caller-supplied
    out-of-range metadata."""
    import jax.numpy as jnp

    total = ctx_lens + q_lens
    nb = (total + block_size - 1) // block_size
    nb = jnp.where(q_lens > 0, nb, 0)
    return jnp.clip(nb, 0, max_blocks).astype(jnp.int32)


def span_slots(block_table, ctx_lens, q_lens, width, block_size):
    """Physical scatter targets for a batch of per-row token spans.

    Row r's span this step covers logical positions
    `ctx_lens[r] .. ctx_lens[r] + q_lens[r] - 1` (a prefill chunk, or a
    single decode token at q_lens == 1). Returns (blk, off), each
    (B, width) int32: span slot (r, i) writes physical block
    `blk[r, i]` at in-block offset `off[r, i]`. Slots past a row's
    `q_lens` — and whole rows with q_lens == 0 — are routed to the
    reserved trash block 0, so the caller can scatter the full (B, width)
    rectangle with no control flow. jit-safe (pure index math, static
    shapes).
    """
    import jax.numpy as jnp

    pos = ctx_lens[:, None] + jnp.arange(width)[None, :]        # (B, W)
    valid = jnp.arange(width)[None, :] < q_lens[:, None]        # (B, W)
    mb = block_table.shape[1]
    bidx = jnp.minimum(pos // block_size, mb - 1)               # clamp pads
    blk = jnp.where(valid,
                    jnp.take_along_axis(block_table, bidx, axis=1), 0)
    off = jnp.where(valid, pos % block_size, 0)
    return blk.astype(jnp.int32), off.astype(jnp.int32)
