"""Blocked (paged) KV-cache pool for continuous-batching decode.

The monolithic decode cache sizes every row at `max_len`, so a batch pays
for its longest request and a finished row's memory is stranded until the
whole batch retires. This module replaces it, for the shared serving
batch, with the paged layout production servers use (vLLM /
TensorRT-LLM style):

  * a physical pool of fixed-size blocks per layer —
    `(L, num_blocks, block_size, Hk, Dh)` for K and V, plus per-(token,
    head) scale planes when `cfg.kv_cache_bits == 8`;
  * a host-side `BlockPool` free-list allocator. Block 0 is reserved as
    the *trash block*: inactive batch rows write there and nothing ever
    reads it back, so the jitted step needs no control flow;
  * per-sequence block tables mapping logical position `p` to physical
    slot `(table[p // block_size], p % block_size)`. Tables are dense,
    append-only, and padded with the trash block.

Tokens enter the pool a *span* at a time: `span_slots` maps a batch of
per-row token spans (a chunk of prompt during chunked prefill, or a
single decode token) to physical (block, offset) scatter targets;
`models.attention.span_attention_paged` does the span write + gather.
Admission/eviction policy lives in `runtime.scheduler`; this module is
pure layout + accounting.

Supported: dense / MoE layouts with global causal attention. Sliding
windows, local/global alternation, and SSM state are not paged yet (their
decode state is O(window) / O(1) per row, so paging buys much less).
"""
from __future__ import annotations

import jax.numpy as jnp


def check_paged_support(cfg) -> None:
    """Raise when `cfg` cannot decode through the blocked KV pool."""
    if cfg.layout not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged KV decode supports dense/moe layouts, not {cfg.layout!r}"
            " (SSM/hybrid decode state is O(1) per row and is not paged)")
    if cfg.local_global_period or cfg.attn_window:
        raise NotImplementedError(
            "paged KV decode does not support windowed or local/global "
            "attention yet — their rolling caches are already O(window)")


def blocks_needed(prompt_len: int, max_tokens: int, block_size: int) -> int:
    """Blocks a request occupies at peak. Chunked prefill writes every
    prompt position into the pool, and decode caches every generated
    token except the last (which is returned, never attended), so the
    footprint is prompt_len + max_tokens - 1 positions."""
    return -(-(prompt_len + max(max_tokens, 1) - 1) // block_size)


def blocks_for_positions(n_positions: int, block_size: int) -> int:
    """Block-table entries covering the first `n_positions` pool slots —
    the committed-context footprint the speculative rollback rewinds to
    (scheduler.Scheduler.commit_speculation)."""
    return -(-max(n_positions, 0) // block_size)


class BlockPool:
    """Host-side free-list allocator over `num_blocks` KV blocks.

    Block 0 is reserved (the trash block for inactive rows) and is never
    handed out, so `capacity == num_blocks - 1`. Double-alloc and
    double-free are hard errors — the scheduler tests lean on this to
    prove admit/evict sequences never leak.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is reserved), got "
                             f"{num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks - 1, 0, -1))
        self._allocated: set[int] = set()

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1

    @property
    def available(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= self.available

    def alloc(self, n: int) -> list[int]:
        if not self.can_alloc(n):
            raise RuntimeError(
                f"block pool exhausted: want {n}, have {self.available} "
                f"(callers must check can_alloc and queue instead)")
        ids = [self._free.pop() for _ in range(n)]
        self._allocated.update(ids)
        return ids

    def free(self, ids) -> None:
        for b in ids:
            if b not in self._allocated:
                raise RuntimeError(f"double free / foreign block {b}")
            self._allocated.remove(b)
            self._free.append(b)


def init_paged_cache(cfg, num_blocks: int, block_size: int, dtype=None):
    """Physical pool arrays for every layer: {"k","v"} of shape
    (L, num_blocks, block_size, Hk, Dh), plus {"ks","vs"} f32 scale planes
    when cfg.kv_cache_bits == 8 (same int8 code + scale convention as
    attention.init_kv_cache)."""
    check_paged_support(cfg)
    dtype = dtype or jnp.dtype(cfg.dtype)
    L, hk, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    shape = (L, num_blocks, block_size, hk, hd)
    if getattr(cfg, "kv_cache_bits", 16) == 8:
        sshape = (L, num_blocks, block_size, hk, 1)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.ones(sshape, jnp.float32),
                "vs": jnp.ones(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def pool_pspecs(cfg):
    """shard_map PartitionSpecs for the paged pool under tensor-parallel
    serving: every pool leaf — (L, NB, bs, Hk, Dh) codes and the int8
    (L, NB, bs, Hk, 1) scale planes — is sliced on the KV-head axis 3
    over the mesh "model" axis, so each shard owns the KV blocks for
    exactly the heads it computes. Block tables, step buffers, and all
    scheduler state stay host-side/replicated (P()); only the pool
    shards. int8 KV quantization is per-(token, head), so head slicing
    is bit-exact — shard r's codes and scales equal rows
    [r*Hk/tp, (r+1)*Hk/tp) of the single-device pool."""
    from jax.sharding import PartitionSpec as P
    spec = P(None, None, None, "model", None)
    keys = ("k", "v", "ks", "vs") if getattr(cfg, "kv_cache_bits", 16) == 8 \
        else ("k", "v")
    return {k: spec for k in keys}


def shard_pool(pool, tp: int, shard: int):
    """The head-slice of `pool` that TP shard `shard` of `tp` owns —
    the reference the property tests compare shard_map's placement
    against. Pure slicing, no device semantics."""
    if not 0 <= shard < tp:
        raise ValueError(f"shard {shard} out of range for tp={tp}")
    out = {}
    for key, leaf in pool.items():
        hk = leaf.shape[3]
        if hk % tp:
            raise ValueError(
                f"pool leaf {key!r} has {hk} KV heads, not divisible by "
                f"tp={tp}")
        n = hk // tp
        out[key] = leaf[:, :, :, shard * n:(shard + 1) * n]
    return out


def valid_block_counts(ctx_lens, q_lens, block_size, max_blocks):
    """Per-row count of block-table entries holding valid context THIS
    step — the grid metadata the Pallas paged-attention kernel walks.

    Row r's span writes its K/V first, so after the scatter the pool holds
    `ctx_lens[r] + q_lens[r]` valid positions = the first
    ceil((ctx + q) / block_size) table entries; everything past that is
    trash-block-0 padding the kernel must never fetch. Idle rows
    (q_lens == 0) count zero — the kernel skips them entirely. jit-safe
    (pure index math); clamped to the table width for caller-supplied
    out-of-range metadata."""
    total = ctx_lens + q_lens
    nb = (total + block_size - 1) // block_size
    nb = jnp.where(q_lens > 0, nb, 0)
    return jnp.clip(nb, 0, max_blocks).astype(jnp.int32)


def span_slots(block_table, ctx_lens, q_lens, width, block_size):
    """Physical scatter targets for a batch of per-row token spans.

    Row r's span this step covers logical positions
    `ctx_lens[r] .. ctx_lens[r] + q_lens[r] - 1` (a prefill chunk, or a
    single decode token at q_lens == 1). Returns (blk, off), each
    (B, width) int32: span slot (r, i) writes physical block
    `blk[r, i]` at in-block offset `off[r, i]`. Slots past a row's
    `q_lens` — and whole rows with q_lens == 0 — are routed to the
    reserved trash block 0, so the caller can scatter the full (B, width)
    rectangle with no control flow. jit-safe (pure index math, static
    shapes).
    """
    pos = ctx_lens[:, None] + jnp.arange(width)[None, :]        # (B, W)
    valid = jnp.arange(width)[None, :] < q_lens[:, None]        # (B, W)
    mb = block_table.shape[1]
    bidx = jnp.minimum(pos // block_size, mb - 1)               # clamp pads
    blk = jnp.where(valid,
                    jnp.take_along_axis(block_table, bidx, axis=1), 0)
    off = jnp.where(valid, pos % block_size, 0)
    return blk.astype(jnp.int32), off.astype(jnp.int32)
