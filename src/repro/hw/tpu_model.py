"""TPU v5e analytical engine model — the deployed-system counterpart of
engine_model.py (DESIGN.md §2 maps the correspondences).

Latency of one linear layer = max(compute, memory) seconds, exactly the
paper's "slowest port wins" logic at chip granularity:

  compute = MACs x 2 / (peak_ops x mxu_utilization(block dims))
  memory  = HBM bytes touched / hbm_bw

Engines:
  baseline — dense WxA8 matmul (kernels/quant_matmul)
  single   — unfused low-rank: two matmul launches, T round-trips HBM
  cascade  — fused low-rank (kernels/lowrank_qmm): T pinned in VMEM

The DSE (hw/dse.py) sweeps block shapes under the VMEM constraint and
bandwidth scalings (the paper's Fig. 10/11 bandwidth-limited axis).
"""
from __future__ import annotations

import dataclasses

from repro.kernels.lowrank_qmm import vmem_bytes as lr_vmem
from repro.kernels.quant_matmul import vmem_bytes as qm_vmem
from repro.launch.mesh import HBM_BW, PEAK_OPS_INT8, VMEM_BYTES


@dataclasses.dataclass(frozen=True)
class Blocks:
    bm: int
    bk: int
    bn: int


@dataclasses.dataclass
class TpuPoint:
    kind: str
    latency_s: float
    compute_s: float
    memory_s: float
    hbm_bytes: float
    vmem_bytes: int
    config: dict


def _mxu_util(bm: int, bk: int, bn: int) -> float:
    """Fraction of MXU peak achievable with these block dims: the 128x128
    systolic array underfills when the M block has fewer than 128 rows
    (bk/bn in block_space are always >=128)."""
    return min(bm, 128) / 128.0


def _pad(x: int, m: int) -> int:
    return -(-x // m) * m


def _packed(weight_wl: int) -> bool:
    """W4 is the only word length the runtime stores packed."""
    return weight_wl == 4


def blocks_feasible(b: Blocks, weight_wl: int) -> bool:
    """Whether the packed kernels accept these blocks: a packed weight's
    N half-block must stay 128-lane aligned, so bn % 256 == 0 (the same
    constraint ops.choose_blocks enforces and quant_matmul asserts). The
    model must not rank configurations the kernels reject."""
    return not _packed(weight_wl) or b.bn % 256 == 0


def dense_engine(m, k, n, b: Blocks, *, weight_wl=8, act_wl=8,
                 hbm_bw=HBM_BW) -> TpuPoint:
    mp, kp, np_ = _pad(m, b.bm), _pad(k, b.bk), _pad(n, b.bn)
    macs = mp * kp * np_
    compute = 2 * macs / (PEAK_OPS_INT8 * _mxu_util(b.bm, b.bk, b.bn))
    # HBM: X once per N-panel pass? output-stationary grid: X blocks stream
    # once per (i,j) row — X re-read N/bn times, W re-read once per i.
    hbm = (mp * kp * _act_bytes(act_wl) * (np_ // b.bn)
           + kp * np_ * (mp // b.bm) * _wl_bytes(weight_wl)
           + mp * np_ * 4)
    memory = hbm / hbm_bw
    return TpuPoint("baseline", max(compute, memory), compute, memory, hbm,
                    qm_vmem(b.bm, b.bk, b.bn, w_packed=_packed(weight_wl)),
                    {"blocks": dataclasses.asdict(b)})


def single_engine(m, k, n, r, b: Blocks, *, weight_wl=8, act_wl=8,
                  hbm_bw=HBM_BW) -> TpuPoint:
    """Two dense launches; the (M, R) intermediate round-trips HBM."""
    p1 = dense_engine(m, k, r, b, weight_wl=weight_wl, act_wl=act_wl,
                      hbm_bw=hbm_bw)
    p2 = dense_engine(m, r, n, b, weight_wl=weight_wl, act_wl=act_wl,
                      hbm_bw=hbm_bw)
    hbm = p1.hbm_bytes + p2.hbm_bytes + 2 * m * r  # T write + read (int8)
    compute = p1.compute_s + p2.compute_s
    memory = hbm / hbm_bw
    return TpuPoint("single", max(compute, memory), compute, memory, hbm,
                    max(p1.vmem_bytes, p2.vmem_bytes),
                    {"blocks": dataclasses.asdict(b), "rank": r})


def cascade_engine(m, k, n, r, b: Blocks, *, weight_wl=8, act_wl=8,
                   hbm_bw=HBM_BW) -> TpuPoint:
    """Fused kernel: T lives in VMEM; W1 re-read once per M-block row, W2
    once per M-block; X once."""
    packed = _packed(weight_wl)
    # a packed W1 pads R to a multiple of 256 (half-width lane alignment,
    # mirroring ops.lrmm) — the model pays that padding like the kernel does
    rp = _pad(r, 256 if packed else 128)
    mp, kp, np_ = _pad(m, b.bm), _pad(k, b.bk), _pad(n, b.bn)
    macs = mp * kp * rp + mp * rp * np_
    compute = 2 * macs / (PEAK_OPS_INT8 * _mxu_util(b.bm, b.bk, b.bn))
    hbm = (mp * kp * _act_bytes(act_wl)            # X once
           + kp * rp * (mp // b.bm) * _wl_bytes(weight_wl)   # W1 per row
           + rp * np_ * (mp // b.bm) * _wl_bytes(weight_wl)  # W2 per row
           + mp * np_ * 4)                         # Y out f32
    memory = hbm / hbm_bw
    return TpuPoint("cascade", max(compute, memory), compute, memory, hbm,
                    lr_vmem(b.bm, b.bk, b.bn, rp, w1_packed=packed,
                            w2_packed=packed),
                    {"blocks": dataclasses.asdict(b), "rank": r})


def _wl_bytes(wl: int) -> float:
    """HBM bytes per element the TPU runtime ACTUALLY streams: W4 is
    packed two-nibbles-per-byte (kernels/quant_matmul.py unpacks in
    VMEM), everything else — including W6, which has no byte-aligned
    packing — rides a full int8 carrier. Activations are int8 carriers
    at every Ay. Pricing W6 at 6/8 would rank DSE designs by bandwidth
    the kernels cannot deliver (the FPGA model in engine_model.py keeps
    wl/8: that target has a native sub-8-bit datapath)."""
    return 0.5 if wl == 4 else 1.0


def _act_bytes(wl: int) -> float:
    """Activations are quantized on the fly into int8 carriers at every
    Ay — never packed — so they always stream a full byte."""
    del wl
    return 1.0


def block_space(max_bm=512):
    for bm in (8, 16, 32, 64, 128, 256, 512):
        if bm > max_bm:
            continue
        for bk in (128, 256, 512, 1024):
            for bn in (128, 256, 512, 1024):
                yield Blocks(bm, bk, bn)


def best_point(m, k, n, r=None, *, weight_wl=8, act_wl=8, hbm_bw=HBM_BW,
               engines=("baseline", "single", "cascade"),
               vmem_budget=VMEM_BYTES):
    """Lowest-latency feasible engine+blocks for one layer."""
    best = None
    for b in block_space(max_bm=max(8, min(512, _pad(m, 8)))):
        if not blocks_feasible(b, weight_wl):
            continue
        cands = []
        if "baseline" in engines:
            cands.append(dense_engine(m, k, n, b, weight_wl=weight_wl,
                                      act_wl=act_wl, hbm_bw=hbm_bw))
        if r is not None and "single" in engines:
            cands.append(single_engine(m, k, n, r, b, weight_wl=weight_wl,
                                       act_wl=act_wl, hbm_bw=hbm_bw))
        if r is not None and "cascade" in engines:
            cands.append(cascade_engine(m, k, n, r, b, weight_wl=weight_wl,
                                        act_wl=act_wl, hbm_bw=hbm_bw))
        for c in cands:
            if c.vmem_bytes > vmem_budget:
                continue
            if best is None or c.latency_s < best.latency_s:
                best = c
    return best
