"""TPU v5e analytical engine model — the deployed-system counterpart of
engine_model.py (DESIGN.md §2 maps the correspondences).

Latency of one linear layer = max(compute, memory) seconds, exactly the
paper's "slowest port wins" logic at chip granularity:

  compute = MACs x 2 / (peak_ops x mxu_utilization(block dims))
  memory  = HBM bytes touched / hbm_bw

Engines:
  baseline — dense WxA8 matmul (kernels/quant_matmul)
  single   — unfused low-rank: two matmul launches, T round-trips HBM
  cascade  — fused low-rank (kernels/lowrank_qmm): T pinned in VMEM
  pattn_*  — serving attention over the blocked KV pool
             (paged_attention_point): the Pallas streaming kernel vs the
             jnp gather oracle, so the model prices the KV-bandwidth term
             of decode, not just the linear layers

The DSE (hw/dse.py) sweeps block shapes under the VMEM constraint and
bandwidth scalings (the paper's Fig. 10/11 bandwidth-limited axis).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.quant import packed_pad_ok
from repro.kernels.lowrank_qmm import vmem_bytes as lr_vmem
from repro.kernels.quant_matmul import vmem_bytes as qm_vmem
from repro.launch.mesh import (DISPATCH_S, HBM_BW, ICI_BW_PER_LINK,
                               ICI_LINKS, PCIE_BW, PEAK_OPS_INT8,
                               VMEM_BYTES)


@dataclasses.dataclass(frozen=True)
class Blocks:
    bm: int
    bk: int
    bn: int


@dataclasses.dataclass
class TpuPoint:
    kind: str
    latency_s: float
    compute_s: float
    memory_s: float
    hbm_bytes: float
    vmem_bytes: int
    config: dict


def _mxu_util(bm: int, bk: int, bn: int) -> float:
    """Fraction of MXU peak achievable with these block dims: the 128x128
    systolic array underfills when the M block has fewer than 128 rows
    (bk/bn in block_space are always >=128)."""
    return min(bm, 128) / 128.0


def _pad(x: int, m: int) -> int:
    return -(-x // m) * m


def _packed(weight_wl: int) -> bool:
    """W4 is the only word length the runtime stores packed."""
    return weight_wl == 4


def blocks_feasible(b: Blocks, weight_wl: int, n: int | None = None) -> bool:
    """Whether the packed kernels accept these blocks: a packed weight's
    N half-block must stay 128-lane aligned, so bn % 256 == 0 (the same
    constraint ops.choose_blocks enforces and quant_matmul asserts). The
    model must not rank configurations the kernels reject. When the N
    axis is known and packing it would pad fatter than its carrier
    (ops.packed_pad_ok false), the dispatch demotes the weight to a
    carrier and any 128-aligned bn is acceptable."""
    if not _packed(weight_wl):
        return True
    if n is not None and not packed_pad_ok(n):
        return True
    return b.bn % 256 == 0


def dense_engine(m, k, n, b: Blocks, *, weight_wl=8, act_wl=8,
                 hbm_bw=HBM_BW) -> TpuPoint:
    # W4 streams packed only when the N axis pads no fatter packed than
    # carrier (ops.packed_pad_ok) — otherwise ops.qmm demotes to an int8
    # carrier and the model must price what actually streams
    w_packed = _packed(weight_wl) and packed_pad_ok(n)
    mp, kp, np_ = _pad(m, b.bm), _pad(k, b.bk), _pad(n, b.bn)
    macs = mp * kp * np_
    compute = 2 * macs / (PEAK_OPS_INT8 * _mxu_util(b.bm, b.bk, b.bn))
    # HBM: X once per N-panel pass? output-stationary grid: X blocks stream
    # once per (i,j) row — X re-read N/bn times, W re-read once per i.
    hbm = (mp * kp * _act_bytes(act_wl) * (np_ // b.bn)
           + kp * np_ * (mp // b.bm)
           * (_wl_bytes(weight_wl) if w_packed else 1.0)
           + mp * np_ * 4)
    memory = hbm / hbm_bw
    return TpuPoint("baseline", max(compute, memory), compute, memory, hbm,
                    qm_vmem(b.bm, b.bk, b.bn, w_packed=w_packed),
                    {"blocks": dataclasses.asdict(b)})


def single_engine(m, k, n, r, b: Blocks, *, weight_wl=8, act_wl=8,
                  hbm_bw=HBM_BW) -> TpuPoint:
    """Two dense launches; the (M, R) intermediate round-trips HBM."""
    p1 = dense_engine(m, k, r, b, weight_wl=weight_wl, act_wl=act_wl,
                      hbm_bw=hbm_bw)
    p2 = dense_engine(m, r, n, b, weight_wl=weight_wl, act_wl=act_wl,
                      hbm_bw=hbm_bw)
    hbm = p1.hbm_bytes + p2.hbm_bytes + 2 * m * r  # T write + read (int8)
    compute = p1.compute_s + p2.compute_s
    memory = hbm / hbm_bw
    return TpuPoint("single", max(compute, memory), compute, memory, hbm,
                    max(p1.vmem_bytes, p2.vmem_bytes),
                    {"blocks": dataclasses.asdict(b), "rank": r})


def cascade_engine(m, k, n, r, b: Blocks, *, weight_wl=8, act_wl=8,
                   hbm_bw=HBM_BW) -> TpuPoint:
    """Fused kernel: T lives in VMEM; W1 re-read once per M-block row, W2
    once per M-block; X once."""
    packed = _packed(weight_wl)
    # a factor packs only along an axis where packing pads no fatter
    # than the carrier (ops.packed_pad_ok; W1 packs along R, W2 along N)
    # — otherwise ops.lrmm demotes it to an int8 carrier up front, so
    # the model prices a carrier (1.0 B/elt, carrier padding) rather
    # than charging doubled padded MACs for halved bytes the kernel
    # never streams
    w1_packed = packed and packed_pad_ok(r)
    w2_packed = packed and packed_pad_ok(n)
    rp = _pad(r, 256 if w1_packed else 128)
    mp, kp, np_ = _pad(m, b.bm), _pad(k, b.bk), _pad(n, b.bn)
    macs = mp * kp * rp + mp * rp * np_
    compute = 2 * macs / (PEAK_OPS_INT8 * _mxu_util(b.bm, b.bk, b.bn))
    hbm = (mp * kp * _act_bytes(act_wl)            # X once
           + kp * rp * (mp // b.bm)
           * (_wl_bytes(weight_wl) if w1_packed else 1.0)    # W1 per row
           + rp * np_ * (mp // b.bm)
           * (_wl_bytes(weight_wl) if w2_packed else 1.0)    # W2 per row
           + mp * np_ * 4)                         # Y out f32
    memory = hbm / hbm_bw
    return TpuPoint("cascade", max(compute, memory), compute, memory, hbm,
                    lr_vmem(b.bm, b.bk, b.bn, rp, w1_packed=w1_packed,
                            w2_packed=w2_packed),
                    {"blocks": dataclasses.asdict(b), "rank": r})


def _wl_bytes(wl: int) -> float:
    """HBM bytes per element the TPU runtime ACTUALLY streams: W4 is
    packed two-nibbles-per-byte (kernels/quant_matmul.py unpacks in
    VMEM), everything else — including W6, which has no byte-aligned
    packing — rides a full int8 carrier. Activations are int8 carriers
    at every Ay. Pricing W6 at 6/8 would rank DSE designs by bandwidth
    the kernels cannot deliver (the FPGA model in engine_model.py keeps
    wl/8: that target has a native sub-8-bit datapath)."""
    return 0.5 if wl == 4 else 1.0


def _act_bytes(wl: int) -> float:
    """Activations are quantized on the fly into int8 carriers at every
    Ay — never packed — so they always stream a full byte."""
    del wl
    return 1.0


# ------------------------------------------------------ paged attention --
def paged_attention_point(ctx_lens, q_lens, *, num_kv_heads, head_dim,
                          num_heads=None, block_size=16, max_blocks=None,
                          kv_bits=16, streamed=True,
                          hbm_bw=HBM_BW) -> TpuPoint:
    """Price one serving-attention step over the blocked KV pool, so the
    DSE / bytes-moved accounting sees attention — the dominant decode
    term — and not just the linear layers.

    streamed=True models the Pallas paged-attention kernel: each active
    row DMAs exactly its ceil((ctx+q)/block_size) valid KV blocks, int8
    KV moves 1 B/element + f32 scale planes (dequantized in VMEM, never
    materialized in HBM). streamed=False models the jnp gather oracle:
    every row reads its FULL max_blocks·block_size logical view
    regardless of ctx, and int8 KV additionally round-trips a dense
    dequantized view at compute dtype. Compute is the QK^T + PV MACs over
    each path's own key window: the streamed kernel touches only valid
    blocks, while the gather path is charged the full max_blocks window
    it really runs the einsum over (masked-out slots still multiply) —
    so the gather point costs more in BOTH terms. Attention at serving
    widths is overwhelmingly memory-bound either way, which is what this
    point exists to show.
    """
    from repro.kernels import paged_attention as pa

    hk, dh = num_kv_heads, head_dim
    h = num_heads or hk
    ctx_lens = [int(c) for c in ctx_lens]
    q_lens = [int(q) for q in q_lens]
    if max_blocks is None:
        max_blocks = max((-(-(c + q) // block_size)
                          for c, q in zip(ctx_lens, q_lens)), default=1)
    if streamed:
        hbm = pa.stream_hbm_bytes(ctx_lens, q_lens, block_size, hk, dh,
                                  kv_bits=kv_bits, n_q_heads=h)
        keys = [(-(-(c + q) // block_size)) * block_size
                for c, q in zip(ctx_lens, q_lens) if q > 0]
    else:
        hbm = pa.gather_hbm_bytes(len(ctx_lens), max_blocks, block_size,
                                  hk, dh, kv_bits=kv_bits,
                                  w=max(q_lens, default=1), n_q_heads=h)
        keys = [max_blocks * block_size
                for q in q_lens if q > 0]
    w = max(q_lens, default=1)
    macs = sum(2 * w * (h // hk) * hk * dh * s for s in keys)  # QK^T + PV
    compute = 2 * macs / (PEAK_OPS_INT8 * _mxu_util(w * (h // hk), dh, 128))
    memory = hbm / hbm_bw
    kind = "pattn_stream" if streamed else "pattn_gather"
    return TpuPoint(kind, max(compute, memory), compute, memory, hbm, 0,
                    {"block_size": block_size, "max_blocks": max_blocks,
                     "kv_bits": kv_bits, "rows": len(ctx_lens)})


def block_space(max_bm=512):
    for bm in (8, 16, 32, 64, 128, 256, 512):
        if bm > max_bm:
            continue
        for bk in (128, 256, 512, 1024):
            for bn in (128, 256, 512, 1024):
                yield Blocks(bm, bk, bn)


def best_point(m, k, n, r=None, *, weight_wl=8, act_wl=8, hbm_bw=HBM_BW,
               engines=("baseline", "single", "cascade"),
               vmem_budget=VMEM_BYTES):
    """Lowest-latency feasible engine+blocks for one layer."""
    best = None
    for b in block_space(max_bm=max(8, min(512, _pad(m, 8)))):
        if not blocks_feasible(b, weight_wl, n):
            continue
        cands = []
        if "baseline" in engines:
            cands.append(dense_engine(m, k, n, b, weight_wl=weight_wl,
                                      act_wl=act_wl, hbm_bw=hbm_bw))
        if r is not None and "single" in engines:
            cands.append(single_engine(m, k, n, r, b, weight_wl=weight_wl,
                                       act_wl=act_wl, hbm_bw=hbm_bw))
        if r is not None and "cascade" in engines:
            cands.append(cascade_engine(m, k, n, r, b, weight_wl=weight_wl,
                                        act_wl=act_wl, hbm_bw=hbm_bw))
        for c in cands:
            if c.vmem_bytes > vmem_budget:
                continue
            if best is None or c.latency_s < best.latency_s:
                best = c
    return best


# ------------------------------------------------------------- speculation --

@dataclasses.dataclass(frozen=True)
class SpeculationPoint:
    """Priced self-speculative decoding trade for one (k, accept_rate)
    operating point (runtime/speculation.py is the thing being priced)."""

    k: int
    accept_rate: float
    expected_tokens: float          # E[tokens emitted per round]
    round_s: float                  # k draft steps + one verify step
    tokens_per_s: float
    baseline_tokens_per_s: float    # plain decode: 1 / full_step_s
    speedup: float
    breakeven_accept_rate: float    # min a where this k stops losing


def expected_tokens_per_round(k: int, accept_rate: float) -> float:
    """E[tokens emitted per speculative round] under i.i.d. per-token
    draft acceptance probability a: the accepted prefix is geometric
    truncated at k, and the verify pass always contributes one more
    token (the full model's own token at the first divergence, or the
    bonus token after a full accept):

        E = 1 + a + a^2 + ... + a^k = (1 - a^(k+1)) / (1 - a)
    """
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    if not 0.0 <= accept_rate <= 1.0:
        raise ValueError(f"accept_rate must be in [0, 1], got {accept_rate}")
    if accept_rate >= 1.0:
        return float(k + 1)
    return (1.0 - accept_rate ** (k + 1)) / (1.0 - accept_rate)


def breakeven_accept_rate(k: int, *, draft_cost_ratio: float,
                          verify_cost_ratio: float = 1.0) -> float:
    """Smallest per-token acceptance rate at which drafting k tokens per
    round emits tokens at least as fast as plain decode.

    A round costs k * draft_cost_ratio + verify_cost_ratio full-model
    steps and emits E(k, a) tokens, so the breakeven solves
    E(k, a) = k * dc + vc. E is strictly increasing in a, so bisection
    converges; the needed E grows linearly in k while E(k, a) saturates
    at 1/(1-a), so the breakeven rate is monotone non-decreasing in k —
    deeper drafts demand better drafts (asserted in tests). Returns 1.0
    when even a perfect draft cannot pay for itself (draft as expensive
    as the full model)."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if draft_cost_ratio <= 0.0 or verify_cost_ratio <= 0.0:
        raise ValueError("cost ratios must be positive")
    target = k * draft_cost_ratio + verify_cost_ratio
    if expected_tokens_per_round(k, 1.0) <= target:
        return 1.0
    lo, hi = 0.0, 1.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if expected_tokens_per_round(k, mid) < target:
            lo = mid
        else:
            hi = mid
    return hi


def speculation_point(k: int, accept_rate: float, *, full_step_s: float,
                      draft_step_s: float,
                      verify_step_s: float | None = None) -> SpeculationPoint:
    """Price one self-speculative operating point so the DSE can weigh
    draft depth k against a plan's measured/predicted acceptance rate.

    full_step_s   — one plain full-model decode step (the baseline pays
                    this per token; also the default verify cost).
    draft_step_s  — one truncated-cascade draft step (from the cascade
                    engine points at the draft rank).
    verify_step_s — the (k+1)-wide verify pass; defaults to full_step_s
                    (decode steps at serving widths are memory-bound, so
                    widening the span is nearly free — the whole reason
                    speculation pays).
    """
    if full_step_s <= 0.0 or draft_step_s <= 0.0:
        raise ValueError("step times must be positive")
    verify_step_s = full_step_s if verify_step_s is None else verify_step_s
    e = expected_tokens_per_round(k, accept_rate)
    round_s = k * draft_step_s + verify_step_s
    tps = e / round_s
    base = 1.0 / full_step_s
    return SpeculationPoint(
        k=int(k), accept_rate=float(accept_rate), expected_tokens=e,
        round_s=round_s, tokens_per_s=tps, baseline_tokens_per_s=base,
        speedup=tps / base,
        breakeven_accept_rate=breakeven_accept_rate(
            k, draft_cost_ratio=draft_step_s / full_step_s,
            verify_cost_ratio=verify_step_s / full_step_s))


# -------------------------------------------------------- tensor parallel --

@dataclasses.dataclass(frozen=True)
class TpPoint:
    """Priced tensor-parallel serving point: what the 2L boundary
    all-reduces of the shard_map step (models/transformer.unified_step
    under api.engine's TP wrapper) cost per step on the ICI fabric."""

    tp: int
    boundaries: int                 # psum sites per step (2 per layer)
    payload_bytes: int              # logical bytes reduced per boundary
    allreduce_bytes: int            # wire bytes per chip per step (all
    #                                 boundaries, ring all-reduce)
    allreduce_s: float              # ICI time per step
    step_s: float | None            # single-device step, when supplied
    tp_step_s: float | None         # modeled sharded step (compute/tp + ICI)
    speedup: float | None           # step_s / tp_step_s


def tp_point(*, batch: int, span_w: int, d_model: int, num_layers: int,
             tp: int, dtype_bytes: int = 2, step_s: float | None = None,
             ici_bw: float = ICI_BW_PER_LINK * ICI_LINKS) -> TpPoint:
    """Price one TP serving configuration for the DSE.

    The sharded step has exactly one all-reduce per attention boundary
    and one per MLP boundary (2 * num_layers total), each over the
    (batch, span_w, d_model) residual-stream activation. A ring
    all-reduce moves 2 * (tp - 1) / tp of the payload over the wire per
    chip, so tp = 1 prices to zero communication (it IS the
    single-device engine). With `step_s` (the measured or modeled
    single-device step) the point also reports the modeled sharded step
    time — perfectly-scaled compute plus the all-reduce — and its
    speedup; communication grows with tp while compute shrinks, which
    is the crossover the DSE sweeps for."""
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if batch < 1 or span_w < 1 or d_model < 1 or num_layers < 1:
        raise ValueError("batch/span_w/d_model/num_layers must be >= 1")
    boundaries = 2 * num_layers
    payload = batch * span_w * d_model * dtype_bytes
    wire = int(boundaries * payload * 2 * (tp - 1) / tp)
    allreduce_s = wire / ici_bw
    tp_step_s = speedup = None
    if step_s is not None:
        if step_s <= 0.0:
            raise ValueError(f"step_s must be positive, got {step_s}")
        tp_step_s = step_s / tp + allreduce_s
        speedup = step_s / tp_step_s
    return TpPoint(tp=int(tp), boundaries=boundaries, payload_bytes=payload,
                   allreduce_bytes=wire, allreduce_s=allreduce_s,
                   step_s=step_s, tp_step_s=tp_step_s, speedup=speedup)


# ----------------------------------------------------------- prefix cache --

@dataclasses.dataclass(frozen=True)
class PrefixCachePoint:
    """Priced prefix-cache operating point: prefill work a serving engine
    skips at a given cache hit rate (runtime/kvblocks + scheduler
    admission are the thing being priced). Savings have two ports, same
    as every engine here: MACs not run (linear layers + attention scores
    for the cached positions) and KV bytes not written back to HBM —
    int8-KV residency writes fewer bytes per cached token than bf16, so
    the cache and the paper's sub-8-bit story compound multiplicatively
    on capacity but the *bandwidth* saving per hit is smaller."""

    hit_rate: float
    tokens_cached: int              # block-aligned prompt tokens skipped
    tokens_computed: int
    macs: float                     # prefill MACs actually run
    macs_nocache: float
    macs_saved: float
    kv_bytes_written: float         # KV writeback for computed tokens
    kv_bytes_saved: float           # writeback skipped for cached tokens
    prefill_s: float                # max(compute, writeback) with cache
    prefill_s_nocache: float
    ttft_speedup: float             # prefill_s_nocache / prefill_s


def prefix_cache_point(prompt_len: int, hit_rate: float, *, num_layers: int,
                       d_model: int, d_ff: int, num_heads: int,
                       num_kv_heads: int, head_dim: int, block_size: int = 16,
                       kv_bits: int = 16,
                       hbm_bw: float = HBM_BW) -> PrefixCachePoint:
    """Price one (prompt_len, hit_rate) prefix-cache point.

    hit_rate is the fraction of prompt tokens served from cached blocks;
    the model rounds it down to whole blocks (only full blocks are ever
    shared) and keeps at least the final position computed (its logits
    seed decoding — the scheduler's copy-on-write rule). Cached
    positions cost nothing: no QKV/MLP MACs, no causal-attention score
    MACs, no KV writeback. Computed positions still attend over the
    whole (cached + computed) context — those reads happen either way,
    so they cancel out of the comparison and are not priced. Monotone by
    construction: more hits => fewer MACs, fewer bytes, never-slower
    prefill (asserted in tests)."""
    if prompt_len < 1:
        raise ValueError(f"prompt_len must be >= 1, got {prompt_len}")
    if not 0.0 <= hit_rate <= 1.0:
        raise ValueError(f"hit_rate must be in [0, 1], got {hit_rate}")
    if kv_bits not in (8, 16):
        raise ValueError(f"kv_bits must be 8 or 16, got {kv_bits}")
    h, hk, dh = num_heads, num_kv_heads, head_dim
    cached = min((int(hit_rate * prompt_len) // block_size) * block_size,
                 prompt_len - 1)
    # per-token linear MACs across all layers: QKV + output proj + MLP
    # (gate/up/down)
    lin = num_layers * (d_model * h * dh + 2 * d_model * hk * dh
                        + h * dh * d_model + 3 * d_model * d_ff)
    # causal attention scores: position p costs 2(p+1)·h·dh MACs (QK^T
    # and PV); cached positions skip theirs entirely
    tri = lambda n: n * (n + 1) // 2

    def _macs(n_cached: int) -> float:
        u = prompt_len - n_cached
        attn = 2 * num_layers * h * dh * (tri(prompt_len) - tri(n_cached))
        return u * lin + attn

    # KV writeback per token: int8 codes + f32 per-(token, head) scales,
    # or 2 B/element bf16
    kv_tok = num_layers * 2 * hk * (dh + 4 if kv_bits == 8 else 2 * dh)

    def _seconds(n_cached: int) -> float:
        u = prompt_len - n_cached
        compute = 2 * _macs(n_cached) / PEAK_OPS_INT8
        return max(compute, u * kv_tok / hbm_bw)

    with_cache, nocache = _seconds(cached), _seconds(0)
    return PrefixCachePoint(
        hit_rate=float(hit_rate), tokens_cached=cached,
        tokens_computed=prompt_len - cached,
        macs=_macs(cached), macs_nocache=_macs(0),
        macs_saved=_macs(0) - _macs(cached),
        kv_bytes_written=(prompt_len - cached) * kv_tok,
        kv_bytes_saved=cached * kv_tok,
        prefill_s=with_cache, prefill_s_nocache=nocache,
        ttft_speedup=nocache / with_cache)


# -------------------------------------------------------------- sampling --

@dataclasses.dataclass(frozen=True)
class SamplingPoint:
    """Priced per-step sampling point: fused in-device selection
    (models/transformer.serve_step's sample branch) vs the host
    round-trip alternative that ships full logits back over PCIe and
    pays a second dispatch to upload the picked tokens."""

    batch: int
    vocab: int
    sampled_frac: float             # fraction of rows with temperature > 0
    fused_ops: float                # argmax scan + top-k window ops
    fused_s: float                  # device-side selection time per step
    host_bytes: float               # logits shipped per step if host-sampled
    host_s: float                   # PCIe transfer + extra dispatch
    overhead_vs_greedy: float       # fused_s_sampled / fused_s_greedy
    speedup_vs_host: float          # host_s / fused_s


def sampling_point(*, batch: int, vocab: int, sampled_frac: float = 1.0,
                   logit_bytes: int = 4, peak_ops: float = PEAK_OPS_INT8,
                   pcie_bw: float = PCIE_BW,
                   dispatch_s: float = DISPATCH_S) -> SamplingPoint:
    """Price one (batch, vocab) sampling configuration for the DSE.

    The fused path selects tokens where the logits already live: greedy
    rows cost one O(B·V) argmax scan; sampled rows add the shared
    top-`TOPK_CAP` candidate window (O(B·V·log cap) compare-exchange
    ops — a bounded lax.top_k, not a full-vocab sort) that serves the
    top-k threshold, top-p mass, and categorical draw in one pass.
    Rows are priced by `sampled_frac` since temperature-0 rows take the
    argmax-only branch inside the same fused step. The host alternative
    pays (batch, vocab) float logits over PCIe every step plus one extra
    dispatch to push the chosen tokens back — latency that scales with
    vocab and never overlaps the next step, which is why the fused path
    wins by orders of magnitude at serving vocab sizes (asserted
    monotone in tests)."""
    if batch < 1 or vocab < 2:
        raise ValueError(f"need batch >= 1 and vocab >= 2, got "
                         f"batch={batch} vocab={vocab}")
    if not 0.0 <= sampled_frac <= 1.0:
        raise ValueError(
            f"sampled_frac must be in [0, 1], got {sampled_frac}")
    from repro.runtime.sampling import TOPK_CAP

    argmax_ops = batch * vocab
    window_ops = batch * vocab * math.log2(min(vocab, TOPK_CAP))
    fused_ops = argmax_ops + sampled_frac * window_ops
    # selection is elementwise/compare work, not MXU MACs: price at a
    # vector-unit fraction of peak
    vpu_ops = peak_ops / 8
    fused_s = fused_ops / vpu_ops
    host_bytes = batch * vocab * logit_bytes
    host_s = host_bytes / pcie_bw + dispatch_s
    greedy_s = argmax_ops / vpu_ops
    return SamplingPoint(
        batch=int(batch), vocab=int(vocab),
        sampled_frac=float(sampled_frac), fused_ops=fused_ops,
        fused_s=fused_s, host_bytes=host_bytes, host_s=host_s,
        overhead_vs_greedy=fused_s / greedy_s,
        speedup_vs_host=host_s / fused_s)
