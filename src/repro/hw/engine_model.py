"""Faithful implementation of the paper's analytical models (§VI, eqs 12-19)
with ZCU111 constants — used to reproduce Fig. 10/11 structure exactly as
published, BEFORE the TPU adaptation (hw/tpu_model.py) takes over for the
deployed system.

Conventions follow the paper: a MatMul engine computes Y[M,N] = X[M,K] @
W[K,N] on an Mt x Nt output-stationary PE array, each PE a Kf-parallel
vector-dot. Rates in words/cycle, workloads in words, latency in cycles.
"""
from __future__ import annotations

import dataclasses
import math

# ---------------------------------------------------------------- platform --
ZCU111 = {
    "dsp": 4272,
    "bram18k": 1080,
    "clock_hz": 200e6,
    # off-chip bandwidth in bits/cycle at 200 MHz (DDR4 ~19.2 GB/s)
    "offchip_bits_per_cycle": 19.2e9 * 8 / 200e6,
}


def f_packing(weight_wl: int) -> int:
    """Multiplications packed per DSP48 (paper cites M4BRAM [2])."""
    return {4: 2, 6: 2, 8: 1}.get(weight_wl, 1)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    mt: int
    nt: int
    kf: int


# ------------------------------------------------------------- eq 12-15 ----
def pe_rates(k: int, n: int, kf: int):
    cyc = math.ceil(k / kf)
    return {
        "r_lhs": k / (cyc * n),
        "r_rhs": kf,
        "r_o": 1.0 / cyc,
    }


def tile_rates(k: int, n: int, t: TileConfig):
    pe = pe_rates(k, n, t.kf)
    return {
        "r_lhs": t.mt * pe["r_lhs"],
        "r_rhs": t.nt * t.kf,
        "r_o": t.mt * t.nt * pe["r_o"],
    }


def tile_workloads(m: int, k: int, n: int, t: TileConfig):
    return {
        "w_lhs": m * k,
        "w_rhs": (m / t.mt) * k * n,
        "w_o": m * n,
    }


def tile_latency(m: int, k: int, n: int, t: TileConfig) -> float:
    """Eq. 15: slowest port wins (cycles)."""
    r = tile_rates(k, n, t)
    w = tile_workloads(m, k, n, t)
    return max(w["w_lhs"] / r["r_lhs"], w["w_rhs"] / r["r_rhs"],
               w["w_o"] / r["r_o"])


# ------------------------------------------------------------- eq 16-18 ----
def dsp_tile(t: TileConfig, weight_wl: int) -> int:
    return t.mt * t.nt * math.ceil(t.kf / f_packing(weight_wl))


def bram18(depth: int, bitwidth: int) -> int:
    """BRAM18K units for a FIFO of `depth` x `bitwidth` bits."""
    return max(1, math.ceil(depth * bitwidth / 18432))


def bram_tile(k: int, t: TileConfig, weight_wl: int, act_wl: int) -> int:
    depth = math.ceil(k / t.kf)
    per_pe = math.ceil(t.kf / f_packing(weight_wl))
    b_lhs = t.mt * per_pe * bram18(depth, act_wl)
    b_rhs = t.nt * per_pe * bram18(depth, weight_wl)
    return b_lhs + b_rhs


# ---------------------------------------------------------------- eq 19 ----
def bandwidth_bits_per_cycle(m, k, n, t: TileConfig, weight_wl, act_wl):
    w = tile_workloads(m, k, n, t)
    lat = tile_latency(m, k, n, t)
    bits = w["w_lhs"] * act_wl + w["w_rhs"] * weight_wl + w["w_o"] * act_wl
    return bits / lat


# ------------------------------------------------------- engine schedules --
@dataclasses.dataclass
class EnginePoint:
    kind: str                 # baseline | single | cascade
    latency_cycles: float
    dsp: int
    bram: int
    bandwidth: float          # bits/cycle required for full throughput
    config: dict


def baseline_engine(m, k, n, t: TileConfig, weight_wl=4, act_wl=8):
    return EnginePoint(
        "baseline", tile_latency(m, k, n, t), dsp_tile(t, weight_wl),
        bram_tile(k, t, weight_wl, act_wl),
        bandwidth_bits_per_cycle(m, k, n, t, weight_wl, act_wl),
        {"tile": dataclasses.asdict(t)},
    )


def single_engine(m, k, n, r, t: TileConfig, weight_wl=4, act_wl=8):
    """One array reused temporally: XW1 (M,K,R) then (XW1)W2 (M,R,N).
    The Nt factor tiles both R and N (paper §V-B); the Mt x R intermediate
    stays on-chip (no off-chip traffic for it)."""
    lat = tile_latency(m, k, r, t) + tile_latency(m, r, n, t)
    w_bits = (m * k * act_wl                 # X in
              + (m / t.mt) * k * r * weight_wl     # W1 streams
              + (m / t.mt) * r * n * weight_wl     # W2 streams
              + m * n * act_wl)              # Y out
    return EnginePoint(
        "single", lat, dsp_tile(t, weight_wl),
        bram_tile(k, t, weight_wl, act_wl) + _interm_bram(t.mt, r, act_wl),
        w_bits / lat, {"tile": dataclasses.asdict(t), "rank": r},
    )


def cascade_engine(m, k, n, r, t1: TileConfig, t2: TileConfig,
                   weight_wl=4, act_wl=8):
    """Two spatially pipelined arrays (same Mt); latency = slower stage."""
    assert t1.mt == t2.mt
    l1 = tile_latency(m, k, r, t1)
    l2 = tile_latency(m, r, n, t2)
    lat = max(l1, l2)
    w_bits = (m * k * act_wl
              + (m / t1.mt) * k * r * weight_wl
              + (m / t2.mt) * r * n * weight_wl
              + m * n * act_wl)
    return EnginePoint(
        "cascade", lat,
        dsp_tile(t1, weight_wl) + dsp_tile(t2, weight_wl),
        bram_tile(k, t1, weight_wl, act_wl)
        + bram_tile(r, t2, weight_wl, act_wl)
        + _interm_bram(t1.mt, r, act_wl),
        w_bits / lat,
        {"tile1": dataclasses.asdict(t1), "tile2": dataclasses.asdict(t2),
         "rank": r},
    )


def _interm_bram(mt, r, act_wl):
    return mt * bram18(r, act_wl)


# ----------------------------------------------------------------- search --
def _tile_space(max_mt=64, max_nt=64, max_kf=64):
    two = [1, 2, 4, 8, 16, 32, 64]
    for mt in two:
        for nt in two:
            for kf in two:
                if mt <= max_mt and nt <= max_nt and kf <= max_kf:
                    yield TileConfig(mt, nt, kf)


def pareto_front(points, x="bandwidth", y="latency_cycles"):
    pts = sorted(points, key=lambda p: (getattr(p, x), getattr(p, y)))
    front, best = [], float("inf")
    for p in pts:
        if getattr(p, y) < best:
            front.append(p)
            best = getattr(p, y)
    return front


def explore(m, k, n, r=None, *, weight_wl=4, act_wl=8, platform=ZCU111):
    """All feasible engine points under the platform constraints."""
    out = []
    for t in _tile_space():
        bp = baseline_engine(m, k, n, t, weight_wl, act_wl)
        if bp.dsp <= platform["dsp"] and bp.bram <= platform["bram18k"]:
            out.append(bp)
        if r is None:
            continue
        sp = single_engine(m, k, n, r, t, weight_wl, act_wl)
        if sp.dsp <= platform["dsp"] and sp.bram <= platform["bram18k"]:
            out.append(sp)
        for t2 in _tile_space(max_mt=t.mt):
            if t2.mt != t.mt:
                continue
            cp = cascade_engine(m, k, n, r, t, t2, weight_wl, act_wl)
            if cp.dsp <= platform["dsp"] and cp.bram <= platform["bram18k"]:
                out.append(cp)
    return out
