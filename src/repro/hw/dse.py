"""Hardware-aware Design Space Exploration (paper §VII).

The co-design loop:
  1. Model compression sweep (method x word length x rank budget) ->
     (quality, compression ratio, NOps) Pareto candidates;
  2. hardware-aware pruning: configurations whose engine working set
     exceeds platform resources are dropped;
  3. per candidate, pick the lowest-latency engine/tile per layer and sum
     -> (quality, latency) design points; return the Pareto front.

Works against either platform model:
  platform="zcu111" -> hw/engine_model (faithful paper reproduction)
  platform="tpu"    -> hw/tpu_model (deployed system; bandwidth scaling
                       models the paper's memory-bound regime)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.hw import engine_model as em
from repro.hw import tpu_model as tm


@dataclasses.dataclass
class LayerShape:
    name: str
    k: int
    n: int
    rank: int | None = None     # None -> dense/quant-only


@dataclasses.dataclass
class DesignPoint:
    label: str
    quality: float
    latency: float              # seconds (tpu) or cycles (zcu111)
    compression_ratio: float
    nops: float
    per_layer: list


def model_layers_from_report(report) -> list:
    """LayerShape list from a core.compress CompressionReport."""
    out = []
    for lr in report.layers:
        k, n = lr.shape[-2], lr.shape[-1]
        mult = lr.shape[0] if len(lr.shape) == 3 else 1
        for i in range(mult):
            out.append(LayerShape(f"{lr.path}[{i}]" if mult > 1 else lr.path,
                                  k, n, lr.rank))
    return out


def total_latency_tpu(layers: Sequence[LayerShape], batch_m: int, *,
                      weight_wl: int, bw_scale: float = 1.0,
                      engines=("baseline", "single", "cascade")):
    """Sum of per-layer best-engine latencies on the TPU model."""
    total = 0.0
    chosen = []
    for l in layers:
        p = tm.best_point(batch_m, l.k, l.n, l.rank, weight_wl=weight_wl,
                          hbm_bw=tm.HBM_BW * bw_scale, engines=engines)
        if p is None:
            return None, []
        total += p.latency_s
        chosen.append((l.name, p.kind, p.latency_s, p.config))
    return total, chosen


def total_latency_zcu111(layers: Sequence[LayerShape], batch_m: int, *,
                         weight_wl: int, bw_bits_per_cycle=None):
    """Per-layer best engine under ZCU111 resources (paper platform)."""
    plat = dict(em.ZCU111)
    if bw_bits_per_cycle is not None:
        plat["offchip_bits_per_cycle"] = bw_bits_per_cycle
    total = 0.0
    chosen = []
    for l in layers:
        pts = em.explore(batch_m, l.k, l.n, l.rank, weight_wl=weight_wl)
        pts = [p for p in pts
               if p.bandwidth <= plat["offchip_bits_per_cycle"]]
        if not pts:
            return None, []
        best = min(pts, key=lambda p: p.latency_cycles)
        total += best.latency_cycles
        chosen.append((l.name, best.kind, best.latency_cycles, best.config))
    return total, chosen


def pareto(points: Sequence[DesignPoint]) -> list:
    """Upper-left front: max quality, min latency."""
    pts = sorted(points, key=lambda p: (p.latency, -p.quality))
    front, best_q = [], -float("inf")
    for p in pts:
        if p.quality > best_q:
            front.append(p)
            best_q = p.quality
    return front


def co_design(
    candidates: Sequence[dict],
    quality_fn: Callable[[dict], float],
    layers_fn: Callable[[dict], Sequence[LayerShape]],
    *,
    batch_m: int = 512,
    platform: str = "tpu",
    bw_scale: float = 1.0,
) -> list:
    """Full paper-§VII loop. `candidates` are compression configs (dicts
    with method/wl/rank info); quality_fn evaluates the calibration metric;
    layers_fn yields the layer shapes+ranks for the latency model."""
    points = []
    for cand in candidates:
        q = quality_fn(cand)
        layers = list(layers_fn(cand))
        if platform == "tpu":
            lat, chosen = total_latency_tpu(
                layers, batch_m, weight_wl=cand["wl"], bw_scale=bw_scale,
                engines=cand.get("engines",
                                 ("baseline", "single", "cascade")))
        else:
            lat, chosen = total_latency_zcu111(layers, batch_m,
                                               weight_wl=cand["wl"])
        if lat is None:
            continue
        points.append(DesignPoint(
            label=cand.get("label", str(cand)), quality=q, latency=lat,
            compression_ratio=cand.get("ratio", 0.0),
            nops=cand.get("nops", 0.0), per_layer=chosen))
    return pareto(points)
