"""Hardware-aware Design Space Exploration (paper §VII).

The co-design loop:
  1. Model compression sweep -> candidate `CompressionPlan`s (per-layer
     method x word length x rank) with (quality, ratio, NOps) accounting;
  2. hardware-aware pruning: configurations whose engine working set
     exceeds platform resources are dropped;
  3. per candidate, pick the lowest-latency engine/tile per layer and sum
     -> (quality, latency) design points; return the Pareto front.

Candidates ARE plans: every returned `DesignPoint` carries the plan it was
scored from, so a Pareto winner deploys directly via
`api.plan.CompressionPlan.from_design_point(dp)` -> `InferenceEngine.build`
— the DSE output is never dead on arrival.

Works against either platform model:
  platform="zcu111" -> hw/engine_model (faithful paper reproduction)
  platform="tpu"    -> hw/tpu_model (deployed system; bandwidth scaling
                       models the paper's memory-bound regime)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

from repro.hw import engine_model as em
from repro.hw import tpu_model as tm


@dataclasses.dataclass
class LayerShape:
    name: str
    k: int
    n: int
    rank: int | None = None     # None -> dense/quant-only
    wl: int | None = None       # per-layer weight word length override


@dataclasses.dataclass
class DesignPoint:
    label: str
    quality: float
    latency: float              # seconds (tpu) or cycles (zcu111)
    compression_ratio: float
    nops: float
    per_layer: list
    plan: Any = None            # the api.plan.CompressionPlan evaluated


def model_layers_from_report(report) -> list:
    """LayerShape list from a core.compress CompressionReport."""
    out = []
    for lr in report.layers:
        k, n = lr.shape[-2], lr.shape[-1]
        mult = lr.shape[0] if len(lr.shape) == 3 else 1
        for i in range(mult):
            out.append(LayerShape(f"{lr.path}[{i}]" if mult > 1 else lr.path,
                                  k, n, lr.rank, wl=lr.wl))
    return out


def layer_shapes_from_plan(plan, params) -> list:
    """LayerShape list (stacks expanded) for a plan's active layers."""
    from repro.core.compress import param_leaves_by_path

    leaves = param_leaves_by_path(params)
    out = []
    for lp in plan.active_layers():
        leaf = leaves[lp.path]
        k, n = int(leaf.shape[-2]), int(leaf.shape[-1])
        mult = 1
        for d in leaf.shape[:-2]:
            mult *= int(d)
        rank = None if lp.rank is None else min(int(lp.rank), min(k, n))
        for i in range(mult):
            out.append(LayerShape(
                f"{lp.path}[{i}]" if mult > 1 else lp.path,
                k, n, rank, wl=lp.wl))
    return out


def total_latency_tpu(layers: Sequence[LayerShape], batch_m: int, *,
                      weight_wl: int = 8, bw_scale: float = 1.0,
                      engines=("baseline", "single", "cascade")):
    """Sum of per-layer best-engine latencies on the TPU model. A layer's
    own wl (mixed-precision plans) overrides the global `weight_wl`."""
    total = 0.0
    chosen = []
    for l in layers:
        p = tm.best_point(batch_m, l.k, l.n, l.rank,
                          weight_wl=l.wl or weight_wl,
                          hbm_bw=tm.HBM_BW * bw_scale, engines=engines)
        if p is None:
            return None, []
        total += p.latency_s
        chosen.append((l.name, p.kind, p.latency_s, p.config))
    return total, chosen


def total_latency_zcu111(layers: Sequence[LayerShape], batch_m: int, *,
                         weight_wl: int = 8, bw_bits_per_cycle=None):
    """Per-layer best engine under ZCU111 resources (paper platform)."""
    plat = dict(em.ZCU111)
    if bw_bits_per_cycle is not None:
        plat["offchip_bits_per_cycle"] = bw_bits_per_cycle
    total = 0.0
    chosen = []
    for l in layers:
        pts = em.explore(batch_m, l.k, l.n, l.rank,
                         weight_wl=l.wl or weight_wl)
        pts = [p for p in pts
               if p.bandwidth <= plat["offchip_bits_per_cycle"]]
        if not pts:
            return None, []
        best = min(pts, key=lambda p: p.latency_cycles)
        total += best.latency_cycles
        chosen.append((l.name, best.kind, best.latency_cycles, best.config))
    return total, chosen


def pareto(points: Sequence[DesignPoint]) -> list:
    """Upper-left front: max quality, min latency."""
    pts = sorted(points, key=lambda p: (p.latency, -p.quality))
    front, best_q = [], -float("inf")
    for p in pts:
        if p.quality > best_q:
            front.append(p)
            best_q = p.quality
    return front


def co_design(
    candidates: Sequence,
    quality_fn: Callable[[Any], float],
    layers_fn: Callable[[Any], Sequence[LayerShape]] | None = None,
    *,
    params=None,
    batch_m: int = 512,
    platform: str = "tpu",
    bw_scale: float = 1.0,
) -> list:
    """Full paper-§VII loop over `CompressionPlan` candidates.

    quality_fn(plan) evaluates the calibration metric; layers_fn(plan)
    yields the layer shapes+ranks+wls for the latency model (defaults to
    `layer_shapes_from_plan` against `params`). Plans may stash accounting
    in plan.meta: "ratio" / "nops" flow into the DesignPoint, and
    "engines_allowed" restricts the TPU engine search. Returns the Pareto
    front; each point carries its plan for deployment.
    """
    from repro.api.plan import CompressionPlan

    if layers_fn is None:
        if params is None:
            raise ValueError("co_design needs layers_fn or params")
        layers_fn = lambda plan: layer_shapes_from_plan(plan, params)  # noqa: E731

    points = []
    for plan in candidates:
        if not isinstance(plan, CompressionPlan):
            raise TypeError(
                f"co_design candidates must be CompressionPlans, got "
                f"{type(plan).__name__} — dict candidates are no longer "
                f"supported (build one with CompressionPlan.uniform / "
                f"from_config)")
        q = quality_fn(plan)
        layers = list(layers_fn(plan))
        meta = getattr(plan, "meta", {}) or {}
        if platform == "tpu":
            lat, chosen = total_latency_tpu(
                layers, batch_m, bw_scale=bw_scale,
                engines=tuple(meta.get("engines_allowed",
                                       ("baseline", "single", "cascade"))))
        else:
            lat, chosen = total_latency_zcu111(layers, batch_m)
        if lat is None:
            continue
        points.append(DesignPoint(
            label=getattr(plan, "label", "") or str(plan),
            quality=q, latency=lat,
            compression_ratio=float(meta.get("ratio", 0.0)),
            nops=float(meta.get("nops", 0.0)),
            per_layer=chosen, plan=plan))
    return pareto(points)
