"""HLO-text cost analyzer for the dry-run roofline.

Why not `compiled.cost_analysis()`? XLA's aggregate counts each while-loop
*body once*, but scan-over-layers puts ~all of a model inside a while loop
with known_trip_count = num_layers — the aggregate under-counts FLOPs and
collective bytes by that factor. This analyzer walks the post-SPMD HLO
call graph and multiplies every computation's cost by the product of
enclosing trip counts (parsed from `backend_config known_trip_count`).

Cost model (per device — post-SPMD HLO is the per-device program):
  * flops            — dot/convolution only: 2·prod(result)·prod(contract).
                       Elementwise FLOPs are ignored (≪1% for LLM steps;
                       DESIGN.md §8). Counted *inside* fusions too.
  * mem_bytes        — Σ over non-fused ops of (operand + result bytes);
                       fusions count as single ops (their internals stay
                       on-chip); slice/gather/dynamic-update-slice ops are
                       charged at slice size, NOT full-operand size (else
                       every scan iteration would be billed for the whole
                       (L, ...) stacked weight tensor it slices from).
                       This is the HBM-traffic proxy.
  * collective_bytes — Σ operand bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute /
                       *-start variants (counted once per executed op).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    mem_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_ops: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    mem_by_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other, mult=1.0):
        self.flops += other.flops * mult
        self.mem_bytes += other.mem_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_ops.items():
            self.collective_ops[k] += v * mult
        for k, v in other.mem_by_op.items():
            self.mem_by_op[k] += v * mult

    def note_mem(self, op: str, b: float):
        self.mem_bytes += b
        self.mem_by_op[op] += b


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")


def _parse_instr(line: str):
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # type is either "(...)" tuple or "dtype[dims]{layout}"
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest2 = rest[: i + 1], rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        type_str, rest2 = rest[:sp], rest[sp + 1:].strip()
    om = re.match(r"([\w\-]+)\(", rest2)
    if not om:
        return None
    opcode = om.group(1)
    # operands: %names inside the first (...) group
    depth = 0
    args_end = len(rest2)
    for i in range(om.end() - 1, len(rest2)):
        depth += rest2[i] == "("
        depth -= rest2[i] == ")"
        if depth == 0:
            args_end = i
            break
    args = rest2[om.end(): args_end]
    operands = re.findall(r"%([\w.\-]+)", args)
    attrs = rest2[args_end:]
    return Instr(name, type_str, opcode, operands, attrs)


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(text)
        # symbol table per computation: instr name -> type string
        self.symbols = {
            cname: {i.name: i.type_str for i in instrs}
            for cname, instrs in self.computations.items()
        }
        # computation parameters also have types (from the header), add them
        self._memo: dict[str, Costs] = {}

    def _parse(self, text: str):
        cur = None
        header_re = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->")
        for raw in text.splitlines():
            if not raw.strip():
                continue
            if not raw.startswith(" "):
                h = header_re.match(raw)
                if h:
                    cur = h.group(2)
                    self.computations[cur] = []
                    if h.group(1):
                        self.entry = cur
                    # parameters: "pname: type" pairs
                    params = h.group(3)
                    for pm in re.finditer(
                            r"([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\]{},]+))",
                            params):
                        self.computations[cur].append(
                            Instr(pm.group(1), pm.group(2), "parameter", [],
                                  ""))
                continue
            if cur is None:
                continue
            ins = _parse_instr(raw)
            if ins:
                self.computations[cur].append(ins)

    # ------------------------------------------------------------- costs --
    def _operand_bytes(self, comp: str, ins: Instr) -> int:
        table = self.symbols[comp]
        return sum(_shape_bytes(table.get(o, "")) for o in ins.operands)

    def _op_mem(self, comp: str, ins: Instr) -> float:
        """HBM traffic of one op. Slice-like ops only touch the slice:
        charging their full operands would bill every scan iteration for
        the whole (L, ...) stacked weight tensor it slices from."""
        table = self.symbols[comp]
        rb = _shape_bytes(ins.type_str)
        obs = [_shape_bytes(table.get(o, "")) for o in ins.operands]
        tag = ins.name + "|" + ins.opcode
        if "dynamic-update-slice" in tag:
            # in-place region update: traffic = update read + write
            small = [b for b in obs if b < rb]
            return 2 * (max(small) if small else rb) + 16
        if ("dynamic-slice" in tag or "gather" in tag
                or ins.opcode in ("dynamic-slice", "gather", "slice")):
            return rb + sum(b for b in obs if b <= 2 * rb)
        return rb + sum(obs)

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        _, rdims = _shape_dims(ins.type_str)
        out = 1.0
        for d in rdims:
            out *= d
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        lhs_type = self.symbols[comp].get(ins.operands[0], "") if ins.operands else ""
        _, ldims = _shape_dims(lhs_type)
        contract = 1.0
        if cm and ldims:
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(ldims):
                    contract *= ldims[int(idx)]
        return 2.0 * out * contract

    def _conv_flops(self, comp: str, ins: Instr) -> float:
        # result elements x 2 x (kernel spatial x in-channels): approximate
        # via operand1 (kernel) size / out_channels
        _, rdims = _shape_dims(ins.type_str)
        out = 1.0
        for d in rdims:
            out *= d
        if len(ins.operands) > 1:
            _, kdims = _shape_dims(self.symbols[comp].get(ins.operands[1], ""))
            k = 1.0
            for d in kdims:
                k *= d
            if rdims:
                k /= max(rdims[-1], 1)
            return 2.0 * out * k
        return 2.0 * out

    def _called(self, ins: Instr, key: str):
        m = re.search(key + r"=%([\w.\-]+)", ins.attrs)
        return m.group(1) if m else None

    def _trip_count(self, ins: Instr) -> float:
        m = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', ins.attrs)
        return float(m.group(1)) if m else 1.0

    def comp_costs(self, comp: str) -> Costs:
        """Costs of one execution of `comp` (recursive, memoized)."""
        if comp in self._memo:
            return self._memo[comp]
        c = Costs()
        self._memo[comp] = c  # break cycles defensively
        for ins in self.computations.get(comp, []):
            op = ins.opcode
            base = op.replace("-start", "")
            if op == "parameter":
                continue
            if base in _COLLECTIVES:
                b = self._operand_bytes(comp, ins)
                c.collective_bytes += b
                c.collective_ops[base] += b
                c.note_mem(base, b + _shape_bytes(ins.type_str))
                continue
            if op == "while":
                trips = self._trip_count(ins)
                body = self._called(ins, "body")
                cond = self._called(ins, "condition")
                if body:
                    c.add(self.comp_costs(body), trips)
                if cond:
                    c.add(self.comp_costs(cond), trips)
                continue
            if op in ("call", "conditional", "async-start"):
                for key in ("to_apply", "calls", "branch_computations"):
                    tgt = self._called(ins, key)
                    if tgt:
                        c.add(self.comp_costs(tgt))
                continue
            if op == "fusion":
                # single mem op; descend for dot flops only
                c.note_mem("fusion", self._op_mem(comp, ins))
                tgt = self._called(ins, "calls")
                if tgt:
                    c.flops += self.comp_costs(tgt).flops
                continue
            if op == "dot":
                c.flops += self._dot_flops(comp, ins)
                c.note_mem("dot", self._operand_bytes(comp, ins)
                           + _shape_bytes(ins.type_str))
                continue
            if op == "convolution":
                c.flops += self._conv_flops(comp, ins)
                c.note_mem("convolution", self._operand_bytes(comp, ins)
                           + _shape_bytes(ins.type_str))
                continue
            if op in ("constant", "iota", "parameter", "get-tuple-element",
                      "tuple", "bitcast", "after-all", "partition-id",
                      "replica-id"):
                continue
            # generic op: memory traffic only
            c.note_mem(op, self._op_mem(comp, ins))
        self._memo[comp] = c
        return c

    def entry_costs(self) -> Costs:
        assert self.entry, "no ENTRY computation found"
        return self.comp_costs(self.entry)


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    c = mod.entry_costs()
    top = dict(sorted(c.mem_by_op.items(), key=lambda kv: -kv[1])[:12])
    return {
        "flops_per_device": c.flops,
        "mem_bytes_per_device": c.mem_bytes,
        "collective_bytes_per_device": c.collective_bytes,
        "collective_breakdown": dict(c.collective_ops),
        "mem_top_ops": top,
    }
