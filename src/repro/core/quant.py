"""Fixed-point quantization schemes (paper §III, §VIII-B).

The paper uses symmetric fixed-point quantization with notation WxAy
(weight word length x, activation word length y). Quantization is applied
*vector-wise* ("quantization is applied vector-wise in the produced matrix"
— §VIII-B) which on a (K, N) weight matrix means one scale per output
column (per-channel), and on the SVD factors one scale per rank-column /
rank-row.

On TPU there is no native int4/int6 datapath: values are stored in an int8
carrier clamped to the word-length range; the *storage* cost used for
compression-ratio accounting is the true word length (packed int4 / int6
in HBM — see core/compress.py). The MXU computes int8xint8->int32.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def qmax(wl: int) -> int:
    """Largest magnitude representable by a symmetric signed `wl`-bit code."""
    if wl < 2:
        raise ValueError(f"word length must be >= 2, got {wl}")
    return 2 ** (wl - 1) - 1


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """A symmetric per-axis quantized tensor.

    values : integer codes in an int8 carrier (|v| <= qmax(wl))
    scale  : fp32 scale, broadcastable against `values` along `axis`
    wl     : word length in bits (4, 6, 8) — the *storage* width
    axis   : axis along which scales are shared (the reduction axis of the
             matmul this tensor feeds); scale shape has 1 there.
    """

    values: Array
    scale: Array
    wl: int
    axis: int

    @property
    def shape(self):
        return self.values.shape

    def dequant(self) -> Array:
        return self.values.astype(jnp.float32) * self.scale

    def storage_bits(self) -> int:
        """True HBM storage cost in bits (packed sub-8-bit + fp32 scales)."""
        n = 1
        for d in self.values.shape:
            n *= int(d)
        ns = 1
        for d in self.scale.shape:
            ns *= int(d)
        return n * self.wl + ns * 32


jax.tree_util.register_pytree_with_keys(
    QuantizedTensor,
    lambda q: ((("values", q.values), ("scale", q.scale)), (q.wl, q.axis)),
    lambda aux, ch: QuantizedTensor(ch[0], ch[1], aux[0], aux[1]),
)


@partial(jax.jit, static_argnames=("wl", "axis"))
def quantize(x: Array, wl: int, axis: int = 0) -> QuantizedTensor:
    """Symmetric per-vector quantization of `x` along `axis`.

    `axis` is the reduction axis: scales are shared along it (one scale per
    remaining index), matching the paper's vector-wise scheme.
    """
    m = qmax(wl)
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / m, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -m, m).astype(jnp.int8)
    return QuantizedTensor(q, scale, wl, axis)


def dequantize(q: QuantizedTensor) -> Array:
    return q.dequant()


@partial(jax.jit, static_argnames=("wl", "axis"))
def fake_quant(x: Array, wl: int, axis: int = 0) -> Array:
    """Quantize-dequantize in one go (used for activation quantization and
    for emulating the quantized model in fp math)."""
    m = qmax(wl)
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / m, 1.0)
    return jnp.clip(jnp.round(x / scale), -m, m) * scale


@partial(jax.jit, static_argnames=("w_wl", "a_wl"))
def quant_linear_ref(x: Array, w: Array, w_wl: int, a_wl: int) -> Array:
    """Reference WxAy linear layer: y = Qa(x) @ Qw(w).

    Weight scales are per output channel (axis=0 of the (K, N) matrix is the
    reduction axis); activation scales per token row.
    """
    qw = quantize(w, w_wl, axis=0)
    xq = fake_quant(x, a_wl, axis=-1)
    return xq @ qw.dequant()


def pack_int4(codes: Array) -> Array:
    """Pack int8-carried int4 codes into bytes (two nibbles per byte).

    Storage-layer utility: models the HBM layout for W4. The last dim must
    be even. Values must be in [-8, 7].
    """
    lo = codes[..., 0::2] & 0x0F
    hi = (codes[..., 1::2] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: Array) -> Array:
    """Inverse of pack_int4 (sign-extends each nibble)."""
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed.astype(jnp.int32) >> 4) & 0x0F).astype(jnp.int8)

    def sext(v):
        return jnp.where(v >= 8, v - 16, v)

    out = jnp.stack([sext(lo), sext(hi)], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
