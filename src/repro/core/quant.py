"""Fixed-point quantization schemes (paper §III, §VIII-B).

The paper uses symmetric fixed-point quantization with notation WxAy
(weight word length x, activation word length y). Quantization is applied
*vector-wise* ("quantization is applied vector-wise in the produced matrix"
— §VIII-B) which on a (K, N) weight matrix means one scale per output
column (per-channel), and on the SVD factors one scale per rank-column /
rank-row.

On TPU there is no native int4/int6 datapath, but HBM residency does not
have to pay for the carrier: W4 tensors are *packed* two nibbles per int8
byte in HBM (`pack_weights`) and unpacked on-chip, inside the Pallas
kernels, right before the int8xint8->int32 MXU dot. W6 has no byte-aligned
packing (4 codes per 3 bytes straddles lanes) and stays int8-carrier
resident — and is *accounted* as 8 bits, not 6: `storage_bits()` reports
the bytes the device arrays actually occupy, never a pretended packed
size. See core/compress.py for whole-model accounting.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def qmax(wl: int) -> int:
    """Largest magnitude representable by a symmetric signed `wl`-bit code."""
    if wl < 2:
        raise ValueError(f"word length must be >= 2, got {wl}")
    return 2 ** (wl - 1) - 1


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """A symmetric per-axis quantized tensor.

    values : integer codes. Carrier layout: one int8 per code
             (|v| <= qmax(wl)). Packed layout (`packed=True`, wl == 4
             only): two nibble codes per int8 byte along the LAST axis,
             so `values.shape[-1]` is half the logical width.
    scale  : fp32 scale, broadcastable against the *logical* values along
             `axis`
    wl     : word length in bits (4, 6, 8) — the code range
    axis   : axis along which scales are shared (the reduction axis of the
             matmul this tensor feeds); scale shape has 1 there.
    packed : True when `values` holds the packed-nibble HBM layout
    act_wl : word length the activations feeding this weight's matmul are
             quantized to at runtime (the plan's WxAy "Ay"); 8 keeps the
             historical A8 behavior bit-identical.

    `wl`, `axis`, `packed`, `act_wl` are pytree aux data: static under
    jit, so kernels specialize on the layout and clamp range, and a plan
    with a different act_wl or packing retraces instead of reusing a
    stale compilation.
    """

    values: Array
    scale: Array
    wl: int
    axis: int
    packed: bool = False
    act_wl: int = 8

    @property
    def shape(self):
        """LOGICAL shape (unpacked), regardless of residency layout."""
        s = self.values.shape
        if self.packed:
            return (*s[:-1], s[-1] * 2)
        return s

    def dequant(self) -> Array:
        v = unpack_int4(self.values) if self.packed else self.values
        return v.astype(jnp.float32) * self.scale

    def storage_bits(self) -> int:
        """HBM storage cost in bits of the arrays as they are actually
        resident: 8 bits per stored byte (so wl per logical code when
        packed, a full 8 for any int8-carrier tensor — including W4/W6
        that was *not* packed) plus fp32 scales. Honest by construction:
        it counts device bytes, not the word length we wish we stored."""
        n = 1
        for d in self.values.shape:
            n *= int(d)
        ns = 1
        for d in self.scale.shape:
            ns *= int(d)
        return n * 8 + ns * 32


jax.tree_util.register_pytree_with_keys(
    QuantizedTensor,
    lambda q: ((("values", q.values), ("scale", q.scale)),
               (q.wl, q.axis, q.packed, q.act_wl)),
    lambda aux, ch: QuantizedTensor(ch[0], ch[1], *aux),
)


@partial(jax.jit, static_argnames=("wl", "axis"))
def quantize(x: Array, wl: int, axis: int = 0) -> QuantizedTensor:
    """Symmetric per-vector quantization of `x` along `axis`.

    `axis` is the reduction axis: scales are shared along it (one scale per
    remaining index), matching the paper's vector-wise scheme.
    """
    m = qmax(wl)
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / m, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -m, m).astype(jnp.int8)
    return QuantizedTensor(q, scale, wl, axis)


def dequantize(q: QuantizedTensor) -> Array:
    return q.dequant()


@partial(jax.jit, static_argnames=("wl", "axis"))
def fake_quant(x: Array, wl: int, axis: int = 0) -> Array:
    """Quantize-dequantize in one go (used for activation quantization and
    for emulating the quantized model in fp math)."""
    m = qmax(wl)
    absmax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / m, 1.0)
    return jnp.clip(jnp.round(x / scale), -m, m) * scale


@partial(jax.jit, static_argnames=("w_wl", "a_wl"))
def quant_linear_ref(x: Array, w: Array, w_wl: int, a_wl: int) -> Array:
    """Reference WxAy linear layer: y = Qa(x) @ Qw(w).

    Weight scales are per output channel (axis=0 of the (K, N) matrix is the
    reduction axis); activation scales per token row.
    """
    qw = quantize(w, w_wl, axis=0)
    xq = fake_quant(x, a_wl, axis=-1)
    return xq @ qw.dequant()


def pack_int4(codes: Array) -> Array:
    """Pack int8-carried int4 codes into bytes (two nibbles per byte).

    This IS the HBM layout for packed W4 weights: element 2i goes to the
    low nibble of byte i, element 2i+1 to the high nibble (matching the
    in-kernel unpack in kernels/quant_matmul.py). The last dim must be
    even. Values must be in [-8, 7].
    """
    if codes.shape[-1] % 2:
        raise ValueError(
            f"pack_int4 needs an even last dim, got shape {codes.shape}")
    lo = codes[..., 0::2] & 0x0F
    hi = (codes[..., 1::2] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: Array) -> Array:
    """Inverse of pack_int4 (sign-extends each nibble)."""
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed.astype(jnp.int32) >> 4) & 0x0F).astype(jnp.int8)

    def sext(v):
        return jnp.where(v >= 8, v - 16, v)

    out = jnp.stack([sext(lo), sext(hi)], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def packed_pad_ok(dim: int) -> bool:
    """Whether nibble-packing a `dim`-wide axis is free of padding
    inflation in the Pallas kernels: a packed half-width must stay
    128-lane aligned, so a packed axis pads to a multiple of 256 where
    its int8 carrier pads to 128. When the two round-ups differ (dim %
    256 in 1..128 — e.g. a rank-128 cascade factor, or the smoke model's
    64-wide heads), packing buys nothing at runtime: the kernel streams
    the same padded bytes as the carrier but runs double the padded MXU
    work (the old `kernel_lrmm_interp_W4_packed_paper512` regression).
    Such axes stay int8 carriers — `packable` gates on this, so the
    decision is made ONCE at pack time, not paid per dispatch."""
    return -(-dim // 256) * 256 == -(-dim // 128) * 128


def packable(q: QuantizedTensor) -> bool:
    """True when `q` can move to the packed-nibble layout: W4 codes (the
    only word length whose packing is byte-aligned) with an even last
    dim whose packed padding does not exceed its carrier's
    (`packed_pad_ok`), not already packed."""
    return (not q.packed and q.wl == 4
            and int(q.values.shape[-1]) % 2 == 0
            and packed_pad_ok(int(q.values.shape[-1])))


def pack_weights(q: QuantizedTensor) -> QuantizedTensor:
    """Move a W4 tensor to the packed HBM-resident layout (exact: the
    codes are unchanged, only the byte layout differs). Non-packable
    tensors (W6/W8, odd last dim, pad-inflating last dim) are returned
    as-is — they stay int8 carriers and `storage_bits()` charges them
    the full 8 bits."""
    if not packable(q):
        return q
    return dataclasses.replace(q, values=pack_int4(q.values), packed=True)


def unpack_weights(q: QuantizedTensor) -> QuantizedTensor:
    """Inverse of pack_weights: back to the int8-carrier layout."""
    if not q.packed:
        return q
    return dataclasses.replace(q, values=unpack_int4(q.values), packed=False)
