"""Sensitivity-based Rank Allocation (paper §IV).

Generic over the model: the caller supplies `eval_fn(ranks) -> accuracy`
(higher is better — BLEU in the paper, token accuracy / −loss here) and the
per-layer maximum ranks. The algorithm is the paper's verbatim:

  1. split the budget equally,
  2. estimate per-layer sensitivity S_i = ∂A/∂r_i by central finite
     differences with step δ (eq. 8),
  3. move δ ranks from the least- to the most-sensitive layer (eqs. 9–10),
  4. decay δ_n = round(δ0 / (1 + α·n)) (eq. 11),
  5. stop on convergence or max iterations.

Evaluations are memoized — the finite-difference probes re-visit nearby
allocations constantly and each probe is a full calibration pass.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence


@dataclasses.dataclass
class SRAResult:
    ranks: list[int]
    accuracy: float
    history: list[tuple[list[int], float]]  # (allocation, accuracy) per iter
    evals: int


def _clip_alloc(ranks, max_ranks, min_rank):
    return [min(max(r, min_rank), mx) for r, mx in zip(ranks, max_ranks)]


def sra_allocate(
    eval_fn: Callable[[Sequence[int]], float],
    num_layers: int,
    total_budget: int,
    max_ranks: Sequence[int],
    *,
    min_rank: int = 1,
    delta0: int | None = None,
    alpha: float = 0.15,
    max_iters: int = 40,
    patience: int = 6,
) -> SRAResult:
    """Run SRA. Returns the best allocation seen (not merely the last)."""
    if len(max_ranks) != num_layers:
        raise ValueError("max_ranks must have one entry per layer")
    if total_budget > sum(max_ranks):
        raise ValueError("budget exceeds sum of max ranks")

    # 1) Initial setup: equal split (remainder spread over the first layers).
    base, rem = divmod(total_budget, num_layers)
    ranks = [base + (1 if i < rem else 0) for i in range(num_layers)]
    ranks = _clip_alloc(ranks, max_ranks, min_rank)
    # re-balance if clipping changed the total
    ranks = _rebalance(ranks, total_budget, max_ranks, min_rank)

    if delta0 is None:
        delta0 = max(1, base // 4)

    cache: dict[tuple, float] = {}

    def ev(alloc) -> float:
        key = tuple(alloc)
        if key not in cache:
            cache[key] = float(eval_fn(list(key)))
        return cache[key]

    best_alloc, best_acc = list(ranks), ev(ranks)
    history = [(list(ranks), best_acc)]
    stall = 0

    for n in range(max_iters):
        delta = max(1, round(delta0 / (1.0 + alpha * n)))
        # 3) central finite-difference sensitivities (eq. 8)
        sens = []
        for i in range(num_layers):
            up = list(ranks)
            dn = list(ranks)
            up[i] = min(up[i] + delta, max_ranks[i])
            dn[i] = max(dn[i] - delta, min_rank)
            span = up[i] - dn[i]
            if span == 0:
                sens.append(0.0)
                continue
            sens.append((ev(up) - ev(dn)) / span)

        # 4) move delta ranks from argmin to argmax sensitivity (eqs. 9–10),
        #    respecting per-layer bounds.
        order_hi = sorted(range(num_layers), key=lambda i: -sens[i])
        order_lo = sorted(range(num_layers), key=lambda i: sens[i])
        i_hi = next((i for i in order_hi if ranks[i] + delta <= max_ranks[i]), None)
        i_lo = next(
            (j for j in order_lo if ranks[j] - delta >= min_rank and j != i_hi),
            None,
        )
        if i_hi is None or i_lo is None:
            break
        ranks[i_hi] += delta
        ranks[i_lo] -= delta

        acc = ev(ranks)
        history.append((list(ranks), acc))
        if acc > best_acc:
            best_acc, best_alloc, stall = acc, list(ranks), 0
        else:
            stall += 1
        # 5) termination: converged (no improvement for `patience` iters)
        if stall >= patience:
            break

    return SRAResult(best_alloc, best_acc, history, evals=len(cache))


def _rebalance(ranks, budget, max_ranks, min_rank):
    """Adjust an allocation so it sums exactly to the budget within bounds."""
    ranks = list(ranks)
    diff = budget - sum(ranks)
    i = 0
    guard = 0
    while diff != 0 and guard < 10_000:
        j = i % len(ranks)
        if diff > 0 and ranks[j] < max_ranks[j]:
            ranks[j] += 1
            diff -= 1
        elif diff < 0 and ranks[j] > min_rank:
            ranks[j] -= 1
            diff += 1
        i += 1
        guard += 1
    return ranks


def uniform_allocation(num_layers: int, total_budget: int,
                       max_ranks: Sequence[int], min_rank: int = 1) -> list[int]:
    """The paper's SVD-baseline allocation: equal rank everywhere."""
    base, rem = divmod(total_budget, num_layers)
    ranks = [base + (1 if i < rem else 0) for i in range(num_layers)]
    return _rebalance(
        _clip_alloc(ranks, max_ranks, min_rank), total_budget, max_ranks, min_rank
    )
