"""ITERA-LLM core: quantization, iterative SVD decomposition, SRA, driver."""
from repro.core.quant import (
    QuantizedTensor,
    fake_quant,
    quant_linear_ref,
    quantize,
    dequantize,
    qmax,
)
from repro.core.itera import (
    LowRankQ,
    itera_decompose,
    svd_decompose,
    reconstruction_error,
)
from repro.core.sra import SRAResult, sra_allocate, uniform_allocation
from repro.core.compress import (
    CompressionConfig,
    CompressionReport,
    compress_params,
    eligible_linears,
    sra_eval_closure,
)

__all__ = [
    "QuantizedTensor", "fake_quant", "quant_linear_ref", "quantize",
    "dequantize", "qmax", "LowRankQ", "itera_decompose", "svd_decompose",
    "reconstruction_error", "SRAResult", "sra_allocate", "uniform_allocation",
    "CompressionConfig", "CompressionReport", "compress_params",
    "eligible_linears", "sra_eval_closure",
]
