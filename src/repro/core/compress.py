"""Whole-model post-training compression driver (paper Fig. 2 pipeline).

Walks a parameter pytree, replaces every eligible 2-D linear weight with a
compressed representation, and returns accounting used by the DSE and the
Pareto benchmarks:

  * storage bits  -> compression ratio vs FP32 (ratio 4 == plain 8-bit)
  * NOps per batch row -> the paper's "number of operations" metric

Storage accounting is RESIDENT-honest: per-layer bits are computed from
the device arrays the compressed node actually holds (`storage_bits()`),
so packed W4 counts 4 bits/weight because the bytes really are halved,
while W6 — which stays in its int8 carrier (no byte-aligned packing) —
counts a full 8, and skipped params count at their actual dtype itemsize.
Nothing is priced at a word length that is not physically resident.

Methods (paper §VIII-C):
  quant  — fixed-point WxAy quantization only                  (baseline)
  svd    — one-shot truncated SVD then quantization            (baseline)
  itera  — Algorithm 1 iterative quantized decomposition       (ours)
  itera + per-layer ranks from SRA                              (ours, best)

`compress_params` executes an `api.plan.CompressionPlan` — per-layer
method / word length / rank, mixed precision across layers. The legacy
`CompressionConfig` (one global method/wl) is kept as a thin shim that
lowers to a uniform plan, so every existing call site keeps working; the
returned `CompressionReport` records the executed plan as provenance.

The compressed pytree stores `QuantizedTensor` / `LowRankQ` nodes in place
of raw arrays; `repro.models.layers.apply_linear` dispatches on the node
type, so any model in the zoo runs compressed without code changes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.itera import LowRankQ, itera_decompose, svd_decompose
from repro.core.quant import QuantizedTensor, pack_weights, quantize

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Uniform-compression shim: one global method/wl, per-layer rank
    override. Lowered to a per-layer `CompressionPlan` by `compress_params`
    (see `to_plan`); new code should build plans directly."""

    method: str = "quant"              # none | quant | svd | itera
    weight_wl: int = 8
    act_wl: int = 8
    pack: bool = True                  # pack W4 weights two-nibbles-per-byte
    rank_fraction: float = 0.5         # uniform rank = frac · min(K, N)
    ranks: dict | None = None          # per-layer override (path -> rank), e.g. from SRA
    min_rank: int = 1
    include: str = r".*"               # regex over pytree paths
    exclude: str = r"(embed|router|norm|scale|bias|ln|pos)"
    min_dim: int = 32                  # skip tiny matrices (router heads etc.)
    power_iters: int = 24

    rank_multiple: int = 64            # shard- & MXU-aligned ranks

    def rank_for(self, path: str, shape) -> int:
        full = min(int(shape[0]), int(shape[1]))
        if self.ranks and path in self.ranks:
            r = int(self.ranks[path])
        else:
            r = int(round(self.rank_fraction * full))
        if full >= 4 * self.rank_multiple:  # align big matrices for TP/MXU
            r = max(self.rank_multiple,
                    (r // self.rank_multiple) * self.rank_multiple)
        return max(self.min_rank, min(r, full))

    def to_plan(self, params):
        from repro.api.plan import CompressionPlan

        return CompressionPlan.from_config(params, self)


@dataclasses.dataclass
class LayerReport:
    path: str
    shape: tuple
    method: str
    rank: int | None
    bits: int                  # RESIDENT bits: what the device arrays occupy
    fp32_bits: int
    nops_per_row: int
    dense_nops_per_row: int
    wl: int = 8
    packed: bool = False       # any factor stored packed-nibble in HBM


@dataclasses.dataclass
class CompressionReport:
    layers: list
    skipped_params: int        # element count of params left uncompressed
    plan: Any = None           # the executed api.plan.CompressionPlan
    skipped_bits: int = 0      # actual bits of those params (dtype itemsize)

    @property
    def total_bits(self) -> int:
        return sum(l.bits for l in self.layers) + self.skipped_bits

    @property
    def total_fp32_bits(self) -> int:
        # skipped params are untouched by compression, so they enter both
        # sides of the total at their actual size — counting them at 32
        # bits regardless of dtype skewed totals for bf16 models.
        return sum(l.fp32_bits for l in self.layers) + self.skipped_bits

    @property
    def compression_ratio(self) -> float:
        """Normalized to FP32 over the *compressed* layers only, matching the
        paper's linear-layer focus (ratio 4 == W8)."""
        comp = sum(l.bits for l in self.layers)
        return sum(l.fp32_bits for l in self.layers) / max(comp, 1)

    @property
    def nops_per_row(self) -> int:
        return sum(l.nops_per_row for l in self.layers)

    @property
    def dense_nops_per_row(self) -> int:
        return sum(l.dense_nops_per_row for l in self.layers)

    def summary(self) -> str:
        return (
            f"layers={len(self.layers)} "
            f"packed={sum(1 for l in self.layers if l.packed)} "
            f"ratio={self.compression_ratio:.2f}x "
            f"NOps={self.nops_per_row/1e6:.2f}M/row "
            f"(dense {self.dense_nops_per_row/1e6:.2f}M/row, "
            f"{100*(1-self.nops_per_row/max(self.dense_nops_per_row,1)):.1f}% saved)"
        )


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_leaves_by_path(params) -> dict:
    """{path: leaf} for every leaf in the tree (plan validation helper)."""
    return {path_str(p): leaf
            for p, leaf in jax.tree_util.tree_flatten_with_path(params)[0]}


def eligible_linears(
    params, cfg: CompressionConfig
) -> list[tuple[str, Array]]:
    """(path, leaf) for every 2-D weight the config selects."""
    inc, exc = re.compile(cfg.include), re.compile(cfg.exclude, re.I)
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        p = path_str(path)
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            continue
        if min(leaf.shape[-2:]) < cfg.min_dim:
            continue
        if not inc.search(p) or exc.search(p):
            continue
        out.append((p, leaf))
    return out


def shape_spectra(params, alpha: float = 2.0,
                  selector: CompressionConfig | None = None):
    """Impose a power-law singular-value spectrum (s_i ∝ i^-alpha) on every
    weight the selector picks, preserving each matrix's singular vectors
    and Frobenius norm.

    Proxy conditioning, not compression: the repo's random-init proxies
    have near-FLAT spectra (Marchenko–Pastur), so truncating ANY rank
    discards components as informative as those kept — low-rank error is
    maximally adversarial and nothing like the trained weights the paper
    compresses, whose spectra decay (the premise that makes rank
    truncation work at all). Benchmarks that measure rank-truncation
    quality trade-offs — e.g. the self-speculative draft's acceptance
    rate — shape the proxy first so the trade-off is measured in the
    decaying-spectrum regime the technique targets. Exact-identity tests
    must NOT depend on this (they hold either way).

    Runs on host (numpy SVD) at build time; batched leaves (L, K, N) are
    shaped per matrix. Leaves the selector excludes (embeddings, norms,
    biases) pass through untouched, shapes and dtypes are preserved.
    """
    if alpha < 0:
        raise ValueError(f"alpha must be >= 0, got {alpha}")
    sel = selector if selector is not None else CompressionConfig()
    targets = {}
    for p, w in eligible_linears(params, sel):
        wn = np.asarray(w, np.float64)
        u, s, vt = np.linalg.svd(wn, full_matrices=False)
        t = np.arange(1, s.shape[-1] + 1, dtype=np.float64) ** -alpha
        t = t * (np.linalg.norm(s, axis=-1, keepdims=True)
                 / np.linalg.norm(t))
        targets[p] = jnp.asarray(((u * t[..., None, :]) @ vt)
                                 .astype(np.asarray(w).dtype))
    return jax.tree_util.tree_map_with_path(
        lambda p, x: targets.get(path_str(p), x), params)


def _runtime_format(node, act_wl: int, pack: bool):
    """Stamp the plan's runtime knobs onto a compressed node: the
    activation word length its matmul quantizes to, and — for W4 with an
    even, non-pad-inflating last dim (`quant.packable`) — the
    packed-nibble HBM layout. Packing is exact (codes unchanged), so
    packed and carrier trees are token-identical."""
    def one(q: QuantizedTensor) -> QuantizedTensor:
        q = dataclasses.replace(q, act_wl=act_wl)
        return pack_weights(q) if pack else q

    if isinstance(node, LowRankQ):
        return LowRankQ(one(node.w1), one(node.w2))
    return one(node)


def _node_bits(node) -> tuple[int, bool]:
    """(resident storage bits, any-factor-packed) straight from the node's
    device arrays — the honest accounting, never an assumed word length."""
    if isinstance(node, LowRankQ):
        return (node.storage_bits(), node.w1.packed or node.w2.packed)
    return node.storage_bits(), node.packed


def _compress_matrix(w: Array, lp, power_iters: int, *,
                     act_wl: int = 8, pack: bool = True):
    """Compress one (..., K, N) weight per its LayerPlan -> (node,
    LayerReport). Leading stack dims (scan-stacked layers, expert stacks,
    layers x experts) are handled by vmapping once per leading dim."""
    k, n = int(w.shape[-2]), int(w.shape[-1])
    rank = min(int(lp.rank), min(k, n)) if lp.rank is not None else None
    if lp.method == "quant":
        fn = lambda m: quantize(m, lp.wl, axis=0)               # noqa: E731
    elif lp.method == "svd":
        fn = lambda m: svd_decompose(m, rank, lp.wl)            # noqa: E731
    elif lp.method == "itera":
        fn = lambda m: itera_decompose(                         # noqa: E731
            m, rank, lp.wl, power_iters=power_iters)
    else:
        raise ValueError(lp.method)
    mult = 1
    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    for d in w.shape[:-2]:
        mult *= int(d)
    node = _runtime_format(fn(w), act_wl, pack)
    bits, packed = _node_bits(node)
    return node, _report_for(lp.path, (k, n), lp.method, lp.wl, rank,
                             mult=mult, bits=bits, packed=packed)


def _report_for(path, kn, method, wl, rank, mult, bits, packed):
    k, n = kn
    fp32 = 32 * k * n * mult
    if method == "quant":
        nops, rank_out = k * n * mult, None
    else:
        nops, rank_out = rank * (k + n) * mult, rank
    return LayerReport(
        path=path, shape=(mult, k, n) if mult > 1 else (k, n),
        method=method, rank=rank_out, bits=bits, fp32_bits=fp32,
        nops_per_row=nops, dense_nops_per_row=k * n * mult, wl=wl,
        packed=packed,
    )


def compress_params(params, spec):
    """Execute a compression spec over a parameter pytree.

    spec: an `api.plan.CompressionPlan` (per-layer method/wl/rank, mixed
    precision across layers) or a legacy `CompressionConfig` (lowered to a
    uniform plan first). Returns (compressed pytree, CompressionReport);
    the report's `.plan` is the executed plan.
    """
    from repro.api.plan import CompressionPlan

    if not isinstance(spec, CompressionPlan):
        if spec.method == "none":
            leaves = jax.tree_util.tree_leaves(params)
            return params, CompressionReport(
                [], sum(int(l.size) for l in leaves),
                plan=CompressionPlan(label="none", act_wl=spec.act_wl),
                skipped_bits=sum(_leaf_bits(l) for l in leaves))
        plan = spec.to_plan(params)
    else:
        plan = spec.validate(params)

    targets = {lp.path: lp for lp in plan.active_layers()}
    reports: list[LayerReport] = []
    skipped = 0
    skipped_bits = 0

    def visit(path, leaf):
        nonlocal skipped, skipped_bits
        p = path_str(path)
        if p in targets:
            node, rep = _compress_matrix(leaf, targets[p], plan.power_iters,
                                         act_wl=plan.act_wl, pack=plan.pack)
            reports.append(rep)
            return node
        if hasattr(leaf, "size"):
            skipped += int(leaf.size)
            skipped_bits += _leaf_bits(leaf)
        return leaf

    new_params = jax.tree_util.tree_map_with_path(visit, params)
    return new_params, CompressionReport(reports, skipped, plan=plan,
                                         skipped_bits=skipped_bits)


def _leaf_bits(leaf) -> int:
    """Actual storage bits of an uncompressed leaf: size x dtype itemsize
    (a bf16 embedding is 16 bits/param, not an assumed 32)."""
    if not hasattr(leaf, "size"):
        return 0
    itemsize = getattr(getattr(leaf, "dtype", None), "itemsize", 4)
    return int(leaf.size) * int(itemsize) * 8


def sra_eval_closure(
    params,
    cfg: CompressionConfig,
    quality_fn: Callable[[Any], float],
):
    """Bridge to core.sra: returns (eval_fn(ranks)->acc, layer_paths, max_ranks).

    `quality_fn(compressed_params) -> float` runs the calibration set.
    """
    targets = eligible_linears(params, cfg)
    paths = [p for p, _ in targets]
    max_ranks = [int(min(w.shape[-2:])) for _, w in targets]

    def eval_fn(ranks):
        rmap = dict(zip(paths, [int(r) for r in ranks]))
        c = dataclasses.replace(cfg, ranks=rmap)
        cp, _ = compress_params(params, c)
        return float(quality_fn(cp))

    return eval_fn, paths, max_ranks
