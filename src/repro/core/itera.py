"""ITERA-LLM core: SVD-based *iterative* tensor decomposition (paper Alg. 1).

The classic baseline (paper §III-A) decomposes W ≈ (U_r Σ_r^½)(Σ_r^½ V_rᵀ)
= W1 W2 in one shot and quantizes afterwards. Algorithm 1 instead runs a
refinement loop: at step k it takes the best rank-1 approximation of the
*current residual*, quantizes that rank-1 pair, and subtracts the QUANTIZED
product from the residual — so every later iteration sees (and compensates)
the quantization error of all earlier ones. Outliers dominate the residual
Frobenius norm and therefore get captured first.

Quantization granularity: one scale per singular vector (the paper's
"vector-wise" scheme): W1' is (K, r) with a (1, r) scale, W2' is (r, N)
with an (r, 1) scale.

Two rank-1 engines are provided:
  * method="svd"   — exact jnp.linalg.svd of the residual each step
                     (faithful to the listing; O(r · svd(K,N)))
  * method="power" — warm-started power iteration (default; numerically
                     equivalent top singular pair at a fraction of the cost,
                     validated against "svd" in tests)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, qmax

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LowRankQ:
    """Quantized rank-r factorization W ≈ dequant(w1) @ dequant(w2).

    w1: (K, r) codes, scale (1, r)   — one scale per left singular vector
    w2: (r, N) codes, scale (r, 1)   — one scale per right singular vector

    This is a storage node, not an operator: the single matmul entry point
    is `repro.models.layers.apply_linear`, which dispatches LowRankQ nodes
    to `repro.kernels.ops.lrmm` (fused cascade kernel on TPU, reference
    math elsewhere) — y = (x @ W1') @ W2' without reconstructing W
    (paper eq. 3).
    """

    w1: QuantizedTensor
    w2: QuantizedTensor

    @property
    def rank(self) -> int:
        return self.w1.shape[1]      # logical, even when w1 is packed

    @property
    def act_wl(self) -> int:
        """Activation word length for both cascade matmuls (phase-1 input
        quantization AND the phase-boundary requant); carried on the
        factors so it rides the pytree into jitted model functions."""
        return self.w1.act_wl

    def dequant_product(self) -> Array:
        return self.w1.dequant() @ self.w2.dequant()

    def storage_bits(self) -> int:
        return self.w1.storage_bits() + self.w2.storage_bits()

    def nops(self, batch_m: int) -> int:
        """MACs for a batch of M rows: M·K·r + M·r·N (paper's NOps metric)."""
        k, r = map(int, self.w1.shape)
        _, n = map(int, self.w2.shape)
        return batch_m * r * (k + n)


jax.tree_util.register_pytree_with_keys(
    LowRankQ,
    lambda t: ((("w1", t.w1), ("w2", t.w2)), None),
    lambda aux, ch: LowRankQ(*ch),
)


def _rank1_svd(r_mat: Array, _v0: Array):
    """Exact top singular triple via full SVD (paper listing: SVD(R)_1)."""
    u, s, vt = jnp.linalg.svd(r_mat, full_matrices=False)
    return u[:, 0], s[0], vt[0, :]


def _rank1_power(r_mat: Array, v0: Array, iters: int = 24):
    """Top singular triple via power iteration on RᵀR, warm-started at v0."""

    def body(_, v):
        u = r_mat @ v
        u = u / (jnp.linalg.norm(u) + 1e-30)
        v = r_mat.T @ u
        return v / (jnp.linalg.norm(v) + 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v0)
    u = r_mat @ v
    s = jnp.linalg.norm(u)
    u = u / (s + 1e-30)
    return u, s, v


def _quant_vec(x: Array, wl: int):
    """Single-scale symmetric quantization of one singular vector."""
    m = qmax(wl)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax / m, 1.0)
    q = jnp.clip(jnp.round(x / scale), -m, m).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


@partial(jax.jit, static_argnames=("rank", "wl", "method", "power_iters"))
def itera_decompose(
    w: Array,
    rank: int,
    wl: int,
    *,
    method: str = "power",
    power_iters: int = 24,
    seed: int = 0,
) -> LowRankQ:
    """Paper Algorithm 1: SVD-based iterative tensor decomposition.

    Args:
      w: (K, N) fp weight matrix.
      rank: target decomposition rank r.
      wl: weight word length (4 / 6 / 8).
      method: "power" (default) or "svd" rank-1 engine.
    Returns LowRankQ with int8-carried codes and fp32 per-vector scales.
    """
    w = w.astype(jnp.float32)
    k_dim, n_dim = w.shape
    rank1 = {"svd": _rank1_svd, "power": partial(_rank1_power, iters=power_iters)}[
        method
    ]

    def step(carry, key):
        resid = carry
        v0 = jax.random.normal(key, (n_dim,), jnp.float32)
        u, s, v = rank1(resid, v0 / jnp.linalg.norm(v0))
        sq = jnp.sqrt(jnp.maximum(s, 0.0))
        w1q, s1 = _quant_vec(u * sq, wl)           # (K,)  codes + scalar scale
        w2q, s2 = _quant_vec(v * sq, wl)           # (N,)
        # Residual update uses the QUANTIZED product — the error-compensation
        # mechanism at the heart of the paper.
        resid = resid - (w1q.astype(jnp.float32) * s1)[:, None] * (
            w2q.astype(jnp.float32) * s2
        )[None, :]
        return resid, (w1q, s1, w2q, s2)

    # fold_in (not split): key k is independent of the requested rank, so
    # a rank-r decomposition is exactly the first r steps of a full-rank
    # one (prefix consistency — used by truncate()).
    keys = jax.vmap(lambda k: jax.random.fold_in(jax.random.PRNGKey(seed),
                                                 k))(jnp.arange(rank))
    _, (w1_cols, s1s, w2_rows, s2s) = jax.lax.scan(step, w, keys)

    w1 = QuantizedTensor(w1_cols.T, s1s[None, :], wl, axis=0)      # (K, r)
    w2 = QuantizedTensor(w2_rows, s2s[:, None], wl, axis=1)        # (r, N)
    return LowRankQ(w1, w2)


@partial(jax.jit, static_argnames=("rank", "wl"))
def svd_decompose(w: Array, rank: int, wl: int) -> LowRankQ:
    """Baseline (paper §VIII-B): one-shot truncated SVD, then vector-wise
    quantization of the produced factors. Same storage format as ITERA so
    comparisons are apples-to-apples."""
    w = w.astype(jnp.float32)
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    sq = jnp.sqrt(jnp.maximum(s[:rank], 0.0))
    w1f = u[:, :rank] * sq[None, :]                # (K, r)
    w2f = vt[:rank, :] * sq[:, None]               # (r, N)

    m = qmax(wl)
    s1 = jnp.maximum(jnp.max(jnp.abs(w1f), axis=0, keepdims=True), 1e-30) / m
    s2 = jnp.maximum(jnp.max(jnp.abs(w2f), axis=1, keepdims=True), 1e-30) / m
    w1q = jnp.clip(jnp.round(w1f / s1), -m, m).astype(jnp.int8)
    w2q = jnp.clip(jnp.round(w2f / s2), -m, m).astype(jnp.int8)
    return LowRankQ(
        QuantizedTensor(w1q, s1.astype(jnp.float32), wl, axis=0),
        QuantizedTensor(w2q, s2.astype(jnp.float32), wl, axis=1),
    )


def truncate(lr: LowRankQ, rank: int) -> LowRankQ:
    """First-r-components decomposition. For ITERA this equals running
    Algorithm 1 with target rank r (greedy prefix consistency); for the
    SVD baseline it equals truncated SVD + vector-wise quantization."""
    if lr.w1.packed or lr.w2.packed:
        raise ValueError("truncate() operates on carrier-layout factors; "
                         "unpack_weights the node first (packing happens "
                         "after rank selection, in compress_params)")
    # dataclasses.replace keeps the non-layout aux (act_wl) intact —
    # truncation must not silently reset an A4/A6 plan back to A8.
    # Ellipsis indexing makes this correct for scan-stacked leaves too:
    # w1 is (..., K, r) and w2 is (..., r, N) whether or not a leading
    # layer axis is present.
    return LowRankQ(
        dataclasses.replace(lr.w1, values=lr.w1.values[..., :rank],
                            scale=lr.w1.scale[..., :rank]),
        dataclasses.replace(lr.w2, values=lr.w2.values[..., :rank, :],
                            scale=lr.w2.scale[..., :rank, :]),
    )


def reconstruction_error(w: Array, lr: LowRankQ) -> Array:
    """Relative Frobenius reconstruction error ‖W − W1'W2'‖_F / ‖W‖_F."""
    return jnp.linalg.norm(w - lr.dequant_product()) / (
        jnp.linalg.norm(w) + 1e-30
    )
