"""Self-speculative decoding tests (runtime/speculation.py + engine wiring).

The load-bearing claims, per docs/serving.md and runtime/speculation.py:
  * greedy speculative serve is token-identical to non-speculative serve
    — the drafts only ever decide HOW MANY full-model tokens a dispatch
    emits, never WHICH tokens (checked for fp32, bf16 and int8-KV
    engines, on mixed prefill/decode batches with per-request
    max_tokens);
  * a draft that never matches costs throughput but not correctness
    (forced-full-rejection: accepted == 0, outputs unchanged);
  * the draft tree is free: dense leaves are shared by reference and
    every cascade is the rank-truncated prefix of the served one;
  * scheduling clamps draft spans inside the request's admission-time
    reservation, and provisional KV blocks roll back without leaking;
  * the TPU cost model prices the trade coherently (breakeven accept
    rate monotone in draft depth);
  * serve() is greedy-only and says so (temperature > 0 raises).
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CompressionPlan, DraftSpec, InferenceEngine,
                       Request, SamplingParams)
from repro.configs import get_config
from repro.core import compress
from repro.core.compress import CompressionConfig
from repro.core.itera import LowRankQ
from repro.core.quant import QuantizedTensor, unpack_weights
from repro.hw import tpu_model
from repro.models import transformer as tfm
from repro.runtime import speculation
from repro.runtime.kvblocks import BlockPool, blocks_for_positions
from repro.runtime.scheduler import Scheduler, Sequence
from repro.runtime.scheduler import Request as SchedRequest

PLAN = CompressionConfig(method="itera", weight_wl=8, rank_fraction=0.75)
SPEC = DraftSpec(k=3, rank_fraction=0.7)


@pytest.fixture(scope="module")
def engine():
    """Low-rank smoke engine carrying its truncated-cascade draft.
    chunk_tokens=8 forces real chunked prefill so speculative rounds mix
    with mid-prompt rows."""
    cfg = get_config("opus-mt", smoke=True)
    return InferenceEngine.build(cfg, PLAN, max_batch=3, block_size=4,
                                 chunk_tokens=8, speculate=SPEC)


@pytest.fixture(scope="module")
def dense_engine():
    cfg = get_config("opus-mt", smoke=True)
    return InferenceEngine.build(cfg, None, max_batch=3, block_size=4,
                                 chunk_tokens=8)


def _requests(engine, seed=0):
    """Mixed workload: prompts longer than the chunk budget (chunked
    prefill) next to short ones, with per-request max_tokens."""
    rng = np.random.default_rng(seed)
    lens = [5, 11, 3, 9, 14, 6]
    gens = [6, 3, 8, 5, 2, 7]
    return [Request(tokens=rng.integers(0, engine.cfg.vocab_size, size=n),
                    max_tokens=g) for n, g in zip(lens, gens)]


def _assert_identical(res_off, res_on):
    for i, (a, b) in enumerate(zip(res_off.outputs, res_on.outputs)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"request {i}: speculative != plain")


# ----------------------------------------------------------- identity --
def test_speculative_serve_token_identical(engine):
    reqs = _requests(engine)
    off = engine.serve(reqs, speculate=False)
    on = engine.serve(reqs, speculate=True)
    _assert_identical(off, on)
    assert off.spec_k == 0 and off.drafted == 0
    assert on.spec_k == SPEC.k
    assert on.drafted > 0 and on.spec_rounds > 0
    assert 0 <= on.accepted <= on.drafted
    assert on.accept_rate == on.accepted / on.drafted
    # speculation emits more tokens per dispatch whenever anything is
    # accepted; it must never take MORE steps than plain decode
    assert on.steps <= off.steps


@pytest.mark.parametrize("variant", ["bf16", "int8kv"])
def test_speculative_identity_dtype_variants(variant):
    """Token identity is a property of the greedy accept rule, not of
    the fp32 reference numerics: it must survive bf16 weights and int8
    KV-cache quantization."""
    cfg = get_config("opus-mt", smoke=True)
    cfg = (dataclasses.replace(cfg, dtype="bfloat16") if variant == "bf16"
           else dataclasses.replace(cfg, kv_cache_bits=8))
    eng = InferenceEngine.build(cfg, PLAN, max_batch=3, block_size=4,
                                chunk_tokens=8, speculate=SPEC)
    reqs = _requests(eng, seed=1)
    _assert_identical(eng.serve(reqs, speculate=False),
                      eng.serve(reqs, speculate=True))


def test_forced_full_rejection(dense_engine):
    """A pathological draft (negated lm head: its argmax is the full
    model's argmin at the identical hidden state) must reject every
    draft token yet leave the outputs untouched."""
    eng = dense_engine
    bad = dict(eng.params)
    bad["lm_head"] = -eng.params["lm_head"]
    ctl = speculation.SpeculationController(DraftSpec(k=2), eng.cfg,
                                            eng.params, draft_params=bad)
    prev = eng.speculation
    eng.speculation = ctl
    try:
        reqs = _requests(eng, seed=2)
        off = eng.serve(reqs, speculate=False)
        on = eng.serve(reqs, speculate=True)
    finally:
        eng.speculation = prev
    _assert_identical(off, on)
    assert on.drafted > 0
    assert on.accepted == 0, "argmin drafts cannot match argmax verify"


# -------------------------------------------------------- draft tree --
def test_draft_rank_granularity():
    assert speculation.draft_rank(512, 0.5) == 256
    # large ranks floor to the kernels' 64-lane granularity
    assert speculation.draft_rank(512, 0.9) == 448
    assert speculation.draft_rank(256, 0.3) == 64
    # small ranks round freely (the kernels accept any rank there)
    assert speculation.draft_rank(100, 0.5) == 50
    assert speculation.draft_rank(8, 0.01) == 1
    assert speculation.draft_rank(48, 1.0) == 48


def _lowrank_leaves(tree):
    return [l for l in jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, LowRankQ))
        if isinstance(l, LowRankQ)]


def test_derive_draft_truncates_and_shares(engine):
    draft = engine.speculation.draft_params
    served = _lowrank_leaves(engine.params)
    drafted = _lowrank_leaves(draft)
    assert served and len(served) == len(drafted)
    for s, d in zip(served, drafted):
        r = int(unpack_weights(s.w2).values.shape[-2])
        rd = int(unpack_weights(d.w2).values.shape[-2])
        assert rd == speculation.draft_rank(r, SPEC.rank_fraction) < r
        # prefix consistency: the draft cascade IS the first rd
        # components of the served one, not a re-decomposition
        np.testing.assert_array_equal(
            np.asarray(unpack_weights(d.w2).values),
            np.asarray(unpack_weights(s.w2).values)[..., :rd, :])
    # dense leaves (embeddings, norms, lm head) are shared by reference:
    # the draft model costs no extra HBM
    flat_s = jax.tree_util.tree_leaves(engine.params)
    flat_d = jax.tree_util.tree_leaves(draft)
    shared = sum(a is b for a, b in zip(flat_s, flat_d))
    assert shared > 0
    assert not speculation.is_exact_draft(engine.params, draft)


def test_exact_draft_detection(engine):
    exact = speculation.derive_draft_params(
        engine.params, DraftSpec(k=2, rank_fraction=1.0))
    assert speculation.is_exact_draft(engine.params, exact)
    lowered = speculation.derive_draft_params(
        engine.params, DraftSpec(k=2, rank_fraction=1.0, act_wl=6))
    assert not speculation.is_exact_draft(engine.params, lowered)
    qs = [l for l in jax.tree_util.tree_leaves(
        lowered, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)]
    assert qs and all(q.act_wl == 6 for q in qs)


# -------------------------------------------------------- spec / plan --
def test_draftspec_validation():
    with pytest.raises(ValueError, match="k must be >= 1"):
        DraftSpec(k=0)
    with pytest.raises(ValueError, match="rank_fraction"):
        DraftSpec(rank_fraction=0.0)
    with pytest.raises(ValueError, match="rank_fraction"):
        DraftSpec(rank_fraction=1.5)
    with pytest.raises(ValueError, match="act_wl"):
        DraftSpec(act_wl=1)
    spec = DraftSpec(k=5, rank_fraction=0.25, act_wl=6)
    assert DraftSpec.from_dict(spec.to_dict()) == spec


def test_plan_carries_draft_spec_through_json():
    spec = DraftSpec(k=3, rank_fraction=0.6)
    plan = CompressionPlan(layers=(), draft=spec, label="specced")
    back = CompressionPlan.loads(plan.dumps())
    assert back.draft == spec
    assert "draft k=3" in plan.summary()
    # absent draft stays absent (no silent default materialization)
    bare = CompressionPlan.loads(CompressionPlan(layers=()).dumps())
    assert bare.draft is None


def test_build_speculate_resolution(engine):
    """build(speculate=...) resolution: False beats the plan's draft,
    ints become DraftSpec(k), plan.draft is the default."""
    cfg = get_config("opus-mt", smoke=True)
    eng = InferenceEngine.build(cfg, None, speculate=2)
    assert eng.speculation is not None and eng.speculation.spec.k == 2
    off = InferenceEngine.build(cfg, None, speculate=False)
    assert off.speculation is None


# ------------------------------------------------------ serve guards --
def test_sampled_rows_never_draft(engine):
    """Speculation is a greedy-row optimization: an all-sampled batch on
    a draft-carrying engine proposes zero draft tokens (verify-logits
    sampling only), while the same prompts served greedy do draft."""
    sp = SamplingParams(max_tokens=4, temperature=0.7, top_k=8, seed=3)
    prompts = [np.arange(1, 5), np.arange(2, 9)]
    sampled = engine.serve(prompts, sp)
    assert sampled.drafted == 0 and sampled.spec_rounds == 0
    greedy = engine.serve(prompts, SamplingParams(max_tokens=4))
    assert greedy.drafted > 0


def test_speculate_true_requires_draft(dense_engine):
    with pytest.raises(ValueError, match="no draft model"):
        dense_engine.serve([np.arange(4)], SamplingParams(max_tokens=2),
                           speculate=True)


# ------------------------------------------------ scheduler clamping --
def _live_seq(pool, prompt_len, max_tokens, n_emitted):
    """A decoding row holding exactly the blocks its committed context
    needs (NOT the admission worst case) — the under-provisioned state
    where reserve_speculation must actually allocate."""
    req = SchedRequest(tokens=np.ones(prompt_len, np.int32),
                       max_tokens=max_tokens, rid=0)
    committed = prompt_len + max(n_emitted - 1, 0)
    seq = Sequence(req=req, row=0,
                   block_ids=pool.alloc(
                       blocks_for_positions(committed, pool.block_size)))
    seq.prefilled = prompt_len
    seq.n_emitted = n_emitted
    return seq


def test_reserve_clamps_to_remaining_tokens():
    pool = BlockPool(16, 4)
    sched = Scheduler(pool, 1)
    # one token left: the (k+1)-wide verify span would cross the final
    # token, so no draft at all
    seq = _live_seq(pool, 6, 4, 3)
    assert sched.reserve_speculation(seq, 4) == 0
    assert seq.draft_blocks == []
    # two left -> k clamps to 1
    seq2 = Sequence(req=seq.req, row=0, block_ids=list(seq.block_ids),
                    prefilled=6, n_emitted=2)
    assert sched.reserve_speculation(seq2, 4) == 1


def test_reserve_and_commit_roll_back_blocks():
    pool = BlockPool(16, 4)
    sched = Scheduler(pool, 1)
    seq = _live_seq(pool, 7, 8, 1)        # committed ctx 7 -> 2 blocks
    base = list(seq.block_ids)
    avail0 = pool.available
    k = sched.reserve_speculation(seq, 4)
    assert k == 4
    assert seq.draft_blocks, "span past the boundary must grow the table"
    assert 0 not in seq.draft_blocks
    # full rejection: one emitted token, provisional blocks all return
    seq.n_emitted += 1
    released = sched.commit_speculation(seq)
    assert released and pool.available == avail0
    assert seq.block_ids == base and seq.draft_blocks == []
    # idempotent: a second commit is a no-op
    assert sched.commit_speculation(seq) == []


def test_commit_keeps_blocks_the_accepted_prefix_reached():
    pool = BlockPool(16, 2)
    sched = Scheduler(pool, 1)
    seq = _live_seq(pool, 4, 8, 1)        # committed ctx 4 -> 2 blocks
    k = sched.reserve_speculation(seq, 3)
    assert k == 3 and len(seq.draft_blocks) >= 1
    held = len(seq.block_ids)
    seq.n_emitted += 3                     # 2 accepted + 1 full-model
    sched.commit_speculation(seq)
    # committed ctx is now 4 + 3 = 7 -> ceil(7/2) = 4 blocks stay
    assert len(seq.block_ids) == 4 <= held
    assert seq.draft_blocks == []


def test_reserve_shrinks_to_pool_capacity():
    pool = BlockPool(4, 2)                 # 3 usable blocks
    sched = Scheduler(pool, 1)
    seq = _live_seq(pool, 4, 10, 1)        # committed 4 -> 2 blocks held
    # span end for k=4 needs blocks the pool can't back; k shrinks
    k = sched.reserve_speculation(seq, 4)
    assert 0 < k < 4
    assert len(seq.block_ids) <= 3


# ------------------------------------------------------- cost model --
def test_expected_tokens_per_round():
    f = tpu_model.expected_tokens_per_round
    assert f(3, 0.0) == pytest.approx(1.0)
    assert f(3, 1.0) == pytest.approx(4.0)
    assert f(2, 0.5) == pytest.approx(1.75)
    assert f(0, 0.9) == pytest.approx(1.0)   # k=0: the plain step
    with pytest.raises(ValueError):
        f(-1, 0.5)
    with pytest.raises(ValueError):
        f(3, 1.5)


def test_breakeven_monotone_in_k():
    """Deeper drafts need a better draft model: the accept rate at which
    speculation breaks even must be non-decreasing in k (asserted for
    the DSE's pricing, see hw/tpu_model.speculation_point)."""
    for dc in (0.1, 0.3, 0.6):
        bs = [tpu_model.breakeven_accept_rate(k, draft_cost_ratio=dc)
              for k in range(1, 9)]
        assert all(b2 >= b1 - 1e-12 for b1, b2 in zip(bs, bs[1:])), \
            f"breakeven not monotone at draft_cost_ratio={dc}: {bs}"
        assert all(0.0 <= b <= 1.0 for b in bs)
    # k=1 closed form: a >= dc (E = 1 + a vs cost 1 + dc)
    assert tpu_model.breakeven_accept_rate(
        1, draft_cost_ratio=0.3) == pytest.approx(0.3, abs=1e-9)


def test_speculation_point_prices_the_trade():
    pt = tpu_model.speculation_point(4, 0.8, full_step_s=1.0,
                                     draft_step_s=0.3)
    assert pt.expected_tokens == pytest.approx(
        tpu_model.expected_tokens_per_round(4, 0.8))
    assert pt.round_s == pytest.approx(4 * 0.3 + 1.0)
    assert pt.speedup > 1.0
    assert pt.tokens_per_s == pytest.approx(
        pt.baseline_tokens_per_s * pt.speedup)
    # below breakeven the same geometry must lose
    lo = tpu_model.speculation_point(4, pt.breakeven_accept_rate * 0.5,
                                     full_step_s=1.0, draft_step_s=0.3)
    assert lo.speedup < 1.0


# ------------------------------------------------------- bench row --
def test_bench_serving_records_speculation():
    """The committed BENCH_serving.json must carry a speculation row
    showing the draft actually pays on the decode-heavy workload."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serving.json")
    rec = json.load(open(path))
    spec = rec.get("speculation")
    assert spec is not None, "BENCH_serving.json lacks a speculation row"
    assert spec["k"] >= 1
    assert spec["drafted"] > 0
    assert spec["accept_rate"] > 0.0
    assert spec["tokens_per_second"] >= spec["baseline_tokens_per_second"]


# -------------------------------------------------- proxy conditioning --
def test_shape_spectra_power_law():
    """shape_spectra turns a flat random spectrum into the decaying one
    trained weights carry (the regime where rank truncation — and hence
    the draft's acceptance rate — is meaningful), preserving singular
    vectors' span, Frobenius norm, shape, dtype, and excluded leaves."""
    rng = np.random.default_rng(0)
    params = {
        "layer": {"proj": jnp.asarray(
            rng.standard_normal((48, 64)), jnp.float32)},
        "embed": {"table": jnp.asarray(
            rng.standard_normal((64, 40)), jnp.float32)},
    }
    shaped = compress.shape_spectra(params, alpha=2.0)
    w = np.asarray(shaped["layer"]["proj"])
    assert w.shape == (48, 64) and w.dtype == np.float32
    s = np.linalg.svd(w, compute_uv=False)
    ratio = s[:-1] / s[1:]
    expect = ((np.arange(2, len(s) + 1) / np.arange(1, len(s))) ** 2.0)
    np.testing.assert_allclose(ratio, expect, rtol=1e-3)
    assert np.linalg.norm(w) == pytest.approx(
        float(np.linalg.norm(np.asarray(params["layer"]["proj"]))),
        rel=1e-5)
    # excluded leaves (embeddings et al.) pass through untouched
    assert shaped["embed"]["table"] is params["embed"]["table"]
    with pytest.raises(ValueError, match="alpha"):
        compress.shape_spectra(params, alpha=-1.0)


def test_shaped_proxy_drafts_accept():
    """End-to-end rationale check: on a spectrum-shaped proxy the
    truncated-rank draft agrees with the full model often enough to be a
    useful draft (flat random-init spectra make acceptance collapse —
    the artifact shape_spectra exists to remove)."""
    cfg = get_config("opus-mt", smoke=True)
    params = compress.shape_spectra(
        tfm.init_params(jax.random.PRNGKey(0), cfg), alpha=2.0)
    eng = InferenceEngine.build(
        cfg, CompressionConfig(method="svd", weight_wl=8,
                               rank_fraction=0.75),
        params=params, max_batch=2, block_size=8, chunk_tokens=16,
        speculate=DraftSpec(k=3, rank_fraction=0.84))
    reqs = [Request(tokens=np.arange(1, 9, dtype=np.int32) * 3 % 512,
                    max_tokens=24),
            Request(tokens=np.arange(1, 6, dtype=np.int32) * 7 % 512,
                    max_tokens=24)]
    off = eng.serve(reqs, speculate=False)
    on = eng.serve(reqs, speculate=True)
    _assert_identical(off, on)
    assert on.drafted > 0
    assert on.accepted / on.drafted > 0.5, (
        f"shaped-spectrum draft acceptance collapsed: "
        f"{on.accepted}/{on.drafted}")
