"""Tests for Sensitivity-based Rank Allocation (paper §IV)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, strategies as st

from repro.core.sra import sra_allocate, uniform_allocation

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@given(st.integers(2, 8), st.integers(10, 60), st.integers(0, 100))
def test_budget_conserved(layers, budget, seed):
    rng = np.random.default_rng(seed)
    opt = rng.integers(1, 32, size=layers)

    def ev(r):
        return -float(sum((a - b) ** 2 for a, b in zip(r, opt)))

    max_ranks = [64] * layers
    budget = min(budget, sum(max_ranks))
    res = sra_allocate(ev, layers, budget, max_ranks, max_iters=10)
    assert sum(res.ranks) == budget
    assert all(1 <= r <= 64 for r in res.ranks)
    for alloc, _ in res.history:
        assert sum(alloc) == budget


def test_beats_uniform_on_heterogeneous():
    """Layers with very different sensitivity -> SRA must beat uniform."""
    weights = np.array([10.0, 1.0, 0.1, 5.0])
    opt = np.array([40, 8, 2, 30])

    def ev(r):
        return -float(np.sum(weights * (np.array(r) - opt) ** 2))

    budget = int(opt.sum())
    uni = uniform_allocation(4, budget, [64] * 4)
    res = sra_allocate(ev, 4, budget, [64] * 4, delta0=8, max_iters=60)
    assert res.accuracy > ev(uni)


def test_respects_max_ranks():
    def ev(r):
        return float(sum(r))  # monotone: wants all rank everywhere

    res = sra_allocate(ev, 3, 20, [8, 8, 8], max_iters=10)
    assert sum(res.ranks) == 20
    assert all(r <= 8 for r in res.ranks)


def test_budget_exceeds_capacity_raises():
    with pytest.raises(ValueError):
        sra_allocate(lambda r: 0.0, 2, 100, [8, 8])


def test_delta_decay_converges():
    opt = [30, 10]

    def ev(r):
        return -float((r[0] - opt[0]) ** 2 + (r[1] - opt[1]) ** 2)

    res = sra_allocate(ev, 2, 40, [64, 64], delta0=16, alpha=0.3,
                       max_iters=50)
    assert abs(res.ranks[0] - 30) <= 2 and abs(res.ranks[1] - 10) <= 2


def test_memoization_bounds_evals():
    calls = []

    def ev(r):
        calls.append(tuple(r))
        return 0.0

    res = sra_allocate(ev, 4, 16, [16] * 4, max_iters=8)
    assert res.evals == len(set(calls))
