"""iteralint test suite: per-rule fixtures, golden CLI output, baseline
gating, suppression syntax, and the repo-tree gate itself.

The fixtures under tests/fixtures/lint/ are parse-only — they are never
imported, so they may reference jax APIs freely and deliberately
violate every rule.
"""
import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "lint"
sys.path.insert(0, str(REPO))

from tools.iteralint import baseline as baseline_mod          # noqa: E402
from tools.iteralint.analyzers import ALL, BY_NAME            # noqa: E402
from tools.iteralint.framework import (Project,               # noqa: E402
                                       run_analyzers)

RULES = [a.name for a in ALL]
FIXTURE_STEM = {
    "trace-safety": "trace_safety",
    "recompile-hazard": "recompile",
    "pallas-contract": "pallas",
    "pytree-aux": "pytree_aux",
    "tp-boundary": "tp_boundary",
    "host-purity": "host_purity",
    "serve-rng": "serve_rng",
}


def lint_paths(paths, rules=None):
    project = Project(REPO, [pathlib.Path(p) for p in paths],
                      use_default_excludes=False)
    analyzers = [BY_NAME[r] for r in rules] if rules else ALL
    return run_analyzers(project, analyzers)


def run_cli(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.iteralint", *args],
        cwd=cwd, capture_output=True, text=True)


# ---------------------------------------------------------------------------
# per-rule fixtures

@pytest.mark.parametrize("rule", RULES)
def test_bad_fixture_fires(rule):
    bad = FIXTURES / f"{FIXTURE_STEM[rule]}_bad.py"
    findings = lint_paths([bad])
    hits = [f for f in findings if f.rule == rule]
    assert hits, f"{bad.name} produced no {rule} findings"
    for f in hits:
        assert f.path.endswith(f"{FIXTURE_STEM[rule]}_bad.py")
        assert f.line > 0


@pytest.mark.parametrize("rule", RULES)
def test_good_fixture_is_clean(rule):
    good = FIXTURES / f"{FIXTURE_STEM[rule]}_good.py"
    findings = lint_paths([good])
    hits = [f for f in findings if f.rule == rule]
    assert not hits, \
        f"{good.name} false positives: {[f.render() for f in hits]}"


def test_good_fixtures_clean_under_all_rules():
    goods = sorted(FIXTURES.glob("*_good.py"))
    assert len(goods) == len(RULES)
    findings = lint_paths(goods)
    assert not findings, [f.render() for f in findings]


# ---------------------------------------------------------------------------
# rule-specific behaviors worth pinning beyond fire/no-fire

def test_trace_safety_findings_name_the_construct():
    findings = lint_paths([FIXTURES / "trace_safety_bad.py"],
                          rules=["trace-safety"])
    blob = " ".join(f.message for f in findings)
    for needle in ("`if`", "`while`", "`assert`", "len()", ".item()",
                   "numpy call"):
        assert needle in blob, f"missing {needle!r} finding"


def test_pallas_contract_covers_each_check():
    findings = lint_paths([FIXTURES / "pallas_bad.py"],
                          rules=["pallas-contract"])
    blob = " ".join(f.message for f in findings)
    for needle in ("index map takes", "returns 3 coordinates",
                   "never asserts `m % bm == 0`", "bfloat16",
                   "packed flag `w_packed`", "2 in_specs"):
        assert needle in blob, f"missing {needle!r} finding"


def test_tp_boundary_counts_and_reachability():
    findings = lint_paths([FIXTURES / "tp_boundary_bad.py"],
                          rules=["tp-boundary"])
    msgs = [f.message for f in findings]
    assert any("`wo` boundary" in m for m in msgs)
    assert any("`down` boundary" in m for m in msgs)
    assert any("2 reduce_tp=True call sites" in m for m in msgs)
    assert any("raw collective" in m for m in msgs)
    # the suppressed psum inside apply_linear stays suppressed
    assert not any(f.line == 8 for f in findings)


def test_serve_rng_names_each_pattern():
    findings = lint_paths([FIXTURES / "serve_rng_bad.py"],
                          rules=["serve-rng"])
    blob = " ".join(f.message for f in findings)
    for needle in ("np.random.uniform", "stdlib `random.random`",
                   "per-step `jax.random.split`", "np.random.randint"):
        assert needle in blob, f"missing {needle!r} finding"
    # keys derived inside the jitted step are the sanctioned pattern
    good = lint_paths([FIXTURES / "serve_rng_good.py"],
                      rules=["serve-rng"])
    assert not good, [f.render() for f in good]


def test_host_purity_flags_lazy_imports_in_pure_modules():
    findings = lint_paths([FIXTURES / "host_purity_bad.py"],
                          rules=["host-purity"])
    assert any("imports `jax.numpy` — this path must stay host-pure"
               in f.message for f in findings)


def test_suppression_comment_silences_rule(tmp_path):
    src = FIXTURES / "pytree_aux_bad.py"
    patched = src.read_text().replace(
        "    lambda q: ((",
        "    # iteralint: disable=pytree-aux\n    lambda q: ((")
    f = tmp_path / "pytree_aux_bad.py"
    f.write_text(patched)
    findings = lint_paths([f], rules=["pytree-aux"])
    assert not findings, [x.render() for x in findings]


# ---------------------------------------------------------------------------
# the repo tree itself must be clean (the CI gate, in-process)

def test_repo_tree_has_no_new_findings():
    project = Project(REPO, [REPO / "src", REPO / "tests"])
    findings = run_analyzers(project, ALL)
    base_keys, base_errors = baseline_mod.load()
    assert not base_errors, base_errors
    new = [f for f in findings if f.key not in base_keys]
    assert not new, "new lint findings:\n" + "\n".join(
        f.render() for f in new)


def test_scheduler_import_path_is_jax_free():
    code = ("import sys; "
            "import repro.runtime.scheduler, repro.runtime.elastic, "
            "repro.runtime.kvblocks; "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    r = subprocess.run([sys.executable, "-c", code], cwd=REPO,
                       env={"PYTHONPATH": str(REPO / "src"),
                            "PATH": "/usr/bin:/bin"},
                       capture_output=True, text=True)
    assert r.returncode == 0, \
        f"scheduler import pulled in jax\n{r.stderr}"


# ---------------------------------------------------------------------------
# CLI: golden output, exit codes, baseline modes

def test_cli_golden_json_on_fixtures():
    r = run_cli("tests/fixtures/lint", "--no-default-excludes", "--json")
    assert r.returncode == 1, r.stdout + r.stderr
    got = json.loads(r.stdout)
    golden = json.loads((FIXTURES / "expected.json").read_text())
    assert got["findings"] == golden["findings"], (
        "fixture findings drifted from tests/fixtures/lint/expected.json"
        " — regenerate with: python -m tools.iteralint tests/fixtures/lint"
        " --no-default-excludes --json > tests/fixtures/lint/expected.json")
    assert got["summary"]["new"] == golden["summary"]["new"]


def test_cli_clean_tree_exits_zero():
    r = run_cli("src", "tests", "--fail-on-new")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 new" in r.stdout


def test_cli_planted_violation_fails(tmp_path):
    plant = tmp_path / "scratch.py"
    plant.write_text(
        "import jax\nimport jax.numpy as jnp\n\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    s = jnp.sum(x)\n"
        "    if s > 0:\n"
        "        s = s + 1\n"
        "    return s\n")
    r = run_cli(str(plant), "--fail-on-new")
    assert r.returncode == 1
    assert "[trace-safety]" in r.stdout
    assert "scratch.py:7" in r.stdout


def test_cli_baseline_tolerates_known_findings(tmp_path):
    plant = tmp_path / "scratch.py"
    plant.write_text(
        "import jax\nimport jax.numpy as jnp\n"
        "f = jax.jit(lambda x, n: jnp.zeros((n,)) + x)\n")
    r = run_cli(str(plant), "--fail-on-new")
    assert r.returncode == 1
    # baseline it (with a justification), and the gate opens
    base = tmp_path / "baseline.json"
    r = run_cli(str(plant), "--update-baseline", "--baseline", str(base))
    assert r.returncode == 0
    data = json.loads(base.read_text())
    for e in data["entries"]:
        e["justification"] = "demo: accepted retrace"
    base.write_text(json.dumps(data))
    r = run_cli(str(plant), "--fail-on-new", "--baseline", str(base))
    assert r.returncode == 0, r.stdout + r.stderr
    # but an entry without justification is itself an error
    for e in data["entries"]:
        e["justification"] = ""
    base.write_text(json.dumps(data))
    r = run_cli(str(plant), "--fail-on-new", "--baseline", str(base))
    assert r.returncode == 1
    assert "no justification" in r.stderr


def test_cli_list_rules():
    r = run_cli("--list-rules")
    assert r.returncode == 0
    for rule in RULES:
        assert rule in r.stdout
