"""Minimal stand-in for `hypothesis` when it is not installed.

Implements only the surface this suite uses — `given`, `settings`, and the
`strategies` functions integers / floats / lists / sampled_from / composite —
with seeded pseudo-random example generation. Property tests then still run
(with less adversarial inputs than real hypothesis shrinking would find)
instead of failing at collection. Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from hypothesis_fallback import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import random
import types


class settings:  # noqa: N801 — mirrors hypothesis' class name
    _profiles = {"default": {"max_examples": 20}}
    _current = "default"

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        cls._current = name

    @classmethod
    def _max_examples(cls):
        return int(cls._profiles.get(cls._current, {}).get("max_examples", 20))


class _Strategy:
    def __init__(self, gen):
        self._gen = gen

    def example(self, rng: random.Random):
        return self._gen(rng)


def _integers(lo, hi):
    return _Strategy(lambda rng: rng.randint(lo, hi))


def _floats(lo, hi, **_kwargs):
    return _Strategy(lambda rng: rng.uniform(lo, hi))


def _sampled_from(seq):
    items = list(seq)
    return _Strategy(lambda rng: items[rng.randrange(len(items))])


def _lists(elem, min_size=0, max_size=None):
    hi = min_size + 10 if max_size is None else max_size

    def gen(rng):
        return [elem.example(rng) for _ in range(rng.randint(min_size, hi))]

    return _Strategy(gen)


def _composite(fn):
    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def gen(rng):
            return fn(lambda s: s.example(rng), *args, **kwargs)

        return _Strategy(gen)

    return builder


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, sampled_from=_sampled_from,
    lists=_lists, composite=_composite,
)


def given(*strats):
    def deco(fn):
        # No functools.wraps here: pytest must see a zero-arg signature,
        # not the strategy-filled parameters (it would demand fixtures).
        def wrapper(*args, **kwargs):
            for i in range(settings._max_examples()):
                rng = random.Random(0xC0FFEE + 7919 * i)
                fn(*args, *[s.example(rng) for s in strats], **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
