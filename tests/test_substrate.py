"""Substrate tests: optimizer, checkpointing, data pipeline, gradient
compression, fault handling."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data import pipeline
from repro.optim import adamw
from repro.runtime import compression
from repro.runtime.fault import ResilientLoop


# ----------------------------------------------------------------- adamw --
@pytest.mark.parametrize("bits", [32, 8])
def test_adamw_converges_quadratic(bits):
    cfg = adamw.AdamWConfig(lr=0.05, warmup_steps=5, total_steps=300,
                            weight_decay=0.0, state_bits=bits)
    params = {"w": jnp.array([4.0, -3.0, 7.0])}
    state = adamw.init(params, cfg)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 2.0) ** 2))(params)
        params, state, _ = adamw.update(g, state, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), 2.0, atol=0.05)


def test_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule_lr(cfg, jnp.asarray(s)))
           for s in (0, 5, 10, 55, 99)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # mid-warmup
    assert lrs[2] == 1.0                     # warmup done
    assert 0 < lrs[4] < lrs[3] < lrs[2]      # cosine decays


def test_grad_clipping():
    cfg = adamw.AdamWConfig(lr=0.1, clip_norm=1.0, warmup_steps=0,
                            total_steps=10, schedule="constant",
                            weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params, cfg)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    p2, _, m = adamw.update(g, state, params, cfg)
    assert float(m["grad_norm"]) > 1e5
    assert abs(float(p2["w"][0])) < 1.0      # clipped update is bounded


# ------------------------------------------------------------ checkpoint --
def test_ckpt_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "n": {"b": jnp.ones((4,), jnp.int32)}}
        for s in (10, 20, 30, 40):
            ckpt.save(d, s, tree, keep=2)
        assert ckpt.list_steps(d) == [30, 40]
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        restored, step = ckpt.restore(d, like)
        assert step == 40
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["n"]["b"].dtype == jnp.int32


def test_ckpt_async_and_crash_cleanup():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.ones((8, 8))}
        t = ckpt.save(d, 1, tree, async_save=True)
        t.join()
        assert ckpt.latest_step(d) == 1
        # simulate a crashed partial save
        os.makedirs(os.path.join(d, "step_00000002.tmp"))
        ckpt.save(d, 3, tree)
        assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_ckpt_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"w": jnp.ones((4,))})
        like = {"w": jax.ShapeDtypeStruct((5,), jnp.float32)}
        with pytest.raises(ValueError):
            ckpt.restore(d, like)


def test_ckpt_missing_key_raises():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"w": jnp.ones((4,))})
        like = {"w": jax.ShapeDtypeStruct((4,), jnp.float32),
                "extra": jax.ShapeDtypeStruct((2,), jnp.float32)}
        with pytest.raises(KeyError):
            ckpt.restore(d, like)


# ------------------------------------------------------------------ data --
def test_hash_batch_deterministic():
    a = pipeline.hash_batch(0, 7, 4, 16, 100)
    b = pipeline.hash_batch(0, 7, 4, 16, 100)
    c = pipeline.hash_batch(0, 8, 4, 16, 100)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    # labels are next-token shifted
    full_a = pipeline.hash_batch(0, 7, 4, 16, 100)
    np.testing.assert_array_equal(np.asarray(full_a["labels"][:, :-1]),
                                  np.asarray(full_a["tokens"][:, 1:]))


def test_markov_learnable_structure():
    task = pipeline.MarkovTask(32, seed=1, branching=3)
    assert task.entropy_floor() < 0.5 * np.log(32)
    b = task.batch(0, 8, 64)
    succ = task.succ
    toks = np.asarray(b["tokens"])
    # every transition must be one of the chain's successors
    for row in toks[:4]:
        for t in range(len(row) - 1):
            assert row[t + 1] in succ[row[t]]


def test_prefetcher():
    seen = []

    def make(step):
        seen.append(step)
        return {"x": step}

    pf = pipeline.Prefetcher(make, depth=2)
    got = [next(pf) for _ in range(5)]
    pf.close()
    assert [s for s, _ in got] == [0, 1, 2, 3, 4]
    assert all(b["x"] == s for s, b in got)


# ---------------------------------------------------- gradient compression --
def test_error_feedback_unbiased_longrun():
    """EF-int8 SGD converges where naive quantized SGD stalls."""
    w_true = jnp.array([0.3, -0.7, 0.05])

    def loss(w):
        return jnp.sum((w - w_true) ** 2)

    w = jnp.zeros(3)
    err = {"w": jnp.zeros(3)}
    for _ in range(400):
        g = {"w": jax.grad(loss)(w)}
        comp, err = compression.compress_with_feedback(g, err)
        deq = comp["w"]["q"].astype(jnp.float32) * comp["w"]["scale"]
        w = w - 0.05 * deq
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_true), atol=0.02)


def test_compressed_bytes_accounting():
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((5,))}
    # per leaf: size int8 codes + one 4-byte fp32 scale on the wire
    assert compression.compressed_bytes(params) == (100 + 4) + (5 + 4)


# ------------------------------------------------------------ fault loop --
def test_resilient_loop_failure_recovery():
    with tempfile.TemporaryDirectory() as d:
        def step_fn(state, step):
            return state + 1, {"loss": float(step)}

        def save_fn(state, step):
            ckpt.save(d, step, {"s": jnp.asarray(state)})

        def restore_fn():
            like = {"s": jax.ShapeDtypeStruct((), jnp.int32)}
            tree, step = ckpt.restore(d, like)
            return int(tree["s"]), step

        save_fn(0, 0)
        loop = ResilientLoop(step_fn, save_fn, restore_fn, ckpt_every=5,
                             inject_failure_at=12)
        state, end = loop.run(0, 0, 20)
        assert end == 20
        assert loop.report.failures == 1
        assert loop.report.restores == 1
        assert state == 20    # replayed steps after restore


def test_resilient_loop_exceeds_budget():
    def bad_step(state, step):
        raise RuntimeError("always fails")

    loop = ResilientLoop(bad_step, lambda s, i: None, lambda: (0, 0),
                         max_failures=2)
    with pytest.raises(RuntimeError):
        loop.run(0, 0, 5)


def test_straggler_detection():
    calls = {"n": 0}
    delays = [0.01] * 5 + [0.08, 0.08, 0.08] + [0.01] * 3

    def step_fn(state, step):
        time.sleep(delays[step])
        return state, {}

    loop = ResilientLoop(step_fn, lambda s, i: None, lambda: (0, 0),
                         ckpt_every=1000, straggler_factor=3.0,
                         straggler_patience=3,
                         on_straggler=lambda: calls.__setitem__(
                             "n", calls["n"] + 1))
    loop.run(0, 0, len(delays))
    assert loop.report.straggler_events >= 3
    assert calls["n"] >= 1        # mitigation fired
