"""Property tests for the fixed-point quantization layer (paper §III)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, strategies as st

from repro.core.quant import (
    fake_quant, pack_int4, qmax, quant_linear_ref, quantize, unpack_int4,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def arrays(draw, shape):
    data = draw(st.lists(
        st.floats(-100, 100, allow_nan=False, width=32),
        min_size=int(np.prod(shape)), max_size=int(np.prod(shape))))
    return np.asarray(data, np.float32).reshape(shape)


@st.composite
def matrix(draw):
    k = draw(st.integers(2, 24))
    n = draw(st.integers(2, 24))
    return arrays(draw, (k, n))


@given(matrix(), st.sampled_from([4, 6, 8]))
def test_roundtrip_error_bound(w, wl):
    """|dequant(quant(x)) - x| <= scale/2 elementwise."""
    q = quantize(jnp.asarray(w), wl, axis=0)
    err = np.abs(np.asarray(q.dequant()) - w)
    bound = np.asarray(q.scale) / 2 + 1e-6
    assert (err <= bound + 1e-4 * np.abs(w)).all()


@given(matrix(), st.sampled_from([4, 6, 8]))
def test_codes_in_range(w, wl):
    q = quantize(jnp.asarray(w), wl, axis=0)
    m = qmax(wl)
    assert int(jnp.max(jnp.abs(q.values.astype(jnp.int32)))) <= m


@given(matrix())
def test_idempotent(w):
    """fake_quant(fake_quant(x)) == fake_quant(x)."""
    a = fake_quant(jnp.asarray(w), 6, axis=0)
    b = fake_quant(a, 6, axis=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@given(matrix(), st.sampled_from([4, 6, 8]))
def test_monotone_in_bits(w, wl):
    """More bits never increases the Frobenius reconstruction error."""
    wj = jnp.asarray(w)
    errs = [float(jnp.linalg.norm(wj - quantize(wj, b, 0).dequant()))
            for b in (4, 6, 8)]
    assert errs[0] >= errs[1] >= errs[2] - 1e-5


def test_error_decreases_with_bits_realistic():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (128, 128))
    errs = {b: float(jnp.linalg.norm(w - quantize(w, b, 0).dequant())
                     / jnp.linalg.norm(w)) for b in (4, 6, 8)}
    assert errs[4] > 2 * errs[6] > 3 * errs[8]


def test_quant_linear_ref_shapes():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (5, 16))
    w = jax.random.normal(key, (16, 8))
    y = quant_linear_ref(x, w, 8, 8)
    assert y.shape == (5, 8)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.05


@given(st.integers(1, 12), st.integers(1, 12))
def test_pack_unpack_int4(r, c):
    rng = np.random.default_rng(0)
    codes = rng.integers(-8, 8, size=(r, 2 * c)).astype(np.int8)
    packed = pack_int4(jnp.asarray(codes))
    assert packed.shape == (r, c)
    out = unpack_int4(packed)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_storage_bits_accounting():
    w = jnp.ones((64, 32))
    q = quantize(w, 4, axis=0)
    assert q.storage_bits() == 64 * 32 * 4 + 32 * 32
