"""Property tests for the fixed-point quantization layer (paper §III)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, strategies as st

from repro.core.quant import (
    fake_quant, pack_int4, pack_weights, packable, packed_pad_ok, qmax,
    quant_linear_ref, quantize, unpack_int4, unpack_weights,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def arrays(draw, shape):
    data = draw(st.lists(
        st.floats(-100, 100, allow_nan=False, width=32),
        min_size=int(np.prod(shape)), max_size=int(np.prod(shape))))
    return np.asarray(data, np.float32).reshape(shape)


@st.composite
def matrix(draw):
    k = draw(st.integers(2, 24))
    n = draw(st.integers(2, 24))
    return arrays(draw, (k, n))


@given(matrix(), st.sampled_from([4, 6, 8]))
def test_roundtrip_error_bound(w, wl):
    """|dequant(quant(x)) - x| <= scale/2 elementwise."""
    q = quantize(jnp.asarray(w), wl, axis=0)
    err = np.abs(np.asarray(q.dequant()) - w)
    bound = np.asarray(q.scale) / 2 + 1e-6
    assert (err <= bound + 1e-4 * np.abs(w)).all()


@given(matrix(), st.sampled_from([4, 6, 8]))
def test_codes_in_range(w, wl):
    q = quantize(jnp.asarray(w), wl, axis=0)
    m = qmax(wl)
    assert int(jnp.max(jnp.abs(q.values.astype(jnp.int32)))) <= m


@given(matrix())
def test_idempotent(w):
    """fake_quant(fake_quant(x)) == fake_quant(x)."""
    a = fake_quant(jnp.asarray(w), 6, axis=0)
    b = fake_quant(a, 6, axis=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@given(matrix(), st.sampled_from([4, 6, 8]))
def test_monotone_in_bits(w, wl):
    """More bits never increases the Frobenius reconstruction error."""
    wj = jnp.asarray(w)
    errs = [float(jnp.linalg.norm(wj - quantize(wj, b, 0).dequant()))
            for b in (4, 6, 8)]
    assert errs[0] >= errs[1] >= errs[2] - 1e-5


def test_error_decreases_with_bits_realistic():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (128, 128))
    errs = {b: float(jnp.linalg.norm(w - quantize(w, b, 0).dequant())
                     / jnp.linalg.norm(w)) for b in (4, 6, 8)}
    assert errs[4] > 2 * errs[6] > 3 * errs[8]


def test_quant_linear_ref_shapes():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (5, 16))
    w = jax.random.normal(key, (16, 8))
    y = quant_linear_ref(x, w, 8, 8)
    assert y.shape == (5, 8)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.05


@given(st.integers(1, 12), st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_pack_unpack_int4(r, c, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-8, 8, size=(r, 2 * c)).astype(np.int8)
    packed = pack_int4(jnp.asarray(codes))
    assert packed.shape == (r, c)
    assert packed.dtype == jnp.int8
    out = unpack_int4(packed)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_pack_unpack_int4_exhaustive_range():
    """Every one of the 256 (lo, hi) nibble pairs round-trips exactly —
    the full int4 code range [-8, 7] in both byte halves."""
    lo, hi = np.meshgrid(np.arange(-8, 8), np.arange(-8, 8))
    codes = np.stack([lo.ravel(), hi.ravel()], axis=-1).astype(np.int8)
    out = unpack_int4(pack_int4(jnp.asarray(codes)))
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_pack_int4_rejects_odd_last_dim():
    with pytest.raises(ValueError, match="even last dim"):
        pack_int4(jnp.zeros((4, 5), jnp.int8))


@given(matrix())
def test_pack_weights_refuses_small_axes(w):
    """The 2..24-wide hypothesis axes are all pad-inflating (a packed
    half-width must pad to 256 lanes where the carrier pads to 128), so
    pack_weights must refuse every one of them — packing would double
    the kernels' padded work for zero byte savings."""
    q = quantize(jnp.asarray(w), 4, axis=0)
    assert not packed_pad_ok(w.shape[-1])
    assert not packable(q) and pack_weights(q) is q


@pytest.mark.parametrize("n", [192, 256, 512])
def test_pack_weights_roundtrip(n):
    """pack_weights/unpack_weights is exact and dequant-invariant on any
    W4 tensor whose last dim is even and pad-ok; odd / pad-inflating
    dims and W6/W8 stay carriers."""
    w = jnp.asarray(np.random.default_rng(n).normal(size=(16, n)),
                    jnp.float32)
    q = quantize(w, 4, axis=0)
    assert packed_pad_ok(n)
    p = pack_weights(q)
    assert p.packed and p.shape == q.shape
    assert p.values.shape[-1] == n // 2
    back = unpack_weights(p)
    np.testing.assert_array_equal(np.asarray(back.values),
                                  np.asarray(q.values))
    np.testing.assert_array_equal(np.asarray(p.dequant()),
                                  np.asarray(q.dequant()))


def test_storage_bits_accounting():
    """storage_bits reports RESIDENT bytes: an unpacked W4 tensor still
    occupies a full int8 carrier (8 bits/code); packing halves it to the
    true 4; W6 has no byte-aligned packing and a pad-inflating W4 axis
    refuses to pack — both stay at an honest 8."""
    w = jnp.ones((64, 256))
    q = quantize(w, 4, axis=0)
    assert q.storage_bits() == 64 * 256 * 8 + 32 * 256
    p = pack_weights(q)
    assert p.packed and p.values.shape == (64, 128)
    assert p.shape == (64, 256)
    assert p.storage_bits() == 64 * 256 * 4 + 32 * 256
    q6 = quantize(w, 6, axis=0)
    assert pack_weights(q6) is q6          # carrier-resident, honest 8 bits
    assert q6.storage_bits() == 64 * 256 * 8 + 32 * 256
    q32 = quantize(jnp.ones((64, 32)), 4, axis=0)
    assert pack_weights(q32) is q32        # pad-inflating axis: carrier
    assert q32.storage_bits() == 64 * 32 * 8 + 32 * 32
