"""End-to-end behaviour tests: train-loss-decreases, compress->serve,
fault-injected training, and the train.py / serve.py drivers themselves."""
import tempfile

import jax
import numpy as np

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_driver_loss_decreases():
    """The paper's setting needs a *learnable* task: 60 steps of the Markov
    stream on the opus-mt smoke model must beat the first-steps loss."""
    with tempfile.TemporaryDirectory() as d:
        losses = train_mod.main([
            "--arch", "opus-mt", "--smoke", "--steps", "60",
            "--batch", "8", "--seq", "64", "--lr", "1e-3",
            "--ckpt-dir", d, "--ckpt-every", "50",
        ])
        assert len(losses) == 60
        first, last = np.mean(losses[:6]), np.mean(losses[-6:])
        assert last < first - 0.3, (first, last)


def test_train_driver_fault_injection_and_resume():
    with tempfile.TemporaryDirectory() as d:
        losses = train_mod.main([
            "--arch", "opus-mt", "--smoke", "--steps", "30",
            "--batch", "4", "--seq", "32",
            "--ckpt-dir", d, "--ckpt-every", "10",
            "--inject-failure-at", "15",
        ])
        # failure at 15 -> restore from 10 -> replay: >= 30 step records
        assert len(losses) >= 30
        from repro.checkpoint import ckpt
        assert ckpt.latest_step(d) == 30


def test_train_resume_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        train_mod.main(["--arch", "opus-mt", "--smoke", "--steps", "10",
                        "--batch", "4", "--seq", "32", "--ckpt-dir", d,
                        "--ckpt-every", "5"])
        losses = train_mod.main(["--arch", "opus-mt", "--smoke", "--steps",
                                 "14", "--batch", "4", "--seq", "32",
                                 "--ckpt-dir", d, "--ckpt-every", "5",
                                 "--resume"])
        assert len(losses) == 4   # only steps 10..13 ran


def test_train_microbatched_grad_accum():
    with tempfile.TemporaryDirectory() as d:
        losses = train_mod.main([
            "--arch", "opus-mt", "--smoke", "--steps", "8",
            "--batch", "8", "--seq", "32", "--microbatches", "2",
            "--ckpt-dir", d,
        ])
        assert len(losses) == 8 and np.isfinite(losses).all()


def test_train_8bit_optimizer():
    with tempfile.TemporaryDirectory() as d:
        losses = train_mod.main([
            "--arch", "opus-mt", "--smoke", "--steps", "20",
            "--batch", "8", "--seq", "32", "--opt-bits", "8",
            "--lr", "1e-3", "--ckpt-dir", d,
        ])
        assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_serve_driver_all_compressions():
    for method in ("none", "quant", "itera"):
        toks = serve_mod.main([
            "--arch", "opus-mt", "--smoke", "--compression", method,
            "--wl", "6", "--rank-fraction", "0.6",
            "--prompt-len", "16", "--gen", "4", "--batch", "2",
        ])
        assert toks.shape == (2, 4)
        assert np.asarray(toks).min() >= 0


def test_compressed_generation_agrees_with_dense_mostly():
    """W8 itera at near-full rank rarely changes greedy decisions.

    The model is randomly initialized, so multi-step rollouts compound any
    argmax flip chaotically — assert strong FIRST-STEP logit agreement and
    only loose rollout agreement."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.compress import CompressionConfig, compress_params
    from repro.data.pipeline import MarkovTask
    from repro.models import init_params, prefill

    cfg = get_config("opus-mt", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = MarkovTask(cfg.vocab_size, seed=0).batch(0, 4, 24)["tokens"]
    lg_d, _ = prefill(params, prompts, cfg)

    # quant-only W8: only A8/W8 rounding noise -> strong top-1 agreement
    cq, _ = compress_params(params, CompressionConfig(
        method="quant", weight_wl=8))
    lg_q, _ = prefill(cq, prompts, cfg)
    top1 = float(np.mean(np.asarray(jnp.argmax(lg_d[:, -1], -1))
                         == np.asarray(jnp.argmax(lg_q[:, -1], -1))))
    assert top1 >= 0.75, top1

    # itera at near-full rank: random-init weights have a flat spectrum,
    # so bound the logit distortion (argmax on a random model is chaotic)
    cp, _ = compress_params(params, CompressionConfig(
        method="itera", weight_wl=8, rank_fraction=0.95))
    lg_c, _ = prefill(cp, prompts, cfg)
    rel = float(jnp.linalg.norm(lg_c - lg_d) / jnp.linalg.norm(lg_d))
    assert rel < 0.25, rel

    comp = serve_mod.generate(cp, cfg, prompts, 8)
    assert comp.shape == (4, 8)
    assert np.asarray(comp).min() >= 0
