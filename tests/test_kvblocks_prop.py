"""Property-based `BlockPool` invariants (hypothesis; falls back to the
seeded-random shim in hypothesis_fallback when it is not installed).

Under ANY sequence of alloc/free operations the free-list allocator must
uphold:
  * the reserved trash block 0 is never handed out;
  * no block is ever held twice (no double-alloc), and freeing a block
    not currently held is a hard error (no double-free);
  * `available` always equals capacity minus blocks held — the free list
    never drifts from the allocation set.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, strategies as st

from repro.runtime.kvblocks import BlockPool, span_slots

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@st.composite
def pool_and_ops(draw):
    """A pool geometry plus a random alloc/free script. Ops are encoded
    so they stay meaningful whatever the interleaving: ('alloc', k) asks
    for k blocks (possibly more than available — callers must see a
    clean refusal), ('free', i) releases the i-th live group (mod the
    number of groups alive at that point)."""
    num_blocks = draw(st.integers(2, 24))
    block_size = draw(st.integers(1, 8))
    ops = []
    for _ in range(draw(st.integers(1, 60))):
        k = draw(st.integers(-8, 8))
        ops.append(("free", -k - 1) if k < 0 else ("alloc", k + 1))
    return num_blocks, block_size, ops


@given(pool_and_ops())
def test_block_pool_invariants_random_ops(case):
    num_blocks, block_size, ops = case
    pool = BlockPool(num_blocks, block_size)
    live: list[list[int]] = []
    for op, arg in ops:
        if op == "alloc":
            if pool.can_alloc(arg):
                ids = pool.alloc(arg)
                assert len(ids) == arg
                assert 0 not in ids, "reserved trash block handed out"
                live.append(ids)
            else:
                with pytest.raises(RuntimeError, match="exhausted"):
                    pool.alloc(arg)
        elif live:
            pool.free(live.pop(arg % len(live)))
        held = [b for ids in live for b in ids]
        assert len(held) == len(set(held)), "block held twice"
        assert all(0 < b < num_blocks for b in held)
        assert pool.available == pool.capacity - len(held), \
            "free list inconsistent with allocations"
        assert pool.can_alloc(pool.available)
        assert not pool.can_alloc(pool.available + 1)
    for ids in live:
        pool.free(ids)
    assert pool.available == pool.capacity
    # every block freed exactly once: a second free must be rejected
    if pool.capacity >= 1:
        ids = pool.alloc(1)
        pool.free(ids)
        with pytest.raises(RuntimeError, match="double free"):
            pool.free(ids)


@given(st.integers(1, 8), st.integers(0, 20), st.integers(0, 12))
def test_span_slots_route_every_valid_token_once(bsz, ctx, qlen):
    """span_slots maps each valid span token to the unique physical slot
    its logical position owns; pad slots all land in trash block 0."""
    width = max(qlen, 1)
    mb = (ctx + width + bsz - 1) // bsz + 1
    table = np.arange(1, mb + 1, dtype=np.int32)[None, :]   # blocks 1..mb
    blk, off = span_slots(table, np.asarray([ctx], np.int32),
                          np.asarray([qlen], np.int32), width, bsz)
    blk, off = np.asarray(blk)[0], np.asarray(off)[0]
    for i in range(width):
        pos = ctx + i
        if i < qlen:
            assert blk[i] == table[0, pos // bsz]
            assert off[i] == pos % bsz
        else:
            assert blk[i] == 0 and off[i] == 0
    # valid slots are distinct (no token overwrites another)
    valid = [(int(blk[i]), int(off[i])) for i in range(qlen)]
    assert len(valid) == len(set(valid))
