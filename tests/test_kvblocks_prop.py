"""Property-based `BlockPool` invariants (hypothesis; falls back to the
seeded-random shim in hypothesis_fallback when it is not installed).

Under ANY sequence of alloc/free operations the free-list allocator must
uphold:
  * the reserved trash block 0 is never handed out;
  * no block is ever held twice (no double-alloc), and freeing a block
    not currently held is a hard error (no double-free);
  * `available` always equals capacity minus blocks held — the free list
    never drifts from the allocation set.

The speculative draft path adds provisional allocation on top
(`Scheduler.reserve_speculation` / `commit_speculation`): under ANY
sequence of reserve→accept→rollback rounds, rejected drafts must return
every provisional block, the trash block must never be captured, and a
row's holdings must stay consistent with its committed context.

Prefix caching layers refcounts and a content index on top: under ANY
interleaving of admissions (with shared/duplicated prompts), chunked
prefill, decode, speculation rounds, preemptions, and finishes, the
refcounts must exactly mirror the live holders (no leak, never
negative), the trash block is never held or cached, a block held by
more than one sequence is never a prefill scatter target (shared
payload never mutated in place), and draining every sequence returns
the pool to full availability.

Tensor-parallel serving head-shards the physical pool but keeps the
allocator and block tables host-side REPLICATED — every shard indexes
its head-slice with the same block ids. The TP invariants here pin
that contract: the same op script driven against one allocator per
shard never lets the shards drift (identical free lists, identical
draft grants, trash block captured on no shard), and `shard_pool` is
an exact head-partition of the single-device pool.
"""
import collections
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, strategies as st

from repro.runtime import elastic
from repro.runtime.kvblocks import (BlockPool, blocks_for_positions,
                                    blocks_needed, init_paged_cache,
                                    pool_pspecs, shard_pool, span_slots,
                                    valid_block_counts)
from repro.runtime.scheduler import Request, Scheduler, Sequence

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@st.composite
def pool_and_ops(draw):
    """A pool geometry plus a random alloc/free script. Ops are encoded
    so they stay meaningful whatever the interleaving: ('alloc', k) asks
    for k blocks (possibly more than available — callers must see a
    clean refusal), ('free', i) releases the i-th live group (mod the
    number of groups alive at that point)."""
    num_blocks = draw(st.integers(2, 24))
    block_size = draw(st.integers(1, 8))
    ops = []
    for _ in range(draw(st.integers(1, 60))):
        k = draw(st.integers(-8, 8))
        ops.append(("free", -k - 1) if k < 0 else ("alloc", k + 1))
    return num_blocks, block_size, ops


@given(pool_and_ops())
def test_block_pool_invariants_random_ops(case):
    num_blocks, block_size, ops = case
    pool = BlockPool(num_blocks, block_size)
    live: list[list[int]] = []
    for op, arg in ops:
        if op == "alloc":
            if pool.can_alloc(arg):
                ids = pool.alloc(arg)
                assert len(ids) == arg
                assert 0 not in ids, "reserved trash block handed out"
                live.append(ids)
            else:
                with pytest.raises(RuntimeError, match="exhausted"):
                    pool.alloc(arg)
        elif live:
            pool.free(live.pop(arg % len(live)))
        held = [b for ids in live for b in ids]
        assert len(held) == len(set(held)), "block held twice"
        assert all(0 < b < num_blocks for b in held)
        assert pool.available == pool.capacity - len(held), \
            "free list inconsistent with allocations"
        assert pool.can_alloc(pool.available)
        assert not pool.can_alloc(pool.available + 1)
    for ids in live:
        pool.free(ids)
    assert pool.available == pool.capacity
    # every block freed exactly once: a second free must be rejected
    if pool.capacity >= 1:
        ids = pool.alloc(1)
        pool.free(ids)
        with pytest.raises(RuntimeError, match="double free"):
            pool.free(ids)


@given(st.integers(1, 8), st.integers(0, 20), st.integers(0, 12))
def test_span_slots_route_every_valid_token_once(bsz, ctx, qlen):
    """span_slots maps each valid span token to the unique physical slot
    its logical position owns; pad slots all land in trash block 0."""
    width = max(qlen, 1)
    mb = (ctx + width + bsz - 1) // bsz + 1
    table = np.arange(1, mb + 1, dtype=np.int32)[None, :]   # blocks 1..mb
    blk, off = span_slots(table, np.asarray([ctx], np.int32),
                          np.asarray([qlen], np.int32), width, bsz)
    blk, off = np.asarray(blk)[0], np.asarray(off)[0]
    for i in range(width):
        pos = ctx + i
        if i < qlen:
            assert blk[i] == table[0, pos // bsz]
            assert off[i] == pos % bsz
        else:
            assert blk[i] == 0 and off[i] == 0
    # valid slots are distinct (no token overwrites another)
    valid = [(int(blk[i]), int(off[i])) for i in range(qlen)]
    assert len(valid) == len(set(valid))


@st.composite
def spec_rounds(draw):
    """A pool geometry, one under-provisioned decoding row, and a script
    of speculative rounds: each round offers k draft tokens and then
    accepts a (possibly empty) prefix of whatever was granted. The row
    starts holding only its committed-context blocks — NOT the admission
    worst case — so reserve_speculation genuinely has to allocate."""
    block_size = draw(st.integers(1, 4))
    prompt_len = draw(st.integers(1, 10))
    max_tokens = draw(st.integers(2, 20))
    # sometimes too small to back every draft: the shrink path must
    # engage, never crash
    num_blocks = draw(st.integers(2, 30))
    rounds = [(draw(st.integers(1, 6)),    # k offered
               draw(st.integers(0, 6)))    # acceptance draw
              for _ in range(draw(st.integers(1, 12)))]
    return block_size, prompt_len, max_tokens, num_blocks, rounds


@given(spec_rounds())
def test_speculative_rollback_never_leaks(case):
    block_size, prompt_len, max_tokens, num_blocks, rounds = case
    pool = BlockPool(num_blocks, block_size)
    committed = prompt_len            # prompt cached, first token pending
    base_need = blocks_for_positions(committed, block_size)
    if base_need > pool.capacity:
        return                        # config can't even hold the prompt
    sched = Scheduler(pool, 1)
    req = Request(tokens=np.ones(prompt_len, np.int32),
                  max_tokens=max_tokens, rid=0)
    seq = Sequence(req=req, row=0, block_ids=pool.alloc(base_need),
                   prefilled=prompt_len, n_emitted=1)
    for k_offer, acc_draw in rounds:
        if seq.done:
            break
        avail_before = pool.available
        held_before = len(seq.block_ids)
        k = sched.reserve_speculation(seq, k_offer)
        # grant is clamped inside the request and the pool
        assert 0 <= k <= min(k_offer, seq.max_tokens - seq.n_emitted - 1)
        assert 0 not in seq.draft_blocks, "trash block 0 captured"
        assert len(set(seq.block_ids)) == len(seq.block_ids)
        if k == 0:
            # no grant -> no draft round; a plain decode step would lean
            # on the admission-time worst-case reservation, which this
            # deliberately under-provisioned row does not carry
            assert seq.draft_blocks == []
            assert pool.available == avail_before
            continue
        # the grant covers through the verify span's last written
        # position (index end -> end + 1 slots)
        end = seq.prompt_len + seq.n_emitted - 1 + k
        assert len(seq.block_ids) >= \
            blocks_for_positions(end + 1, block_size)
        # kernel-walk safety: the paged-attention metadata for this
        # row's verify span never exceeds the blocks actually held
        ctx0 = seq.prompt_len + seq.n_emitted - 1
        vb = int(valid_block_counts(np.asarray([ctx0], np.int32),
                                    np.asarray([1 + k], np.int32),
                                    block_size, 1 << 30)[0])
        assert vb <= len(seq.block_ids)
        # accept a prefix: 0..k drafts survive, plus the full model's own
        # token (every verify emits at least one)
        seq.n_emitted += min(acc_draw, k) + 1
        released = sched.commit_speculation(seq)
        assert seq.draft_blocks == []
        assert 0 not in released
        # reject-then-free leaks nothing: blocks either stayed with the
        # row or went back to the pool, and the free list agrees
        assert pool.available == \
            pool.capacity - len(seq.block_ids), \
            "pool accounting drifted across a speculative round"
        # holdings rewound to the committed context (never below the
        # pre-draft holdings, never past what the round allocated) —
        # i.e. valid_block_counts for every future span over the cached
        # context stays within the rewound table
        ctx = seq.prompt_len + seq.n_emitted - 1
        assert len(seq.block_ids) >= blocks_for_positions(ctx, block_size)
        assert int(valid_block_counts(
            np.asarray([max(ctx - 1, 0)], np.int32),
            np.asarray([1], np.int32), block_size,
            len(seq.block_ids))[0]) <= len(seq.block_ids)
        assert held_before <= len(seq.block_ids) + len(released)
        assert pool.available <= avail_before
    sched.finish(seq)
    assert pool.available == pool.capacity, "blocks leaked after finish"


# ------------------------------------------------- head-sharded pool (TP) --

@given(pool_and_ops(), st.sampled_from([2, 4]))
def test_block_pool_replicated_across_shards(case, tp):
    """Under TP the allocator is replicated host-side: one logical
    BlockPool per shard fed the SAME op script. Whatever the script, the
    per-shard free lists must stay identical step by step (a drifted
    shard would scatter KV into different physical blocks than its
    peers' block tables name) and the trash block is handed out on no
    shard."""
    num_blocks, block_size, ops = case
    pools = [BlockPool(num_blocks, block_size) for _ in range(tp)]
    live: list[list[list[int]]] = [[] for _ in range(tp)]
    for op, arg in ops:
        for s, pool in enumerate(pools):
            if op == "alloc":
                if pool.can_alloc(arg):
                    ids = pool.alloc(arg)
                    assert 0 not in ids, f"shard {s}: trash block captured"
                    live[s].append(ids)
                else:
                    with pytest.raises(RuntimeError, match="exhausted"):
                        pool.alloc(arg)
            elif live[s]:
                pool.free(live[s].pop(arg % len(live[s])))
        # shards agree exactly: same groups, same free count
        assert all(live[s] == live[0] for s in range(tp)), \
            "per-shard allocations drifted"
        assert all(p.available == pools[0].available for p in pools), \
            "per-shard free lists drifted"
    for s, pool in enumerate(pools):
        for ids in live[s]:
            pool.free(ids)
        assert pool.available == pool.capacity


@given(spec_rounds(), st.sampled_from([2, 4]))
def test_speculative_rounds_replicated_across_shards(case, tp):
    """reserve→accept→rollback rounds replayed on one Scheduler per
    shard: every shard grants the same k, rewinds to the same holdings,
    and valid_block_counts over the rewound table agree across shards
    (the kernel walks the same number of blocks on every chip)."""
    block_size, prompt_len, max_tokens, num_blocks, rounds = case
    base_need = blocks_for_positions(prompt_len, block_size)
    if base_need > num_blocks - 1:
        return
    pools = [BlockPool(num_blocks, block_size) for _ in range(tp)]
    scheds = [Scheduler(p, 1) for p in pools]
    reqs = [Request(tokens=np.ones(prompt_len, np.int32),
                    max_tokens=max_tokens, rid=0) for _ in range(tp)]
    seqs = [Sequence(req=reqs[s], row=0, block_ids=pools[s].alloc(base_need),
                     prefilled=prompt_len, n_emitted=1) for s in range(tp)]
    for k_offer, acc_draw in rounds:
        if seqs[0].done:
            break
        grants = [scheds[s].reserve_speculation(seqs[s], k_offer)
                  for s in range(tp)]
        assert len(set(grants)) == 1, "draft grant differs across shards"
        for s in range(tp):
            assert 0 not in seqs[s].draft_blocks
            assert seqs[s].block_ids == seqs[0].block_ids
        k = grants[0]
        adv = min(acc_draw, k) + 1 if k else 0
        for s in range(tp):
            seqs[s].n_emitted += adv
            if k:
                scheds[s].commit_speculation(seqs[s])
        assert all(seqs[s].block_ids == seqs[0].block_ids
                   for s in range(tp)), "rollback diverged across shards"
        assert all(pools[s].available == pools[0].available
                   for s in range(tp))
        # per-shard kernel metadata agrees: same valid block walk
        ctx = seqs[0].prompt_len + seqs[0].n_emitted - 1
        counts = {int(valid_block_counts(
            np.asarray([max(ctx - 1, 0)], np.int32),
            np.asarray([1], np.int32), block_size,
            len(seqs[s].block_ids))[0]) for s in range(tp)}
        assert len(counts) == 1, "valid_block_counts differ across shards"
    for s in range(tp):
        scheds[s].finish(seqs[s])
        assert pools[s].available == pools[s].capacity


def test_shard_pool_partitions_heads_exactly():
    """shard_pool is an exact partition: the per-shard head-slices
    concatenate back to the single-device pool for every leaf (16-bit
    and int8-with-scales layouts), per-shard shapes carry Hk/tp heads,
    and non-dividing geometry / bad shard indices are hard errors."""
    from repro.configs import get_config

    cfg = get_config("opus-mt", smoke=True)
    for kv_bits in (16, 8):
        c = dataclasses.replace(cfg, kv_cache_bits=kv_bits)
        pool = init_paged_cache(c, num_blocks=5, block_size=4)
        hk = c.num_kv_heads
        for tp in (1, 2, 4):
            shards = [shard_pool(pool, tp, s) for s in range(tp)]
            for key, leaf in pool.items():
                for s in range(tp):
                    assert shards[s][key].shape[3] == hk // tp
                glued = np.concatenate(
                    [np.asarray(s[key]) for s in shards], axis=3)
                assert np.array_equal(glued, np.asarray(leaf)), key
        with pytest.raises(ValueError, match="shard"):
            shard_pool(pool, 2, 2)
        with pytest.raises(ValueError, match="not divisible"):
            shard_pool(pool, 3, 0)


# ---------------------------------------------- prefix-cache refcounts --

@st.composite
def cache_scripts(draw):
    """A pool geometry plus a random script over the prefix-caching
    scheduler: submissions drawn from two shared prompt prefixes (tail
    length 0 makes a fully-cached, copy-on-write candidate), interleaved
    with admission, prefill chunks, decode/speculation rounds, pool-
    pressure preemptions, and finishes."""
    num_blocks = draw(st.integers(6, 24))
    block_size = draw(st.integers(1, 4))
    max_batch = draw(st.integers(1, 3))
    ops = []
    for _ in range(draw(st.integers(5, 45))):
        kind = draw(st.sampled_from(
            ["submit", "admit", "chunk", "decode", "spec", "finish",
             "preempt"]))
        ops.append((kind, draw(st.integers(0, 7))))
    return num_blocks, block_size, max_batch, ops


@given(cache_scripts())
def test_prefix_cache_refcounts_mirror_holders_exactly(case):
    num_blocks, bs, max_batch, ops = case
    pool = BlockPool(num_blocks, bs)
    sched = Scheduler(pool, max_batch, prefix_cache=True, fingerprint=b"prop")
    rid = 0

    def live():
        return [s for s in sched.rows if s is not None]

    for kind, arg in ops:
        if kind == "submit":
            p = arg % 2                             # two shared prefixes
            plen = (1 + p) * bs                     # 1 or 2 full blocks
            tail = (arg >> 1) % (bs + 2)            # 0 -> COW candidate
            toks = np.concatenate([
                np.full(plen, 17 + p, np.int32),
                np.arange(1000 + 10 * rid, 1000 + 10 * rid + tail,
                          dtype=np.int32)])
            req = Request(tokens=toks, max_tokens=1 + arg % 3, rid=rid)
            rid += 1
            if blocks_needed(toks.size, req.max_tokens, bs) <= pool.capacity:
                sched.submit(req)
        elif kind == "admit":
            s = sched.try_admit()
            if s is not None and s.cow_dst is not None:
                # engine contract: dispatch the device copy, then drop
                # the source pin
                assert s.cow_src is not None and s.cow_src != s.cow_dst
                sched.release_cow(s)
        elif kind == "chunk":
            cands = [s for s in live() if not s.prefill_done]
            if cands:
                s = cands[arg % len(cands)]
                width = min(1 + arg, s.prompt_len - s.prefilled)
                span = range(s.prefilled, s.prefilled + width)
                assert all(p // bs >= s.n_shared for p in span), \
                    "prefill chunk aimed inside the shared prefix"
                for b in {s.block_ids[p // bs] for p in span}:
                    assert pool.refcount(b) == 1, \
                        "prefill chunk would write a shared block"
                shared_before = s.block_ids[:s.n_shared]
                sched.advance_prefill(s, width)
                assert s.block_ids[:s.n_shared] == shared_before
        elif kind == "decode":
            cands = [s for s in live() if s.prefill_done and not s.done]
            if cands:
                cands[arg % len(cands)].n_emitted += 1
        elif kind == "spec":
            cands = [s for s in live()
                     if s.prefill_done and not s.done and s.n_emitted]
            if cands:
                s = cands[arg % len(cands)]
                shared_before = s.block_ids[:s.n_shared]
                k = sched.reserve_speculation(s, 1 + arg % 3)
                if k:
                    s.n_emitted += min(arg % (k + 1), k) + 1
                    sched.commit_speculation(s)
                assert s.block_ids[:s.n_shared] == shared_before, \
                    "speculative rollback rewound into shared blocks"
        elif kind == "finish":
            if live():
                sched.finish(live()[arg % len(live())])
        elif kind == "preempt":
            victims = elastic.preemption_victims(sched.rows)
            if victims:
                sched.preempt(victims[0])
        # ------ global invariants after EVERY op ------
        expect = collections.Counter()
        for s in live():
            assert 0 not in s.block_ids, "trash block held by a sequence"
            assert s.cow_src != 0 and s.cow_dst != 0
            assert len(set(s.block_ids)) == len(s.block_ids)
            assert s.prefilled >= s.n_shared * bs, \
                "write watermark fell inside the shared prefix"
            expect.update(s.block_ids)
            if s.cow_src is not None:
                expect[s.cow_src] += 1
        for b, c in expect.items():
            assert pool.refcount(b) == c, f"refcount drift on block {b}"
        assert pool.refcount(0) == 0
        assert pool.available == pool.capacity - len(expect), \
            "pool accounting drifted (leak or double count)"
        priv = collections.Counter()
        for s in live():
            priv.update(s.block_ids[s.n_shared:])
        assert all(c == 1 for c in priv.values()), \
            "privately-held block appears in two sequences"
    for s in list(sched.rows):
        if s is not None:
            sched.finish(s)
    assert pool.available == pool.capacity, "blocks leaked after drain"
    # refcounts can never go negative: the first over-free is an error
    ids = pool.alloc(1)
    pool.free(ids)
    with pytest.raises(RuntimeError, match="double free"):
        pool.free(ids)


def test_pool_pspecs_shard_heads_only():
    """pool_pspecs slices exactly the KV-head axis (3) over "model" for
    every pool leaf, and names the int8 scale planes iff the config
    carries int8 KV."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config

    cfg = get_config("opus-mt", smoke=True)
    specs = pool_pspecs(cfg)
    assert set(specs) == {"k", "v"}
    specs8 = pool_pspecs(dataclasses.replace(cfg, kv_cache_bits=8))
    assert set(specs8) == {"k", "v", "ks", "vs"}
    for spec in list(specs.values()) + list(specs8.values()):
        assert spec == P(None, None, None, "model", None)
