"""Fused in-device sampling, stop criteria, and streaming.

The contracts under test (runtime/sampling.py + the serve/generate
paths that consume it):

  * **Greedy bit-identity** — temperature-0 rows take the raw-logits
    argmax inside the SAME fused step as sampled rows, so a greedy
    request's tokens are bit-identical whether it rides a greedy-only
    serve, a mixed batch, or a speculative round.
  * **Seeded reproducibility** — keys are a pure function of
    (seed, rid, counter), so seeded serves replay token-for-token
    across repeats, prefix-cache on/off, TP mesh sizes (subprocess
    matrix), and the generate()/serve() split.
  * **Stop truncation** — device-side eos / stop-sequence / max_tokens
    evaluation agrees with the `match_stop_host` numpy oracle applied
    to the unstopped stream, inclusively.
  * **Streaming + SLO** — on_token delivers every token in order with
    exactly one final event per request; ServeResult's queue/goodput/
    attainment metrics are consistent with the outputs.

The dtype x kv x mesh x cache determinism matrix runs in ONE
subprocess under XLA_FLAGS=--xla_force_host_platform_device_count=8
(this process must keep seeing 1 device), same as test_tp_serving.
"""
import asyncio
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.engine import InferenceEngine, SamplingParams, TokenEvent
from repro.configs import get_config
from repro.core.compress import CompressionConfig
from repro.hw import tpu_model
from repro.launch.serve import serve_stream
from repro.models.transformer import init_params
from repro.runtime import sampling as smp
from repro.runtime.scheduler import Request
from repro.runtime.speculation import DraftSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="module")
def base():
    cfg = get_config("opus-mt", smoke=True)
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def engine(base):
    cfg, params = base
    return InferenceEngine(cfg, params, max_batch=3, block_size=4,
                           chunk_tokens=8)


def _prompts(vocab, seed=0, lens=(5, 11, 3, 14, 8)):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=n).astype(np.int32) for n in lens]


SAMPLED = SamplingParams(max_tokens=6, temperature=0.9, top_k=20,
                         top_p=0.9, seed=7)


# ------------------------------------------------------------ params --

def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(eos_id=-2)
    with pytest.raises(ValueError):
        SamplingParams(stop=((1, 2), ()))
    assert SamplingParams(top_p=1.0).top_p == 1.0


def test_sampling_params_json_roundtrip():
    sp = SamplingParams(max_tokens=9, temperature=0.7, top_k=5, top_p=0.85,
                        seed=3, eos_id=2, stop=((4, 5), (6,)))
    d = json.loads(json.dumps(sp.to_dict()))
    assert SamplingParams.from_dict(d) == sp
    # defaults survive a round trip through a sparse dict too
    assert SamplingParams.from_dict(
        json.loads(json.dumps(SamplingParams().to_dict()))) \
        == SamplingParams()


# ------------------------------------------------------- unit: keys --

def test_row_keys_pure_in_request_and_counter():
    seed = jnp.array([7, 7, 9], jnp.int32)
    rid = jnp.array([0, 1, 0], jnp.int32)
    ctr = jnp.array([3, 3, 3], jnp.int32)
    keys = np.asarray(smp.row_keys(seed, rid, ctr))
    # same (seed, rid, counter) gives the same key at any batch row
    solo = np.asarray(smp.row_keys(seed[:1], rid[:1], ctr[:1]))
    assert np.array_equal(keys[0], solo[0])
    # rid and seed both separate streams
    assert not np.array_equal(keys[0], keys[1])
    assert not np.array_equal(keys[0], keys[2])
    # consecutive counters separate draws within a stream
    nxt = np.asarray(smp.row_keys(seed[:1], rid[:1], ctr[:1] + 1))
    assert not np.array_equal(keys[0], nxt[0])


def test_f32_bits_roundtrip():
    for x in (0.0, 1.0, 0.9, 1e-3, 3.5):
        bits = smp.f32_bits(x)
        back = np.int32(bits).view(np.float32)
        assert back == np.float32(x)


# ---------------------------------------------------- unit: sampler --

def test_sample_tokens_greedy_and_degenerate_knobs():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 33)).astype(np.float32))
    keys = smp.row_keys(jnp.arange(4), jnp.arange(4), jnp.zeros(4, jnp.int32))
    argmax = np.asarray(jnp.argmax(logits, axis=-1))

    temp0 = smp.sample_tokens(logits, jnp.zeros(4), jnp.zeros(4, jnp.int32),
                              jnp.ones(4), keys)
    assert np.array_equal(np.asarray(temp0), argmax)
    # top_k = 1 collapses the distribution to the argmax even when hot
    k1 = smp.sample_tokens(logits, jnp.full(4, 2.0),
                           jnp.ones(4, jnp.int32), jnp.ones(4), keys)
    assert np.array_equal(np.asarray(k1), argmax)
    # a vanishing top_p keeps only the top token
    p0 = smp.sample_tokens(logits, jnp.full(4, 2.0),
                           jnp.zeros(4, jnp.int32), jnp.full(4, 1e-6), keys)
    assert np.array_equal(np.asarray(p0), argmax)
    # top_k bounds the support of actual sampling
    order = np.asarray(jnp.argsort(logits, axis=-1)[:, ::-1])
    k3 = smp.sample_tokens(logits, jnp.full(4, 5.0),
                           jnp.full(4, 3, jnp.int32), jnp.ones(4), keys)
    for r, t in enumerate(np.asarray(k3)):
        assert t in order[r, :3]


def test_sample_tokens_mixed_rows_independent():
    """A greedy row's output is unaffected by sampled neighbors, and a
    sampled row draws the same token at any batch position (the
    per-row-key property the serve path relies on)."""
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
    keys = smp.row_keys(jnp.full(3, 7, jnp.int32),
                        jnp.array([0, 1, 2], jnp.int32),
                        jnp.zeros(3, jnp.int32))
    temps = jnp.array([0.0, 1.0, 0.0])
    out = np.asarray(smp.sample_tokens(
        logits, temps, jnp.zeros(3, jnp.int32), jnp.ones(3), keys))
    assert out[0] == int(jnp.argmax(logits[0]))
    assert out[2] == int(jnp.argmax(logits[2]))
    solo = np.asarray(smp.sample_tokens(
        logits[1:2], jnp.ones(1), jnp.zeros(1, jnp.int32), jnp.ones(1),
        smp.row_keys(jnp.full(1, 7, jnp.int32), jnp.ones(1, jnp.int32),
                     jnp.zeros(1, jnp.int32))))
    assert out[1] == solo[0]


# ------------------------------------------------- unit: stop oracle --

def test_match_stop_host_semantics():
    toks = [5, 3, 9, 3, 9, 2]
    assert smp.match_stop_host(toks, None, (), None) is None
    assert smp.match_stop_host(toks, 2, (), None) == 6
    assert smp.match_stop_host(toks, 9, (), None) == 3       # first hit
    # inclusive multi-token match
    assert smp.match_stop_host(toks, None, ((3, 9),), None) == 3
    assert smp.match_stop_host(toks, None, ((9, 3, 9),), None) == 5
    # max_tokens is a stop like any other; earliest criterion wins
    assert smp.match_stop_host(toks, None, (), 4) == 4
    assert smp.match_stop_host(toks, 3, ((5,),), 4) == 1
    # a stop longer than the stream so far never fires
    assert smp.match_stop_host([3], None, ((9, 3),), None) is None


def test_finished_mask_counter_guard_ignores_stale_ring():
    """A stop sequence fully present in the ring but longer than this
    request's own emissions (counter + 1) must not fire — that content
    belongs to the row's previous occupant."""
    recent = jnp.asarray([[4, 5, 6]], jnp.int32)
    stop = jnp.asarray(smp.pack_stop_seqs(((4, 5, 6),), 1, 3))[None]
    meta = {"counter": jnp.array([1], jnp.int32),      # only 2 own tokens
            "eos": jnp.array([-1], jnp.int32),
            "max_tokens": jnp.array([0], jnp.int32)}
    toks = jnp.array([6], jnp.int32)
    assert int(smp.finished_mask(toks, recent, meta, stop)[0]) == 0
    meta["counter"] = jnp.array([2], jnp.int32)        # now it's all ours
    assert int(smp.finished_mask(toks, recent, meta, stop)[0]) == 1


# ------------------------------------------------- serve: identity --

def test_temperature_zero_serve_bit_identical_to_greedy(engine):
    prompts = _prompts(engine.cfg.vocab_size)
    greedy = engine.serve(prompts, SamplingParams(max_tokens=6))
    # temperature=0 with sampling knobs set still reduces to argmax
    t0 = engine.serve(prompts, SamplingParams(
        max_tokens=6, temperature=0.0, top_k=5, top_p=0.5, seed=11))
    for i, (a, b) in enumerate(zip(greedy.outputs, t0.outputs)):
        np.testing.assert_array_equal(b, a, err_msg=f"request {i}")


def test_mixed_batch_temp0_rows_match_greedy(engine):
    """Greedy rows inside a mixed sampled batch (the fused sample-branch
    step, do_sample=True) stay bit-identical to the greedy-only serve."""
    prompts = _prompts(engine.cfg.vocab_size, seed=2)
    greedy = engine.serve(prompts, SamplingParams(max_tokens=6))
    reqs = [Request(tokens=p,
                    temperature=0.0 if i % 2 == 0 else 0.8,
                    top_k=10, top_p=0.9, seed=5)
            for i, p in enumerate(prompts)]
    mixed = engine.serve(reqs, SamplingParams(max_tokens=6))
    changed = 0
    for i, (a, b) in enumerate(zip(greedy.outputs, mixed.outputs)):
        if i % 2 == 0:
            np.testing.assert_array_equal(
                b, a, err_msg=f"greedy row {i} perturbed by sampled batch")
        else:
            changed += not np.array_equal(a, b)
    assert changed > 0, "sampling never diverged from greedy (degenerate)"


def test_seeded_serve_reproducible_and_seed_sensitive(engine):
    prompts = _prompts(engine.cfg.vocab_size, seed=3)
    a = engine.serve(prompts, SAMPLED)
    b = engine.serve(prompts, SAMPLED)
    for i, (x, y) in enumerate(zip(a.outputs, b.outputs)):
        np.testing.assert_array_equal(y, x, err_msg=f"request {i}")
    other = engine.serve(prompts, dataclasses.replace(SAMPLED, seed=8))
    assert any(not np.array_equal(x, y)
               for x, y in zip(a.outputs, other.outputs))


def test_generate_matches_serve_under_shared_seed(engine):
    """The rectangular generate() path and the continuous-batching serve
    path derive identical keys (rid = batch row = submission order), so
    a seeded sampled run agrees token-for-token."""
    prompts = _prompts(engine.cfg.vocab_size, seed=4, lens=(6, 6, 6))
    g = engine.generate(np.stack(prompts), SAMPLED)
    s = engine.serve(prompts, SAMPLED)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(
            np.asarray(s.outputs[i]), g.tokens[i],
            err_msg=f"request {i}: generate != serve")


def test_prefix_cache_on_off_sampled_identity(base):
    """Seeded sampled outputs are invariant to prefix-cache hits — keys
    depend on the emission counter, not on how much prefill was skipped.
    The cache must actually engage for the test to mean anything."""
    cfg, params = base
    eng = InferenceEngine(cfg, params, max_batch=3, block_size=4,
                          chunk_tokens=8)
    rng = np.random.default_rng(5)
    prefix = rng.integers(1, cfg.vocab_size, size=12).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(
        1, cfg.vocab_size, size=2 + i % 4).astype(np.int32)])
        for i in range(5)]
    off = eng.serve(prompts, SAMPLED, prefix_cache=False)
    on = eng.serve(prompts, SAMPLED, prefix_cache=True)
    assert on.cache_hit_blocks > 0
    for i, (a, b) in enumerate(zip(off.outputs, on.outputs)):
        np.testing.assert_array_equal(b, a, err_msg=f"request {i}")


# ----------------------------------------------------- serve: stops --

def test_stop_truncation_matches_host_oracle(engine):
    """Device-side stop truncation == the numpy oracle applied to the
    unstopped stream, for eos and multi-token stop sequences, on both
    greedy and sampled rows. Counter-based keys make the sampled stream
    itself invariant to the stop config, so the oracle diff is exact."""
    prompts = _prompts(engine.cfg.vocab_size, seed=6)
    for sp in (SamplingParams(max_tokens=10),
               dataclasses.replace(SAMPLED, max_tokens=10)):
        full = engine.serve(prompts, sp)
        stream = [np.asarray(o) for o in full.outputs]
        eos = int(stream[0][1])
        stops = ((int(stream[1][2]), int(stream[1][3])),
                 (int(stream[2][0]),))
        sp_stop = dataclasses.replace(sp, eos_id=eos, stop=stops)
        res = engine.serve(prompts, sp_stop)
        hit = 0
        for i, out in enumerate(res.outputs):
            keep = smp.match_stop_host(stream[i], eos, stops, 10)
            assert keep is not None
            hit += keep < 10
            np.testing.assert_array_equal(
                np.asarray(out), stream[i][:keep],
                err_msg=f"request {i}: device stop != oracle")
        assert hit > 0, "no row actually stopped early (degenerate pick)"
        assert res.stopped_early == hit


def test_per_request_stop_overrides(engine):
    """Request-level eos/stop fields override the call-level params, and
    rows finishing early free their slots for waiting requests."""
    prompts = _prompts(engine.cfg.vocab_size, seed=7, lens=(5, 7, 4, 9))
    full = engine.serve(prompts, SamplingParams(max_tokens=8))
    s0 = np.asarray(full.outputs[0])
    eos0 = int(s0[1])
    stop2 = ((int(np.asarray(full.outputs[2])[0]),),)
    reqs = [Request(tokens=prompts[0], eos_id=eos0),
            Request(tokens=prompts[1]),
            Request(tokens=prompts[2], stop=stop2),
            Request(tokens=prompts[3])]
    res = engine.serve(reqs, SamplingParams(max_tokens=8))
    keep0 = smp.match_stop_host(s0, eos0, (), 8)
    assert keep0 < 8 and len(res.outputs[0]) == keep0
    assert len(res.outputs[2]) == 1
    np.testing.assert_array_equal(res.outputs[1], full.outputs[1])
    np.testing.assert_array_equal(res.outputs[3], full.outputs[3])
    assert res.stopped_early == 2


# ----------------------------------------------- serve: speculation --

def test_mixed_greedy_sampled_with_speculation(base):
    """Speculation composes with sampling: greedy rows keep drafting
    (token-identical to non-speculative serve), sampled rows are never
    drafted but sample the identical stream off the verify logits."""
    cfg, _ = base
    plan = CompressionConfig(method="itera", weight_wl=8, rank_fraction=0.75)
    eng = InferenceEngine.build(cfg, plan, max_batch=3, block_size=4,
                                chunk_tokens=8,
                                speculate=DraftSpec(k=3, rank_fraction=0.7))
    prompts = _prompts(cfg.vocab_size, seed=8)
    reqs = lambda: [Request(tokens=p,                       # noqa: E731
                            temperature=0.0 if i % 2 else 0.9,
                            top_k=15, top_p=0.95, seed=13)
                    for i, p in enumerate(prompts)]
    sp = SamplingParams(max_tokens=6)
    plain = eng.serve(reqs(), sp, speculate=False)
    spec = eng.serve(reqs(), sp)
    assert spec.spec_rounds > 0 and spec.drafted > 0
    for i, (a, b) in enumerate(zip(plain.outputs, spec.outputs)):
        np.testing.assert_array_equal(
            b, a, err_msg=f"request {i}: speculative != plain")


def test_speculative_stop_sequences_match_oracle(base):
    """The speculative loop's host-side stop matching truncates exactly
    like the fused device path (shared oracle semantics)."""
    cfg, _ = base
    plan = CompressionConfig(method="itera", weight_wl=8, rank_fraction=0.75)
    eng = InferenceEngine.build(cfg, plan, max_batch=3, block_size=4,
                                chunk_tokens=8,
                                speculate=DraftSpec(k=3, rank_fraction=0.7))
    prompts = _prompts(cfg.vocab_size, seed=9)
    full = eng.serve(prompts, SamplingParams(max_tokens=8))
    stream = [np.asarray(o) for o in full.outputs]
    eos = int(stream[0][1])
    sp = SamplingParams(max_tokens=8, eos_id=eos)
    res = eng.serve(prompts, sp)
    assert res.spec_rounds > 0
    for i, out in enumerate(res.outputs):
        keep = smp.match_stop_host(stream[i], eos, (), 8)
        np.testing.assert_array_equal(np.asarray(out), stream[i][:keep],
                                      err_msg=f"request {i}")


# -------------------------------------------------------- streaming --

def test_on_token_event_stream(engine):
    prompts = _prompts(engine.cfg.vocab_size, seed=10, lens=(5, 9, 3))
    events = []
    res = engine.serve(prompts, SAMPLED, on_token=events.append)
    by_rid = {}
    for e in events:
        assert isinstance(e, TokenEvent)
        by_rid.setdefault(e.rid, []).append(e)
    assert sorted(by_rid) == [0, 1, 2]
    for rid, evs in by_rid.items():
        assert [e.index for e in evs] == list(range(len(evs)))
        np.testing.assert_array_equal(
            [e.token for e in evs], np.asarray(res.outputs[rid]),
            err_msg=f"rid {rid}: streamed tokens != outputs")
        assert [e.final for e in evs] == \
            [False] * (len(evs) - 1) + [True]
        assert all(b.time >= a.time for a, b in zip(evs, evs[1:]))


def test_serve_stream_async_front_door(engine):
    prompts = _prompts(engine.cfg.vocab_size, seed=11, lens=(4, 7))

    async def drive():
        events, result = [], None
        async for item in serve_stream(engine, prompts, SAMPLED):
            if isinstance(item, TokenEvent):
                assert result is None, "event after final result"
                events.append(item)
            else:
                result = item
        return events, result

    events, res = asyncio.run(drive())
    assert res is not None and len(res.outputs) == 2
    assert len(events) == sum(len(o) for o in res.outputs)
    finals = [e for e in events if e.final]
    assert sorted(e.rid for e in finals) == [0, 1]


# ------------------------------------------------------ SLO metrics --

def test_slo_metrics_consistent(engine):
    prompts = _prompts(engine.cfg.vocab_size, seed=12)
    full = engine.serve(prompts, SamplingParams(max_tokens=8))
    eos = int(np.asarray(full.outputs[0])[1])
    res = engine.serve(prompts, SamplingParams(max_tokens=8, eos_id=eos))
    n = len(prompts)
    assert len(res.queue_times) == n and len(res.finish_times) == n
    assert all(t >= 0.0 for t in res.queue_times)
    assert all(f > 0.0 for f in res.finish_times)
    assert res.queue_p95 >= res.queue_p50 >= 0.0
    assert res.stopped_early >= 1
    # goodput is monotone in the deadline and saturates at full
    # throughput once every request makes it
    deadlines = [0.0, max(res.finish_times) / 2, max(res.finish_times) + 1]
    gp = [res.goodput(d) for d in deadlines]
    assert gp == sorted(gp) and gp[0] == 0.0
    assert gp[-1] == pytest.approx(res.tokens_per_second)
    assert res.slo_attainment(1e9, 1e9) == 1.0
    assert 0.0 <= res.slo_attainment(
        max(res.finish_times) / 2, 1e-9) <= 1.0


# --------------------------------------------------- hardware model --

def test_sampling_point_pricing():
    p = tpu_model.sampling_point(batch=8, vocab=32000)
    g = tpu_model.sampling_point(batch=8, vocab=32000, sampled_frac=0.0)
    assert g.overhead_vs_greedy == 1.0
    assert p.overhead_vs_greedy > 1.0
    # the fused path beats the PCIe logits round-trip by a wide margin
    assert p.speedup_vs_host > 10.0
    prev = None
    for v in (1024, 8192, 32000, 128000):
        pt = tpu_model.sampling_point(batch=8, vocab=v)
        if prev is not None:
            assert pt.host_s > prev.host_s
            assert pt.fused_s > prev.fused_s
        assert pt.speedup_vs_host > 10.0
        prev = pt
    # sampled_frac interpolates between argmax-only and full-sort cost
    half = tpu_model.sampling_point(batch=8, vocab=32000, sampled_frac=0.5)
    assert g.fused_s < half.fused_s < p.fused_s
    for bad in (dict(batch=0, vocab=8), dict(batch=1, vocab=1),
                dict(batch=1, vocab=8, sampled_frac=-0.1)):
        with pytest.raises(ValueError):
            tpu_model.sampling_point(**bad)


# --------------------------------------- subprocess: the full matrix --

def test_seeded_determinism_matrix():
    """fp32/bf16 x bf16/int8-KV x mesh 1/2 x prefix-cache on/off: a
    seeded sampled serve emits the SAME tokens in all 16 cells (and on a
    repeat run), because keys are a pure function of (seed, rid,
    counter) — none of model dtype's logits permutations, KV rounding,
    TP sharding, or skipped prefill enter the derivation. Within a
    (dtype, kv) pair every mesh/cache variant is token-identical; across
    dtypes the logits differ so streams may too."""
    out = run_sub("""
        import dataclasses
        import numpy as np
        import jax
        from repro.api.engine import InferenceEngine, SamplingParams
        from repro.configs import get_config
        from repro.launch.mesh import make_serving_mesh
        from repro.models import transformer as tfm

        rng = np.random.default_rng(0)
        sp = SamplingParams(max_tokens=5, temperature=0.8, top_k=20,
                            top_p=0.9, seed=7)
        cfg0 = get_config("opus-mt", smoke=True)
        prefix = rng.integers(1, cfg0.vocab_size, size=12).astype(np.int32)
        prompts = [np.concatenate([prefix, rng.integers(
            1, cfg0.vocab_size, size=2 + i % 4).astype(np.int32)])
            for i in range(5)]
        for dtype in ("float32", "bfloat16"):
            for kv_bits in (16, 8):
                cfg = dataclasses.replace(cfg0, dtype=dtype,
                                          kv_cache_bits=kv_bits)
                params = tfm.init_params(jax.random.PRNGKey(0), cfg)
                ref = None
                for tp in (1, 2):
                    eng = InferenceEngine.build(
                        cfg, params=params,
                        mesh=make_serving_mesh(tp) if tp > 1 else None,
                        max_batch=3, block_size=4, chunk_tokens=8)
                    for cache in (False, True):
                        r = eng.serve(prompts, sp, prefix_cache=cache)
                        if cache:
                            assert r.cache_hit_blocks > 0, (dtype, kv_bits)
                        if ref is None:
                            ref = r.outputs
                            rep = eng.serve(prompts, sp, prefix_cache=cache)
                            for i, (a, b) in enumerate(
                                    zip(ref, rep.outputs)):
                                assert np.array_equal(a, b), (
                                    f"repeat drift request {i}")
                        else:
                            for i, (a, b) in enumerate(
                                    zip(ref, r.outputs)):
                                assert np.array_equal(a, b), (
                                    f"{dtype}/kv{kv_bits}/tp{tp}/"
                                    f"cache={cache} request {i}: "
                                    f"{b} != {a}")
                        print(f"OK {dtype} kv{kv_bits} tp{tp} "
                              f"cache={int(cache)}")
        print("SAMPLING_MATRIX_DONE")
        """)
    assert "SAMPLING_MATRIX_DONE" in out
    assert out.count("OK ") == 16
