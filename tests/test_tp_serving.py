"""Tensor-parallel sharded serving tests.

The contract under test: `engine.build(mesh=make_serving_mesh(N))`
serves greedy outputs TOKEN-IDENTICAL to the single-device engine —
across dtypes, KV-cache precisions, mesh sizes, and with speculative
decoding — because the shard_map step computes the same math, just
split over heads/hidden columns with one psum per layer boundary.

Mesh cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests in this
process must keep seeing 1 device); the whole dtype x kv x mesh matrix
runs in ONE subprocess to amortize import + compile cost. Pure-rule
cases (TP spec rules, geometry errors) run in-process; the sampled-
serving TP identity case rides its own subprocess.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ------------------------------------------------------------- identity --

def test_tp_serve_token_identity_matrix():
    """Greedy serve on forced 2- and 4-device meshes (and the degenerate
    1-device mesh, which runs the same shard_map path) is token-identical
    to the single-device engine for fp32/bf16 models with bf16 and int8
    KV, on a mixed prefill/decode batch (ragged prompts, chunked prefill
    forced by a small token budget)."""
    out = run_sub("""
        import dataclasses
        import numpy as np
        import jax
        from repro.api.engine import InferenceEngine, SamplingParams
        from repro.configs import get_config
        from repro.launch.mesh import make_serving_mesh
        from repro.models import transformer as tfm

        rng = np.random.default_rng(0)
        sp = SamplingParams(max_tokens=6)
        for dtype in ("float32", "bfloat16"):
            for kv_bits in (16, 8):
                cfg = dataclasses.replace(
                    get_config("opus-mt", smoke=True),
                    dtype=dtype, kv_cache_bits=kv_bits)
                params = tfm.init_params(jax.random.PRNGKey(0), cfg)
                prompts = [rng.integers(1, cfg.vocab_size, size=n)
                           .astype(np.int32) for n in (5, 11, 3, 16, 8)]
                solo = InferenceEngine.build(
                    cfg, params=params, max_batch=3, block_size=4,
                    chunk_tokens=8)
                r0 = solo.serve(prompts, sp)
                for tp in (1, 2, 4):
                    eng = InferenceEngine.build(
                        cfg, params=params, mesh=make_serving_mesh(tp),
                        max_batch=3, block_size=4, chunk_tokens=8)
                    r1 = eng.serve(prompts, sp)
                    # small budget + more requests than rows => chunked
                    # prefill overlapping decode, the regime under test
                    assert r1.mixed_steps > 0, (dtype, kv_bits, tp)
                    for i, (a, b) in enumerate(zip(r0.outputs, r1.outputs)):
                        assert np.array_equal(a, b), (
                            f"{dtype}/kv{kv_bits}/tp{tp} request {i}: "
                            f"{b} != {a}")
                    print(f"OK {dtype} kv{kv_bits} tp{tp}")
        print("MATRIX_DONE")
        """)
    assert "MATRIX_DONE" in out
    assert out.count("OK ") == 12          # 2 dtypes x 2 kv x 3 meshes


def test_tp_speculative_identity():
    """Speculative decoding under TP: the same truncated-cascade draft +
    verify + accept round, shard-mapped, emits tokens identical to both
    the single-device speculative engine and plain non-speculative serve.
    Compression is restricted to N-sliced sites (wq/wk/wv/gate/up) whose
    TP slice is bit-exact — see launch.sharding._TP_RULES."""
    out = run_sub("""
        import numpy as np
        import jax
        from repro.api.engine import InferenceEngine, SamplingParams
        from repro.api.plan import CompressionPlan
        from repro.configs import get_config
        from repro.core.compress import CompressionConfig, shape_spectra
        from repro.launch.mesh import make_serving_mesh
        from repro.models import transformer as tfm
        from repro.runtime.speculation import DraftSpec

        cfg = get_config("opus-mt", smoke=True)
        params = shape_spectra(
            tfm.init_params(jax.random.PRNGKey(0), cfg), alpha=3.0)
        cc = CompressionConfig(method="svd", weight_wl=8,
                               rank_fraction=0.75,
                               include=r"/(wq|wk|wv|gate|up)$")
        plan = CompressionPlan.from_config(params, cc)
        spec = DraftSpec(k=4, rank_fraction=0.25)
        rng = np.random.default_rng(2)
        prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (5, 11, 3, 16, 8)]
        sp = SamplingParams(max_tokens=8)
        kw = dict(params=params, max_batch=3, block_size=4, chunk_tokens=8)
        r_plain = InferenceEngine.build(cfg, plan, **kw).serve(prompts, sp)
        r_solo = InferenceEngine.build(cfg, plan, speculate=spec,
                                       **kw).serve(prompts, sp)
        for tp in (2, 4):
            eng = InferenceEngine.build(cfg, plan, speculate=spec,
                                        mesh=make_serving_mesh(tp), **kw)
            r_tp = eng.serve(prompts, sp)
            assert r_tp.drafted > 0 and r_tp.spec_rounds > 0
            for i in range(len(prompts)):
                assert np.array_equal(r_plain.outputs[i], r_tp.outputs[i])
                assert np.array_equal(r_solo.outputs[i], r_tp.outputs[i])
            print(f"OK tp{tp} accept={r_tp.accept_rate:.2f}")
        print("SPEC_DONE")
        """)
    assert "SPEC_DONE" in out


def test_tp_generate_ragged_identity():
    """engine.generate on a ragged batch routes through serve — the TP
    engine must match there too (the public API most callers use)."""
    run_sub("""
        import numpy as np
        import jax
        from repro.api.engine import InferenceEngine, SamplingParams
        from repro.configs import get_config
        from repro.launch.mesh import make_serving_mesh
        from repro.models import transformer as tfm

        cfg = get_config("opus-mt", smoke=True)
        params = tfm.init_params(jax.random.PRNGKey(3), cfg)
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
                   for n in (7, 13, 4)]
        sp = SamplingParams(max_tokens=5)
        g0 = InferenceEngine.build(cfg, params=params).generate(prompts, sp)
        g1 = InferenceEngine.build(
            cfg, params=params,
            mesh=make_serving_mesh(2)).generate(prompts, sp)
        assert np.array_equal(g0.tokens, g1.tokens)
        """)


# ----------------------------------------------------- geometry / errors --

def test_tp_geometry_divisibility_errors():
    """GQA head counts (and d_ff) that don't divide the mesh raise a
    descriptive error naming the offending ModelConfig field — shard_map
    has no GSPMD padding to hide behind."""
    import dataclasses

    from repro.configs import get_config
    from repro.launch.sharding import check_tp_geometry

    cfg = get_config("opus-mt", smoke=True)
    # smoke geometry: 4 heads, 4 kv heads, d_ff 256 — divides 1/2/4
    for tp in (1, 2, 4):
        check_tp_geometry(cfg, tp)

    gqa = dataclasses.replace(cfg, num_kv_heads=2)
    with pytest.raises(ValueError, match=r"num_kv_heads=2"):
        check_tp_geometry(gqa, 4)
    with pytest.raises(ValueError, match=r"no GSPMD padding"):
        check_tp_geometry(gqa, 4)
    check_tp_geometry(gqa, 2)       # 2 kv heads over 2 shards is fine

    odd = dataclasses.replace(cfg, num_heads=6, num_kv_heads=6)
    with pytest.raises(ValueError, match=r"num_heads=6"):
        check_tp_geometry(odd, 4)

    ssm = dataclasses.replace(cfg, layout="mamba1")
    with pytest.raises(NotImplementedError, match=r"dense"):
        check_tp_geometry(ssm, 2)


def test_tp_spec_rules_unit():
    """TP param slicing rules, no mesh needed: N-sites column-sliced,
    K-sites row-sliced with replicated per-output-column scales, LowRankQ
    w1 replicated / w2 column-sliced on N-sites, everything else
    replicated. Leading scan-stack dims stay unsharded."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import tp_spec_for

    z = jnp.zeros
    # dense sites (leading L stack dim)
    assert tp_spec_for("layers/attn/wq", z((2, 64, 64)), 2) == \
        P(None, None, "model")
    assert tp_spec_for("layers/attn/wo", z((2, 64, 64)), 2) == \
        P(None, "model", None)
    assert tp_spec_for("layers/mlp/up", z((2, 64, 256)), 2) == \
        P(None, None, "model")
    assert tp_spec_for("layers/mlp/down", z((2, 256, 64)), 2) == \
        P(None, "model", None)
    # quantized dense: values follow the site, K-site scales replicate
    assert tp_spec_for("layers/attn/wq/values", z((2, 64, 64)), 2) == \
        P(None, None, "model")
    assert tp_spec_for("layers/attn/wq/scale", z((2, 1, 64)), 2) == \
        P(None, None, "model")
    assert tp_spec_for("layers/mlp/down/values", z((2, 256, 64)), 2) == \
        P(None, "model", None)
    assert tp_spec_for("layers/mlp/down/scale", z((2, 1, 64)), 2) == \
        P(None, None, None)
    # low-rank cascade on an N-site: w1 fully replicated, w2 col-sliced,
    # w2's per-rank-row scale replicated
    assert tp_spec_for("layers/attn/wk/w1/values", z((2, 64, 48)), 2) == \
        P(None, None, None)
    assert tp_spec_for("layers/attn/wk/w1/scale", z((2, 1, 48)), 2) == \
        P(None, None, None)
    assert tp_spec_for("layers/attn/wk/w2/values", z((2, 48, 64)), 2) == \
        P(None, None, "model")
    assert tp_spec_for("layers/attn/wk/w2/scale", z((2, 48, 1)), 2) == \
        P(None, None, None)
    # low-rank on a K-site: w1 rows sliced, everything else replicated
    assert tp_spec_for("layers/mlp/down/w1/values", z((2, 256, 48)), 2) == \
        P(None, "model", None)
    assert tp_spec_for("layers/mlp/down/w2/values", z((2, 48, 64)), 2) == \
        P(None, None, None)
    # replicated leaves
    assert tp_spec_for("embed", z((100, 64)), 2) == P(None, None)
    assert tp_spec_for("lm_head", z((64, 100)), 2) == P(None, None)
    assert tp_spec_for("final_norm/gamma", z((64,)), 2) == P(None)
    # tp=1: everything replicated, same code path
    assert tp_spec_for("layers/attn/wq", z((2, 64, 64)), 1) == \
        P(None, None, None)
    # non-divisible slice dim is a hard error naming the path
    with pytest.raises(ValueError, match=r"layers/mlp/up"):
        tp_spec_for("layers/mlp/up", z((2, 64, 250)), 4)


def test_serving_mesh_needs_devices():
    """make_serving_mesh raises with the XLA_FLAGS recipe when the host
    has too few devices (this process sees exactly 1)."""
    from repro.launch.mesh import make_serving_mesh

    with pytest.raises(ValueError, match=r"xla_force_host_platform"):
        make_serving_mesh(4)
    with pytest.raises(ValueError, match=r">= 1"):
        make_serving_mesh(0)
    mesh = make_serving_mesh(1)
    assert mesh.shape["model"] == 1 and mesh.shape["data"] == 1


def test_build_rejects_bad_tp_geometry():
    """engine.build(mesh=...) runs the geometry check up front — a
    non-dividing GQA config fails at build, not mid-serve."""
    run_sub("""
        import dataclasses
        from repro.api.engine import InferenceEngine
        from repro.configs import get_config
        from repro.launch.mesh import make_serving_mesh

        cfg = dataclasses.replace(get_config("opus-mt", smoke=True),
                                  num_kv_heads=2)
        try:
            InferenceEngine.build(cfg, mesh=make_serving_mesh(4))
        except ValueError as e:
            assert "num_kv_heads=2" in str(e), str(e)
        else:
            raise AssertionError("bad GQA geometry built successfully")
        """)


# ---------------------------------------------------------- temperature --

def test_serve_sampled_tp_matches_single_device():
    """Seeded sampled serving is token-identical across TP mesh sizes:
    the residual (hence logits and per-row PRNG keys) is replicated
    after the boundary psums, so every shard samples the same token —
    and the counter-based keys make mesh 1 and mesh 2 draw the same
    stream for the same (seed, rid, counter)."""
    run_sub("""
        import numpy as np
        from repro.api.engine import InferenceEngine, SamplingParams
        from repro.launch.mesh import make_serving_mesh

        prompts = [list(range(1, 8)), list(range(3, 15)), [5, 4, 3]]
        sp = SamplingParams(max_tokens=6, temperature=0.8, top_k=20,
                            top_p=0.9, seed=7)
        ref = InferenceEngine.build("opus-mt", smoke=True).serve(prompts, sp)
        tp = InferenceEngine.build(
            "opus-mt", smoke=True, mesh=make_serving_mesh(2)
        ).serve(prompts, sp)
        for a, b in zip(ref.outputs, tp.outputs):
            assert np.array_equal(a, b), (a, b)
        print("TP_SAMPLED_OK")
        """)
