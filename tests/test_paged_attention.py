"""Pallas paged-attention kernel vs the jnp gather oracle.

The kernel (`kernels/paged_attention.py`) streams only the block-table
entries that hold valid context and dequantizes int8 K/V in VMEM; the
oracle (`span_attention_paged(..., impl="ref")`) gathers the full logical
pool view. These tests pin the contract between them:

  * numerically matching outputs on every valid span position, across
    mixed prefill-chunk + decode + idle spans, GQA, logit soft-capping,
    and bf16/f32/int8 KV pools;
  * trash-block padding and blocks past the valid count are NEVER read by
    the kernel (poisoned-pool proof);
  * token-identical greedy generation through `engine.serve` for both
    KV formats — the acceptance bar of the kernel PR;
  * the bytes-moved model scales with ctx_lens (stream) vs pool capacity
    (gather), strictly favoring the kernel whenever ctx < capacity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import InferenceEngine, SamplingParams
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.kernels import paged_attention as pa
from repro.models import attention as attn
from repro.runtime import kvblocks


def _mk_cfg(**kw):
    base = dict(name="pa-test", layout="dense", num_layers=1, d_model=32,
                num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                dtype="float32", remat=False)
    base.update(kw)
    return ModelConfig(**base)


def _mk_state(cfg, key, *, B=3, W=4, MB=4, bs=4,
              ctx=(5, 0, 9), ql=(3, 0, 1), poison=None):
    """Params, a pre-populated single-layer pool, block tables, and a span
    batch: row 0 = mid-prompt chunk, row 1 = idle, row 2 = decode."""
    ks = jax.random.split(key, 6)
    params = attn.attn_init(ks[0], cfg, jnp.dtype(cfg.dtype))
    nb_pool = 1 + sum(-(-(c + q) // bs) for c, q in zip(ctx, ql))
    pool = {k: v[0] for k, v in kvblocks.init_paged_cache(
        dataclasses.replace(cfg, num_layers=1), nb_pool, bs).items()}
    if "ks" in pool:
        shp = pool["k"].shape
        pool["k"] = jax.random.randint(ks[1], shp, -127, 128).astype(jnp.int8)
        pool["v"] = jax.random.randint(ks[2], shp, -127, 128).astype(jnp.int8)
        pool["ks"] = jax.random.uniform(ks[3], pool["ks"].shape,
                                        jnp.float32, 0.01, 0.1)
        pool["vs"] = jax.random.uniform(ks[4], pool["vs"].shape,
                                        jnp.float32, 0.01, 0.1)
    else:
        dt = pool["k"].dtype
        pool["k"] = jax.random.normal(ks[1], pool["k"].shape, dt)
        pool["v"] = jax.random.normal(ks[2], pool["v"].shape, dt)
    if poison is not None:
        # blocks the kernel must never read: the reserved trash block
        pool["k"] = pool["k"].at[0].set(poison)
        pool["v"] = pool["v"].at[0].set(poison)
    bt = np.zeros((len(ctx), MB), np.int32)
    nxt = 1
    for r, (c, q) in enumerate(zip(ctx, ql)):
        need = -(-(c + q) // bs)
        bt[r, :need] = np.arange(nxt, nxt + need)
        nxt += need
    x = jax.random.normal(ks[5], (B, W, cfg.d_model), jnp.dtype(cfg.dtype))
    return (params, pool, jnp.asarray(bt), jnp.asarray(ctx, jnp.int32),
            jnp.asarray(ql, jnp.int32), x)


def _both(cfg, state):
    params, pool, bt, ctx, ql, x = state
    yr, pr = attn.span_attention_paged(params, x, pool, bt, ctx, ql, cfg,
                                       impl="ref")
    yk, pk = attn.span_attention_paged(params, x, pool, bt, ctx, ql, cfg,
                                       impl="kernel")
    return (yr, pr), (yk, pk)


def _assert_span_close(yr, yk, ql, *, rtol, atol):
    for r in range(yr.shape[0]):
        n = int(ql[r])
        if n:
            np.testing.assert_allclose(
                np.asarray(yr[r, :n], np.float32),
                np.asarray(yk[r, :n], np.float32), rtol=rtol, atol=atol)


# ------------------------------------------------------- kernel vs oracle --
def test_kernel_matches_oracle_f32_mixed_spans():
    """Mixed chunk + idle + decode spans, GQA (Hk < H), fp32: the kernel
    reproduces the gather oracle to fp32 round-off, and both paths write
    the identical scattered pool."""
    cfg = _mk_cfg()
    state = _mk_state(cfg, jax.random.PRNGKey(0))
    (yr, pr), (yk, pk) = _both(cfg, state)
    for k in pr:
        np.testing.assert_array_equal(np.asarray(pr[k]), np.asarray(pk[k]))
    _assert_span_close(yr, yk, state[4], rtol=2e-5, atol=2e-5)


def test_kernel_matches_oracle_int8_kv_and_softcap():
    """int8 KV pool (in-kernel dequant) + Gemma-style logit soft-capping,
    bf16 activations: matches the oracle to bf16 round-off."""
    cfg = _mk_cfg(dtype="bfloat16", kv_cache_bits=8, logit_softcap=30.0)
    state = _mk_state(cfg, jax.random.PRNGKey(1))
    (yr, _), (yk, _) = _both(cfg, state)
    # outputs are O(10) bf16 values (codes up to 127 x scales up to 0.1):
    # atol of one bf16 ulp at that magnitude, since online softmax and the
    # one-shot softmax legitimately round the last bit differently
    _assert_span_close(yr, yk, state[4], rtol=2e-2, atol=1e-1)


def test_kernel_never_reads_trash_or_invalid_blocks():
    """Poison the reserved trash block with huge values: the kernel's
    output must equal the clean-pool output bit for bit — proof the DMA
    walk never touches table padding (the oracle relies on masking
    instead; both must agree on the valid region either way)."""
    cfg = _mk_cfg()
    clean = _mk_state(cfg, jax.random.PRNGKey(2))
    poisoned = _mk_state(cfg, jax.random.PRNGKey(2), poison=1e30)
    params, pool, bt, ctx, ql, x = poisoned
    _, (yk_clean, _) = _both(cfg, clean)
    yk_poison, _ = attn.span_attention_paged(params, x, pool, bt, ctx, ql,
                                             cfg, impl="kernel")
    _assert_span_close(yk_clean, yk_poison, ql, rtol=0, atol=0)


def test_idle_rows_emit_zeros_and_skip_work():
    """q_lens == 0 rows return exactly zero from the kernel (the oracle
    computes garbage there; both are discarded by the caller — zeros just
    prove the kernel skipped the row entirely)."""
    cfg = _mk_cfg()
    params, pool, bt, ctx, ql, x = _mk_state(cfg, jax.random.PRNGKey(3))
    q = jax.random.normal(jax.random.PRNGKey(9), (3, 4, 4, 8), jnp.float32)
    o = pa.paged_attention(q, pool, bt, ctx, ql, interpret=True)
    assert int(ql[1]) == 0
    np.testing.assert_array_equal(np.asarray(o[1]), np.zeros_like(o[1]))


def test_valid_block_counts():
    ctx = jnp.asarray([0, 5, 16, 9, 100], jnp.int32)
    ql = jnp.asarray([4, 3, 1, 0, 1], jnp.int32)
    nb = kvblocks.valid_block_counts(ctx, ql, 4, 8)
    # idle rows count zero; others ceil((ctx+q)/bs), clamped to the table
    np.testing.assert_array_equal(np.asarray(nb), [1, 2, 5, 0, 8])


# --------------------------------------------------------- through serve --
@pytest.mark.parametrize("kv_bits", [16, 8])
def test_serve_token_identical_kernel_vs_oracle(kv_bits):
    """The acceptance bar: greedy engine.serve emits identical tokens
    whether serving attention runs the Pallas kernel or the jnp gather
    oracle — mixed ragged prompts (chunked prefill + decode + idle rows),
    GQA, fp32 model, both KV formats."""
    cfg = dataclasses.replace(get_config("opus-mt", smoke=True),
                              num_kv_heads=2, kv_cache_bits=kv_bits)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (5, 11, 8)]
    sp = SamplingParams(max_tokens=6)
    outs = {}
    for impl in ("ref", "kernel"):
        eng = InferenceEngine.build(cfg, None, paged_attn=impl)
        res = eng.serve(prompts, sp, max_batch=4, block_size=4)
        outs[impl] = np.stack(res.outputs)
    np.testing.assert_array_equal(outs["ref"], outs["kernel"])


@pytest.mark.parametrize("kv_bits", [16, 8])
def test_serve_token_identical_bf16(kv_bits):
    """Same bar on a bfloat16 GQA model: the kernel's online softmax must
    not flip greedy tokens even at bf16 logits."""
    cfg = _mk_cfg(name="pa-bf16", num_layers=2, d_model=64, d_ff=128,
                  vocab_size=256, dtype="bfloat16", kv_cache_bits=kv_bits)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (7, 3, 12)]
    sp = SamplingParams(max_tokens=8)
    outs = {}
    for impl in ("ref", "kernel"):
        eng = InferenceEngine.build(cfg, None, paged_attn=impl)
        outs[impl] = np.stack(
            eng.serve(prompts, sp, max_batch=4, block_size=4).outputs)
    np.testing.assert_array_equal(outs["ref"], outs["kernel"])


def test_paged_attn_impl_validation_and_auto():
    cfg = _mk_cfg(paged_attn_impl="bogus")
    with pytest.raises(ValueError, match="paged_attn_impl"):
        attn._paged_impl(cfg)
    auto = attn._paged_impl(_mk_cfg())
    assert auto == ("kernel" if jax.default_backend() == "tpu" else "ref")


# ------------------------------------------------------------ byte model --
def test_stream_bytes_scale_with_ctx_not_pool():
    """The bytes-moved claim of the PR: the kernel's modeled traffic
    grows with ctx_lens and stays strictly below the gather path whenever
    ctx < pool capacity; the gather path is flat in ctx."""
    bs, hk, dh, mb, b = 16, 4, 64, 32, 4
    short = pa.stream_hbm_bytes([16, 8, 0, 24], [8, 1, 0, 8], bs, hk, dh)
    long_ = pa.stream_hbm_bytes([400, 290, 0, 500], [8, 1, 0, 8], bs, hk, dh)
    gather = pa.gather_hbm_bytes(b, mb, bs, hk, dh, w=8)
    # gather_hbm_bytes takes no ctx argument at all — flat in context by
    # construction — so the property under test is the stream ordering:
    assert short < long_ < gather
    # int8 KV: gather additionally round-trips the dense dequantized
    # view, so the stream/gather gap widens
    s8 = pa.stream_hbm_bytes([400, 290, 0, 500], [8, 1, 0, 8], bs, hk, dh,
                             kv_bits=8)
    g8 = pa.gather_hbm_bytes(b, mb, bs, hk, dh, kv_bits=8, w=8)
    assert s8 / g8 < long_ / gather
    # idle rows stream exactly one (trash) block, never their stale ctx
    assert (pa.stream_hbm_bytes([100], [0], bs, hk, dh)
            == bs * pa.kv_bytes_per_token(hk, dh, 16))


def test_tpu_model_prices_paged_attention():
    from repro.hw import tpu_model as tm

    ctx, ql = [400, 290, 0, 500], [8, 1, 0, 8]
    sp = tm.paged_attention_point(ctx, ql, num_kv_heads=4, head_dim=64,
                                  num_heads=8, block_size=16, max_blocks=32)
    gp = tm.paged_attention_point(ctx, ql, num_kv_heads=4, head_dim=64,
                                  num_heads=8, block_size=16, max_blocks=32,
                                  streamed=False)
    assert sp.kind == "pattn_stream" and gp.kind == "pattn_gather"
    assert sp.hbm_bytes < gp.hbm_bytes
    assert sp.latency_s < gp.latency_s          # decode attn is bw-bound
    assert sp.memory_s >= sp.compute_s
