"""True sub-8-bit residency, end to end: W4 plans materialize packed HBM
storage (halved device bytes, asserted against `.nbytes`), packed and
carrier engines generate identical tokens through `engine.serve`, the
honest accounting reports what is actually resident, and checkpoints
round-trip the packed layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CompressionPlan, InferenceEngine, SamplingParams
from repro.configs import get_config
from repro.core.compress import CompressionConfig, compress_params
from repro.core.itera import LowRankQ
from repro.core.quant import QuantizedTensor
from repro.models import init_params


@pytest.fixture(scope="module")
def smoke():
    cfg = get_config("opus-mt", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _quant_nodes(tree):
    out = []

    def visit(leaf):
        if isinstance(leaf, LowRankQ):
            out.extend([leaf.w1, leaf.w2])
        elif isinstance(leaf, QuantizedTensor):
            out.append(leaf)
        return leaf

    jax.tree_util.tree_map(
        visit, tree,
        is_leaf=lambda x: isinstance(x, (LowRankQ, QuantizedTensor)))
    return out


# -------------------------------------------------------- material packing --
def test_w4_plan_materially_packed(smoke):
    """The acceptance bar: a W4 plan's device arrays really occupy
    wl/8 · K · N bytes (+ fp32 scales) — packed nibbles, not an int8
    carrier with pretend accounting. Packing is gated per axis by
    `quant.packed_pad_ok`: a last dim whose packed padding would exceed
    its carrier's (e.g. the smoke model's 64-wide heads) stays an int8
    carrier, because the kernels would stream the same padded bytes for
    double the padded MXU work."""
    from repro.core.quant import packed_pad_ok

    _, params = smoke
    plan = CompressionPlan.uniform(params, method="quant", weight_wl=4)
    assert plan.pack
    cp, rep = compress_params(params, plan)
    nodes = _quant_nodes(cp)
    assert nodes, "smoke model produced no quantized nodes"
    n_packed = 0
    for q in nodes:
        n_codes = int(np.prod(q.shape))
        if packed_pad_ok(q.shape[-1]):
            assert q.packed, "W4 pad-ok weight left unpacked"
            assert q.values.nbytes == n_codes // 2  # wl/8 · K · N, exactly
            n_packed += 1
        else:
            assert not q.packed, "pad-inflating axis must stay carrier"
            assert q.values.nbytes == n_codes
        assert q.values.nbytes + q.scale.nbytes == q.storage_bits() // 8
    assert n_packed, "smoke model has no pad-ok W4 axis — test is vacuous"
    assert any(l.packed for l in rep.layers)
    assert (sum(l.packed for l in rep.layers)
            == sum(packed_pad_ok(q.shape[-1]) for q in nodes))
    # carrier build of the same plan doubles the PACKED nodes' bytes and
    # leaves the demoted ones alone
    cpc, _ = compress_params(params, plan.replace(pack=False))
    for q, qc in zip(_quant_nodes(cp), _quant_nodes(cpc)):
        assert qc.values.nbytes == (q.values.nbytes * 2 if q.packed
                                    else q.values.nbytes)


def test_w6_stays_carrier_and_is_labeled(smoke):
    """W6 has no byte-aligned packing: it stays int8-resident and the
    report says so — packed=False, bits charged at 8/code."""
    _, params = smoke
    cp, rep = compress_params(
        params, CompressionPlan.uniform(params, method="quant", weight_wl=6))
    for q in _quant_nodes(cp):
        assert not q.packed
        assert q.values.nbytes == int(np.prod(q.shape))
    assert not any(l.packed for l in rep.layers)
    for l in rep.layers:
        mult, k, n = (l.shape if len(l.shape) == 3 else (1, *l.shape))
        assert l.bits == (8 * k * n + 32 * n) * mult


def test_itera_w4_factors_packed(smoke):
    """ITERA factors pack per axis: W1 along R, W2 along N — each only
    when the axis is even AND pad-ok. The smoke model's rank-32 W1s and
    64-wide W2s stay carriers while the 256/512-wide W2s pack; a
    512-wide layer with rank 256 packs both factors."""
    from repro.core.quant import packed_pad_ok

    _, params = smoke
    cp, _ = compress_params(
        params, CompressionPlan.uniform(params, method="itera", weight_wl=4,
                                        rank_fraction=0.5))
    nodes = _quant_nodes(cp)
    assert nodes and all(q.act_wl == 8 for q in nodes)
    for q in nodes:
        assert q.packed == (q.shape[-1] % 2 == 0
                            and packed_pad_ok(q.shape[-1]))
    assert any(q.packed for q in nodes) and not all(q.packed for q in nodes)
    big = {"proj": {"w": jnp.asarray(
        np.random.default_rng(0).normal(size=(512, 512)), jnp.float32)}}
    cpb, _ = compress_params(
        big, CompressionPlan.uniform(big, method="itera", weight_wl=4,
                                     rank_fraction=0.5))
    (lr,) = [l for l in jax.tree_util.tree_leaves(
        cpb, is_leaf=lambda x: isinstance(x, LowRankQ))
        if isinstance(l, LowRankQ)]
    assert lr.w1.shape == (512, 256) and lr.w1.packed   # R=256: pad-ok
    assert lr.w2.shape == (256, 512) and lr.w2.packed   # N=512: pad-ok


# --------------------------------------------------------- token identity --
def test_packed_vs_carrier_serve_token_identical(smoke):
    """Nibble unpack is exact, so packed and carrier engines must emit
    the same tokens through the in-flight batching serve loop (ragged
    prompts, chunked prefill) and through rectangular generate."""
    cfg, params = smoke
    plan = CompressionPlan.uniform(params, method="quant", weight_wl=4,
                                   label="w4")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (5, 11, 8)]
    sp = SamplingParams(max_tokens=6)
    outs = {}
    for pack in (True, False):
        eng = InferenceEngine.build(cfg, plan.replace(pack=pack),
                                    params=params)
        res = eng.serve(prompts, sp)
        outs[pack] = (np.stack(res.outputs),
                      eng.generate(np.stack([prompts[0], prompts[0]]),
                                   sp).tokens,
                      eng.weight_hbm_bytes())
    np.testing.assert_array_equal(outs[True][0], outs[False][0])
    np.testing.assert_array_equal(outs[True][1], outs[False][1])
    assert outs[True][2] < outs[False][2]   # and the packed engine is smaller


def test_act_wl_plan_changes_tokens(smoke):
    """act_wl is honored at runtime: an A4 engine's logits diverge from
    the A8 engine's (same weights, same prompts)."""
    cfg, params = smoke
    base = CompressionPlan.uniform(params, method="quant", weight_wl=8)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 10), 1,
                              cfg.vocab_size)
    from repro.models.transformer import forward

    cp8, _ = compress_params(params, base)
    cp4, _ = compress_params(params, base.replace(act_wl=4, label="a4"))
    nodes = _quant_nodes(cp4)
    assert nodes and all(q.act_wl == 4 for q in nodes)
    h8, _ = forward(cp8, toks, cfg)
    h4, _ = forward(cp4, toks, cfg)
    assert bool(jnp.isfinite(h4).all())
    assert not np.allclose(np.asarray(h8), np.asarray(h4))


# ---------------------------------------------------------- serialization --
def test_plan_pack_flag_roundtrips(smoke):
    _, params = smoke
    plan = CompressionPlan.uniform(params, method="quant", weight_wl=4)
    assert CompressionPlan.loads(plan.dumps()).pack is True
    off = plan.replace(pack=False)
    assert CompressionPlan.loads(off.dumps()).pack is False
    # legacy JSON without the key defaults to packed
    d = plan.to_dict()
    d.pop("pack")
    assert CompressionPlan.from_dict(d).pack is True


def test_ckpt_roundtrip_packed(tmp_path, smoke):
    """A packed compressed tree survives save/restore bit-exactly, and
    restoring into a tree with the wrong residency layout is refused."""
    from repro.checkpoint import ckpt

    _, params = smoke
    # quant: the smoke model's 256/512-wide axes really pack, so the
    # packed-vs-carrier layout refusal below has a layout to differ on
    plan = CompressionPlan.uniform(params, method="quant", weight_wl=4)
    cp, _ = compress_params(params, plan)
    ckpt.save(str(tmp_path), 7, cp)
    restored, step = ckpt.restore(str(tmp_path), cp)
    assert step == 7
    la, lb = jax.tree_util.tree_leaves(cp), jax.tree_util.tree_leaves(restored)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    carrier, _ = compress_params(params, plan.replace(pack=False))
    with pytest.raises(ValueError, match="quant layout"):
        ckpt.restore(str(tmp_path), carrier)
    # act_wl is runtime-only aux — it never changes the stored arrays, so
    # restoring into an A4 tree of the same layout is legitimate
    a4, _ = compress_params(params, plan.replace(act_wl=4))
    restored_a4, _ = ckpt.restore(str(tmp_path), a4)
    for a, b in zip(jax.tree_util.tree_leaves(cp),
                    jax.tree_util.tree_leaves(restored_a4)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- honest accounting --
def test_skipped_params_counted_at_itemsize():
    """A bf16 leaf left uncompressed costs 16 bits/param in the totals,
    not an assumed 32."""
    params = {
        "proj": {"w": jnp.ones((64, 64), jnp.float32)},
        "embed": jnp.ones((128, 32), jnp.bfloat16),
    }
    cp, rep = compress_params(
        params, CompressionConfig(method="quant", weight_wl=8))
    assert rep.skipped_params == 128 * 32
    assert rep.skipped_bits == 128 * 32 * 16
    assert rep.total_bits == sum(l.bits for l in rep.layers) + 128 * 32 * 16


def test_none_method_skipped_bits_itemsize():
    params = {"embed": jnp.ones((16, 8), jnp.bfloat16)}
    _, rep = compress_params(
        params, CompressionConfig(method="none"))
    assert rep.skipped_bits == 16 * 8 * 16
