"""BAD: unmarked static params; per-step scalars into jitted calls."""
import jax
import jax.numpy as jnp


# `n` drives a range() and a shape but is not static -> retrace per value
step = jax.jit(lambda x, n: sum(jnp.zeros((n,)) + x for _ in range(n)))


class Engine:

    def __init__(self):
        self._step = jax.jit(lambda x: x * 2)

    def serve(self, reqs):
        out = []
        for r in reqs:
            # fresh python scalar per iteration -> one trace per length
            out.append(self._step(jnp.ones(4), len(r)))
        return out
