"""BAD: a host-pure scheduler module touching jax.
# iteralint: host-pure-module
"""
import jax
import jax.numpy as jnp
import numpy as np


def admit(queue, pool):
    # device op in the admission hot path
    order = jnp.argsort(jnp.asarray([r.rid for r in queue]))
    return [queue[i] for i in np.asarray(order)]


def evict(pool):
    import jax.numpy as lazy_jnp   # even lazily: pure modules ban jax
    return lazy_jnp.zeros(())


def count(pool):
    return jax.device_count()
