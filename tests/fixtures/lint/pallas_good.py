"""GOOD: a pallas_call honoring every launch contract."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def kernel(x_ref, w_ref, o_ref, acc_ref):
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(x, w, *, bm=128, bk=128, bn=256, w_packed=False):
    m, k = x.shape
    _, n = w.shape
    assert m % bm == 0 and k % bk == 0 and n % bn == 0
    assert not w_packed or bn % 256 == 0
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
    )(x, w)
