"""BAD: host control flow and syncs on traced values inside jit."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def branchy(x):
    s = jnp.sum(x)
    if s > 0:                       # traced `if`
        s = s + 1
    while s < 10:                   # traced `while`
        s = s * 2
    assert s != 0                   # traced `assert`
    return s


@jax.jit
def syncy(x):
    y = jnp.abs(x)
    n = len(y)                      # len() of traced array
    v = float(jnp.max(y))           # float() host sync
    host = np.asarray(y)            # numpy materialization
    return y.item() + n + v + host.sum()
