"""GOOD: static args declared; per-step scalars bucketed outside loop."""
import jax
import jax.numpy as jnp

# the shape-driving arg is declared static
step = jax.jit(lambda x, n: jnp.zeros((n,)) + x, static_argnums=1)


def _pow2_bucket(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class Engine:

    def __init__(self):
        self._step = jax.jit(lambda x, w: x[:, :w], static_argnums=1)

    def serve(self, reqs):
        w = _pow2_bucket(max(len(r) for r in reqs))
        out = []
        for r in reqs:
            out.append(self._step(jnp.ones((4, 16)), w))
        return out
