"""GOOD: arrays in children, static hashable scalars in aux_data."""
import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class QuantBlob:
    values: jax.Array
    scale: jax.Array
    wl: int
    axis: int
    packed: bool


jax.tree_util.register_pytree_with_keys(
    QuantBlob,
    lambda q: ((("values", q.values), ("scale", q.scale)),
               (q.wl, q.axis, q.packed)),
    lambda aux, ch: QuantBlob(ch[0], ch[1], *aux),
)
