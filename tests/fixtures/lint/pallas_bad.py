"""BAD: pallas_call violating grid/BlockSpec/scratch contracts."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def kernel(x_ref, w_ref, o_ref, acc_ref):
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...])
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(x, w, *, bm=128, bk=128, bn=128, w_packed=False):
    m, k = x.shape
    _, n = w.shape
    # missing: assert m % bm == 0 (grid divides m // bm below)
    # missing: packed `% 256` guard for w_packed
    assert k % bk == 0 and n % bn == 0
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            # index map takes 2 args for a rank-3 grid
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            # index map returns 3 coords for a rank-2 block
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        # bf16 accumulator scratch loses mantissa across the K loop
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.bfloat16)],
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
    )(x, w, w)  # 3 operands vs 2 in_specs
