"""BAD: boundary projections without reduce_tp; stray collectives."""
import jax


def apply_linear(x, w, *, reduce_tp=False):
    out = x @ w
    if reduce_tp:
        out = jax.lax.psum(out, "model")  # iteralint: disable=tp-boundary
    return out


# iteralint: tp-root
def serving_step(x, params):
    h = attention_block(x, params)
    return mlp_block(h, params)


def attention_block(x, params):
    # boundary projection missing reduce_tp=True: shards stay partial
    return apply_linear(x, params["wo"])


def mlp_block(x, params):
    h = apply_linear(x, params["up"])
    # raw collective instead of the sanctioned wrapper, outside shard_map
    h = jax.lax.psum(h, "model")
    return apply_linear(h, params["down"])


def double_reduce(x, params):
    # two all-reduces in one boundary function
    a = apply_linear(x, params["wo"], reduce_tp=True)
    b = apply_linear(a, params["down"], reduce_tp=True)
    return b
