"""GOOD: a host-pure scheduler module — numpy/stdlib only.
# iteralint: host-pure-module
"""
import collections

import numpy as np


def admit(queue, pool):
    order = np.argsort([r.rid for r in queue])
    return [queue[i] for i in order]


def evict(pool, n):
    victims = collections.deque(maxlen=n)
    for b in pool:
        victims.append(b)
    return list(victims)
