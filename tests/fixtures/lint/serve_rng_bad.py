"""Fixture: host RNG on the serve loop's host path (serve-rng fires).

Every pattern here breaks seeded reproducibility: host RNG state (or a
threaded jax key) makes each token's randomness depend on how many
steps ran before it, which batch composition, prefix-cache hits, and
chunking all change.
"""
# iteralint: host-serve-loop
import random

import jax
import numpy as np


def serve_loop(reqs, step_fn, key):
    outs = []
    for r in reqs:
        key, sub = jax.random.split(key)        # per-step host split
        temp = np.random.uniform(0.5, 1.0)      # numpy host RNG
        jitter = random.random()                # stdlib host RNG
        outs.append(step_fn(r, sub, temp, jitter))
    return outs


def pick_row(rows):
    return rows[np.random.randint(len(rows))]   # scheduling must not roll dice
