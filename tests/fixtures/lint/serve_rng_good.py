"""Fixture: the sanctioned serve sampling pattern (serve-rng clean).

The host packs (seed, rid, counter) metadata into the one per-step
buffer; keys are derived and consumed inside the jitted step. PRNGKey
per request (not per step) is fine; jax.random use inside a traced
function is exactly the point of the rule's exemption.
"""
# iteralint: host-serve-loop
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def fused_step(buf):
    seed, rid, counter = buf[:, -3], buf[:, -2], buf[:, -1]

    def one(s, r, c):
        return jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(s), r), c)

    keys = jax.vmap(one)(seed, rid, counter)
    return jax.vmap(jax.random.categorical)(
        keys, jnp.zeros((buf.shape[0], 8), jnp.float32))


def serve_loop(reqs):
    outs = []
    for step, r in enumerate(reqs):
        buf = np.zeros((len(reqs), 8), np.int32)
        buf[:, -3:] = (7, r, step)      # metadata, not randomness
        outs.append(fused_step(buf))
    return outs
