"""GOOD: exactly one reduce_tp per boundary, collectives stay caged."""


def apply_linear(x, w, *, reduce_tp=False):
    out = x @ w
    if reduce_tp:
        out = psum_tp(out)
    return out


def psum_tp(x):
    return x


# iteralint: tp-root
def serving_step(x, params):
    h = attention_block(x, params)
    return mlp_block(h, params)


def attention_block(x, params):
    # the wo projection carries the block's single all-reduce
    return apply_linear(x, params["wo"], reduce_tp=True)


def mlp_block(x, params):
    h = apply_linear(x, params["up"])
    return apply_linear(h, params["down"], reduce_tp=True)
