"""BAD: arrays / unhashables in registered pytree aux_data."""
import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class QuantBlob:
    values: jax.Array
    scale: jax.Array
    wl: int
    tags: list


jax.tree_util.register_pytree_with_keys(
    QuantBlob,
    # `scale` is an array and `tags` a list — both poison the jit cache
    lambda q: ((("values", q.values),), (q.wl, q.scale, q.tags, [1])),
    lambda aux, ch: QuantBlob(ch[0], aux[1], aux[0], aux[2]),
)
