"""GOOD: static branching, shape reads, device-side reductions."""
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("width",))
def sanctioned(x, width=4):
    # branching on a declared-static parameter is fine
    if width:
        x = x[:, :width]
    # .shape is static under tracing
    assert x.shape[-1] <= 8
    b = x.shape[0]
    s = jnp.sum(x)
    # data-dependent select stays on device
    return jnp.where(s > 0, s, -s) / b


def host_wrapper(x):
    # host code may branch on values freely — it is not traced
    y = sanctioned(x)
    if y.shape[0] > 1:
        return y
    return y[None]
