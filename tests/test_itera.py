"""Tests for Algorithm 1 (iterative quantized SVD) — the paper's core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, strategies as st

from repro.core.itera import (
    LowRankQ, itera_decompose, reconstruction_error, svd_decompose,
)
from repro.core.quant import quantize

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def lowrankish(key, k, n, decay=0.15):
    """Matrix with decaying spectrum + outliers (LLM-weight-like)."""
    ku, kv, ko = jax.random.split(key, 3)
    u = jax.random.normal(ku, (k, min(k, n)))
    v = jax.random.normal(kv, (min(k, n), n))
    s = jnp.exp(-decay * jnp.arange(min(k, n)))
    w = (u * s) @ v
    out = jax.random.bernoulli(ko, 0.002, w.shape) * 8.0
    return w + out


def test_engines_agree():
    w = lowrankish(jax.random.PRNGKey(0), 48, 64)
    e_svd = float(reconstruction_error(w, itera_decompose(w, 8, 8,
                                                          method="svd")))
    e_pow = float(reconstruction_error(w, itera_decompose(w, 8, 8,
                                                          method="power")))
    assert abs(e_svd - e_pow) < 0.05


@given(st.integers(0, 5))
def test_residual_monotone_in_rank(seed):
    """More rank never hurts reconstruction (greedy residual shrinks)."""
    w = lowrankish(jax.random.PRNGKey(seed), 40, 48)
    errs = [float(reconstruction_error(w, itera_decompose(w, r, 8)))
            for r in (2, 8, 24)]
    assert errs[0] >= errs[1] >= errs[2] - 1e-4


@pytest.mark.parametrize("wl", [4, 6])
def test_itera_beats_svd_then_quant(wl):
    """The paper's central claim at the matrix level: the error-compensating
    loop beats decompose-then-quantize at the same (rank, bits)."""
    wins = 0
    for seed in range(5):
        w = lowrankish(jax.random.PRNGKey(seed), 96, 96)
        r = 32
        e_it = float(reconstruction_error(w, itera_decompose(w, r, wl)))
        e_sv = float(reconstruction_error(w, svd_decompose(w, r, wl)))
        wins += e_it <= e_sv + 1e-4
    assert wins >= 4, f"itera won only {wins}/5"


def test_gap_grows_as_bits_shrink():
    """Error-compensation matters more at lower precision."""
    w = lowrankish(jax.random.PRNGKey(7), 96, 96)
    gaps = {}
    for wl in (4, 8):
        e_it = float(reconstruction_error(w, itera_decompose(w, 32, wl)))
        e_sv = float(reconstruction_error(w, svd_decompose(w, 32, wl)))
        gaps[wl] = e_sv - e_it
    assert gaps[4] >= gaps[8] - 1e-4


def test_full_rank_high_bits_near_exact():
    w = lowrankish(jax.random.PRNGKey(3), 32, 32, decay=0.3)
    lr = itera_decompose(w, 32, 8)
    assert float(reconstruction_error(w, lr)) < 0.08


def test_factor_shapes_and_dtypes():
    from repro.models.layers import apply_linear

    w = lowrankish(jax.random.PRNGKey(4), 40, 56)
    lr = itera_decompose(w, 12, 6)
    assert lr.w1.shape == (40, 12) and lr.w2.shape == (12, 56)
    assert lr.w1.values.dtype == jnp.int8
    assert lr.w1.scale.shape == (1, 12) and lr.w2.scale.shape == (12, 1)
    assert lr.rank == 12
    y = apply_linear(jnp.ones((3, 40)), lr)
    assert y.shape == (3, 56)


def test_nops_and_storage():
    w = lowrankish(jax.random.PRNGKey(5), 64, 64)
    lr = itera_decompose(w, 16, 4)
    assert lr.nops(8) == 8 * 16 * (64 + 64)
    # decompose emits int8 carriers: resident cost is 8 bits/code until
    # the factors are packed (compress_params does this for W4 plans)
    assert lr.storage_bits() == (64 * 16 + 16 * 64) * 8 + 2 * 16 * 32
    # hand-build the packed layout (these 16/64-wide axes are
    # pad-inflating, so pack_weights itself refuses them — see
    # quant.packed_pad_ok): storage_bits counts the halved bytes either way
    import dataclasses

    from repro.core.quant import pack_int4

    def force(q):
        return dataclasses.replace(q, values=pack_int4(q.values),
                                   packed=True)

    packed = LowRankQ(force(lr.w1), force(lr.w2))
    assert packed.rank == 16 and packed.w1.shape == (64, 16)
    assert packed.storage_bits() == (64 * 16 + 16 * 64) * 4 + 2 * 16 * 32


def test_truncate_preserves_aux_and_rejects_packed():
    """truncate keeps act_wl (an A4 plan must not silently become A8)
    and refuses packed factors (packing happens after rank selection)."""
    import dataclasses
    from repro.core.itera import truncate
    from repro.core.quant import pack_int4

    w = lowrankish(jax.random.PRNGKey(6), 64, 64)
    lr = itera_decompose(w, 16, 4)
    lr_a4 = LowRankQ(dataclasses.replace(lr.w1, act_wl=4),
                     dataclasses.replace(lr.w2, act_wl=4))
    t = truncate(lr_a4, 8)
    assert t.rank == 8 and t.w1.act_wl == 4 and t.w2.act_wl == 4
    # any packed factor must be refused, however it was built (these
    # axes are pad-inflating, so hand-build the layout)
    packed = LowRankQ(
        dataclasses.replace(lr.w1, values=pack_int4(lr.w1.values),
                            packed=True),
        dataclasses.replace(lr.w2, values=pack_int4(lr.w2.values),
                            packed=True))
    with pytest.raises(ValueError, match="carrier-layout"):
        truncate(packed, 8)


def test_outlier_capture():
    """Outliers dominate the residual -> captured in early iterations."""
    w = jnp.zeros((32, 32)).at[3, 7].set(50.0).at[20, 11].set(-40.0)
    w = w + 0.01 * jax.random.normal(jax.random.PRNGKey(0), (32, 32))
    lr = itera_decompose(w, 2, 8)
    rec = lr.dequant_product()
    assert abs(float(rec[3, 7]) - 50.0) < 2.0
    assert abs(float(rec[20, 11]) + 40.0) < 2.0
