"""In-flight batching scheduler + blocked KV-cache tests.

The load-bearing claims, per docs/serving.md:
  * ragged arrivals through the unified token-budget step — prompts
    chunk-prefilled across steps while older rows decode — are greedy
    token-identical to running each prompt alone (incl. int8 KV blocks);
  * serve never runs a solo prefill: every forward pass is the one
    jitted step, and decode rows advance on every step a chunk runs;
  * the block pool never leaks under random admit/evict sequences;
  * overflowing the row/block capacity queues requests instead of
    crashing, and everything still completes correctly.
"""
import dataclasses
import random

import jax
import numpy as np
import pytest

from repro.api import InferenceEngine, Request, SamplingParams
from repro.configs import get_config
from repro.models import init_params
from repro.runtime.kvblocks import BlockPool, blocks_needed
from repro.runtime.scheduler import Scheduler


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("opus-mt", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    # chunk_tokens=8 forces real chunked prefill: every prompt longer
    # than the leftover budget enters the pool across multiple steps.
    return InferenceEngine(cfg, params, max_batch=3, block_size=4,
                           chunk_tokens=8)


def _prompts(lens, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32) for n in lens]


def _solo(engine, prompt, gen):
    return engine.generate(np.asarray(prompt)[None],
                           SamplingParams(max_tokens=gen)).tokens[0]


# ------------------------------------------------------------ equivalence --
def test_ragged_matches_per_prompt_greedy(engine):
    prompts = _prompts([5, 9, 12, 7, 16, 3], engine.cfg.vocab_size)
    res = engine.serve(prompts, SamplingParams(max_tokens=6))
    assert [p.size for p in prompts] == res.prompt_lens
    for p, out in zip(prompts, res.outputs):
        np.testing.assert_array_equal(out, _solo(engine, p, 6))


def test_per_request_max_tokens_prefix_property(engine):
    """Greedy decode is prefix-stable: a request stopped at g tokens must
    equal the first g tokens of a longer run on the same prompt."""
    prompts = _prompts([6, 11, 4], engine.cfg.vocab_size, seed=1)
    gens = [1, 7, 3]
    reqs = [Request(tokens=p, max_tokens=g) for p, g in zip(prompts, gens)]
    res = engine.serve(reqs)
    for p, g, out in zip(prompts, gens, res.outputs):
        assert out.shape == (g,)
        np.testing.assert_array_equal(out, _solo(engine, p, 8)[:g])


def test_int8_kv_blocks_match_rectangular(engine):
    """Quantized (int8+scales) KV blocks reproduce the monolithic int8
    cache path token for token, including chunked prefill (prefill
    attends fake-quantized K/V, exactly what the pool hands back)."""
    cfg8 = dataclasses.replace(engine.cfg, kv_cache_bits=8)
    eng8 = InferenceEngine(cfg8, engine.params, max_batch=2, block_size=4,
                           chunk_tokens=6)
    prompts = _prompts([5, 10, 7], cfg8.vocab_size, seed=2)
    res = eng8.serve(prompts, SamplingParams(max_tokens=5))
    assert res.prefill_chunks > len(prompts), "prompts were not chunked"
    for p, out in zip(prompts, res.outputs):
        np.testing.assert_array_equal(out, _solo(eng8, p, 5))


def test_serve_has_no_solo_prefill_path(engine):
    """Every forward pass in serve is the unified step: sabotaging the
    rectangular prefill callable must not change serve at all."""
    eng = InferenceEngine(engine.cfg, engine.params, max_batch=2,
                          block_size=4, chunk_tokens=8)
    prompts = _prompts([9, 4, 13, 6], engine.cfg.vocab_size, seed=4)
    want = [_solo(engine, p, 5) for p in prompts]

    def boom(*a, **k):
        raise AssertionError("serve called the solo prefill path")

    eng._prefill = boom
    res = eng.serve(prompts, SamplingParams(max_tokens=5))
    for w, out in zip(want, res.outputs):
        np.testing.assert_array_equal(out, w)
    assert res.prefill_tokens == sum(p.size for p in prompts)


def test_decode_advances_while_chunks_run(engine):
    """In-flight batching proper: a long prompt admitted mid-flight is
    chunk-prefilled in the same steps that keep the resident row
    decoding — no decode stall on admission."""
    prompts = _prompts([4, 16, 12], engine.cfg.vocab_size, seed=5)
    res = engine.serve(prompts, SamplingParams(max_tokens=8),
                       max_batch=2, chunk_tokens=8)
    assert res.mixed_steps > 0, "no step mixed prefill chunks with decode"
    for p, out in zip(prompts, res.outputs):
        np.testing.assert_array_equal(out, _solo(engine, p, 8))


def test_schedule_output_decode_first_then_balanced_chunks():
    """schedule(): decode rows always advance; the chunk budget is split
    evenly over prefilling rows (narrow spans = little padding in the
    rectangular step); budget a short prompt can't use idles."""
    pool = BlockPool(num_blocks=64, block_size=2)
    sched = Scheduler(pool, max_batch=3)
    a = Request(tokens=np.arange(1, 11), max_tokens=4, rid=0)   # 10 tokens
    b = Request(tokens=np.arange(1, 4), max_tokens=4, rid=1)    # 3 tokens
    for r in (a, b):
        sched.submit(r)
    plan = sched.schedule(token_budget=8)
    assert [s.req.rid for s in plan.admitted] == [0, 1]
    assert not plan.decode
    rows = {s.req.rid: s.row for s in plan.admitted}
    # even split is 4+4, but rid 1 only has 3 tokens of prompt; the
    # spare token idles rather than widening rid 0's span past the cap
    assert plan.prefill == {rows[0]: 4, rows[1]: 3}
    assert plan.max_span == 4 and plan.total_tokens == 7
    sched.rows[rows[0]].prefilled = 10          # rid 0 prompt now cached
    sched.rows[rows[1]].prefilled = 3           # rid 1 too, still no output
    plan2 = sched.schedule(token_budget=8)
    assert sorted(plan2.decode) == sorted([rows[0], rows[1]])
    assert not plan2.prefill and not plan2.is_mixed
    for s in list(sched.rows):
        if s is not None:
            sched.finish(s)


def test_schedule_short_prompt_budget_idles_not_widens():
    """Budget a short-remaining prompt leaves unused does NOT widen an
    older row's chunk past the balanced cap — the span (and so the
    step's padding) stays bounded by ceil(budget / #prefilling)."""
    pool = BlockPool(num_blocks=64, block_size=2)
    sched = Scheduler(pool, max_batch=3)
    sched.submit(Request(tokens=np.arange(1, 21), max_tokens=2, rid=0))
    sched.submit(Request(tokens=np.arange(1, 3), max_tokens=2, rid=1))
    plan = sched.schedule(token_budget=12)
    rows = {s.req.rid: s.row for s in plan.admitted}
    # even cap is 6; rid 1 only has 2 prompt tokens, rid 0 stays at 6
    assert plan.prefill == {rows[0]: 6, rows[1]: 2}
    assert plan.max_span == 6
    for s in list(sched.rows):
        if s is not None:
            sched.finish(s)


# ----------------------------------------------------------- block pool --
def test_block_pool_never_leaks_random_admit_evict():
    rng = random.Random(0)
    pool = BlockPool(num_blocks=17, block_size=4)
    live = []
    for _ in range(500):
        if live and (rng.random() < 0.4 or not pool.can_alloc(1)):
            pool.free(live.pop(rng.randrange(len(live))))
        else:
            n = rng.randint(1, min(4, pool.available))
            ids = pool.alloc(n)
            assert 0 not in ids, "trash block must never be handed out"
            live.append(ids)
    held = [b for ids in live for b in ids]
    assert len(held) == len(set(held)), "double-allocated block"
    assert pool.available == pool.capacity - len(held)
    for ids in live:
        pool.free(ids)
    assert pool.available == pool.capacity
    with pytest.raises(RuntimeError, match="double free"):
        pool.free([1])


def test_block_pool_rejects_overdraw_and_tiny_pools():
    pool = BlockPool(num_blocks=4, block_size=2)
    assert pool.capacity == 3
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(4)
    with pytest.raises(ValueError, match="reserved"):
        BlockPool(num_blocks=1, block_size=2)


def test_blocks_needed_excludes_final_token():
    # prompt 4 + gen 5 caches positions 0..7 -> 2 blocks of 4, not 3
    assert blocks_needed(4, 5, 4) == 2
    assert blocks_needed(4, 6, 4) == 3
    # chunked prefill writes every prompt position into the pool, so even
    # a gen-1 request holds blocks for its prompt (not the final token)
    assert blocks_needed(9, 1, 4) == 3


# ------------------------------------------------------------- overflow --
def test_capacity_overflow_queues_not_crashes(engine):
    """7 requests into 2 rows and a pool sized for exactly 2 worst-case
    sequences: later arrivals must wait, everyone must finish correct."""
    prompts = _prompts([8, 3, 12, 5, 9, 4, 6], engine.cfg.vocab_size, seed=3)
    gen = 4
    per_seq = max(blocks_needed(p.size, gen, 4) for p in prompts)
    res = engine.serve(prompts, SamplingParams(max_tokens=gen),
                       max_batch=2, block_size=4,
                       num_blocks=2 * per_seq + 1)
    assert res.max_queue_depth >= 5, "overflow should have queued requests"
    for p, out in zip(prompts, res.outputs):
        np.testing.assert_array_equal(out, _solo(engine, p, gen))


def test_oversized_request_fails_loudly():
    pool = BlockPool(num_blocks=3, block_size=2)
    sched = Scheduler(pool, max_batch=2)
    with pytest.raises(ValueError, match="blocks"):
        sched.submit(Request(tokens=np.arange(1, 20), max_tokens=4))
    with pytest.raises(ValueError, match="unresolved"):
        sched.submit(Request(tokens=np.arange(1, 4)))  # max_tokens=None


def test_pool_pressure_preempts_newest_zero_output_row():
    """When the head request cannot admit even with a free row, schedule()
    preempts the newest zero-output sequence: its blocks are freed, its
    request requeues immediately behind the head, the head admits in the
    same step, and the victim — having yielded once — is never preempted
    again."""
    pool = BlockPool(num_blocks=13, block_size=4)       # capacity 12
    sched = Scheduler(pool, max_batch=3)
    a = Request(tokens=np.arange(1, 18), max_tokens=4, rid=0)   # 5 blocks
    b = Request(tokens=np.arange(1, 14), max_tokens=4, rid=1)   # 4 blocks
    c = Request(tokens=np.arange(1, 16), max_tokens=4, rid=2)   # 5 blocks
    for r in (a, b, c):
        sched.submit(r)
    plan = sched.schedule(token_budget=32)      # a, b admitted; c waits
    assert [s.req.rid for s in plan.admitted] == [0, 1]
    assert not plan.preempted and sched.num_waiting == 1
    row_a = plan.admitted[0].row
    sched.rows[row_a].prefilled = 17            # a decoded once: protected
    sched.rows[row_a].n_emitted = 1
    plan2 = sched.schedule(token_budget=32)     # pool can't back c (5 > 3)
    # victim = b (newest zero-output); a is mid-decode and untouchable
    assert plan2.preempted == [plan.admitted[1].row]
    assert [s.req.rid for s in plan2.admitted] == [2]
    assert sched.preemptions == 1 and b.requeued
    assert sched.waiting[0] is b, "victim must requeue at the queue head"
    assert pool.available == pool.capacity - 5 - 5      # b's blocks freed
    # b re-admits once a row frees, and never yields again
    sched.finish(sched.rows[row_a])
    plan3 = sched.schedule(token_budget=32)
    assert [s.req.rid for s in plan3.admitted] == [1]
    row_c = plan2.admitted[0].row
    sched.rows[row_c].prefilled = 15            # c decoding now: protected
    sched.rows[row_c].n_emitted = 1
    sched.submit(Request(tokens=np.arange(1, 30), max_tokens=4, rid=3))
    plan4 = sched.schedule(token_budget=32)     # rid 3 needs 8: can't fit
    assert not plan4.admitted and not plan4.preempted, \
        "a once-requeued request was preempted again"
    assert sched.preemptions == 1
    for s in list(sched.rows):
        if s is not None:
            sched.finish(s)
    assert pool.available == pool.capacity


def test_preemption_declined_when_it_cannot_fit_the_head():
    """No victim set that provably fits the head => no preemption at all
    (churn without progress is worse than waiting)."""
    pool = BlockPool(num_blocks=13, block_size=4)       # capacity 12
    sched = Scheduler(pool, max_batch=3)
    big = Request(tokens=np.arange(1, 18), max_tokens=4, rid=0)   # 5 blocks
    small = Request(tokens=np.arange(1, 5), max_tokens=1, rid=1)  # 1 block
    for r in (big, small):
        sched.submit(r)
    plan = sched.schedule(token_budget=32)
    assert len(plan.admitted) == 2
    plan.admitted[0].prefilled = 17             # big is decoding: protected
    plan.admitted[0].n_emitted = 1
    # head needs 8; 6 free + 1 reclaimable from the only victim < 8
    sched.submit(Request(tokens=np.arange(1, 30), max_tokens=4, rid=2))
    plan2 = sched.schedule(token_budget=32)
    assert not plan2.admitted and not plan2.preempted
    assert sched.preemptions == 0 and not small.requeued
    for s in list(sched.rows):
        if s is not None:
            sched.finish(s)


def test_scheduler_fcfs_head_of_line():
    """Admission is FCFS: a small later request does not jump a head
    request that is waiting on blocks."""
    pool = BlockPool(num_blocks=9, block_size=2)   # capacity 8
    sched = Scheduler(pool, max_batch=4)
    sched.submit(Request(tokens=np.arange(1, 9), max_tokens=4))   # 6 blocks
    big = sched.try_admit()
    assert big is not None and len(big.block_ids) == 6
    sched.submit(Request(tokens=np.arange(1, 9), max_tokens=4))   # waits
    sched.submit(Request(tokens=np.arange(1, 3), max_tokens=2))   # would fit
    assert sched.try_admit() is None
    assert sched.num_waiting == 2 and sched.max_queue_depth == 2
    sched.finish(big)
    nxt = sched.try_admit()
    assert nxt is not None and nxt.req.tokens.size == 8, "FCFS violated"
