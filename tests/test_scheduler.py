"""Continuous-batching scheduler + blocked KV-cache tests.

The load-bearing claims, per docs/serving.md:
  * ragged arrivals through the shared masked decode batch are greedy
    token-identical to running each prompt alone (incl. int8 KV blocks);
  * the block pool never leaks under random admit/evict sequences;
  * overflowing the row/block capacity queues requests instead of
    crashing, and everything still completes correctly.
"""
import dataclasses
import random

import jax
import numpy as np
import pytest

from repro.api import InferenceEngine, Request, SamplingParams
from repro.configs import get_config
from repro.models import init_params
from repro.runtime.kvblocks import BlockPool, blocks_needed
from repro.runtime.scheduler import Scheduler


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("opus-mt", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return InferenceEngine(cfg, params, max_batch=3, block_size=4)


def _prompts(lens, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32) for n in lens]


def _solo(engine, prompt, gen):
    return engine.generate(np.asarray(prompt)[None],
                           SamplingParams(max_tokens=gen)).tokens[0]


# ------------------------------------------------------------ equivalence --
def test_ragged_matches_per_prompt_greedy(engine):
    prompts = _prompts([5, 9, 12, 7, 16, 3], engine.cfg.vocab_size)
    res = engine.serve(prompts, SamplingParams(max_tokens=6))
    assert [p.size for p in prompts] == res.prompt_lens
    for p, out in zip(prompts, res.outputs):
        np.testing.assert_array_equal(out, _solo(engine, p, 6))


def test_per_request_max_tokens_prefix_property(engine):
    """Greedy decode is prefix-stable: a request stopped at g tokens must
    equal the first g tokens of a longer run on the same prompt."""
    prompts = _prompts([6, 11, 4], engine.cfg.vocab_size, seed=1)
    gens = [1, 7, 3]
    reqs = [Request(tokens=p, max_tokens=g) for p, g in zip(prompts, gens)]
    res = engine.serve(reqs)
    for p, g, out in zip(prompts, gens, res.outputs):
        assert out.shape == (g,)
        np.testing.assert_array_equal(out, _solo(engine, p, 8)[:g])


def test_int8_kv_blocks_match_rectangular(engine):
    """Quantized (int8+scales) KV blocks reproduce the monolithic int8
    cache path token for token."""
    cfg8 = dataclasses.replace(engine.cfg, kv_cache_bits=8)
    eng8 = InferenceEngine(cfg8, engine.params, max_batch=2, block_size=4)
    prompts = _prompts([5, 10, 7], cfg8.vocab_size, seed=2)
    res = eng8.serve(prompts, SamplingParams(max_tokens=5))
    for p, out in zip(prompts, res.outputs):
        np.testing.assert_array_equal(out, _solo(eng8, p, 5))


# ----------------------------------------------------------- block pool --
def test_block_pool_never_leaks_random_admit_evict():
    rng = random.Random(0)
    pool = BlockPool(num_blocks=17, block_size=4)
    live = []
    for _ in range(500):
        if live and (rng.random() < 0.4 or not pool.can_alloc(1)):
            pool.free(live.pop(rng.randrange(len(live))))
        else:
            n = rng.randint(1, min(4, pool.available))
            ids = pool.alloc(n)
            assert 0 not in ids, "trash block must never be handed out"
            live.append(ids)
    held = [b for ids in live for b in ids]
    assert len(held) == len(set(held)), "double-allocated block"
    assert pool.available == pool.capacity - len(held)
    for ids in live:
        pool.free(ids)
    assert pool.available == pool.capacity
    with pytest.raises(RuntimeError, match="double free"):
        pool.free([1])


def test_block_pool_rejects_overdraw_and_tiny_pools():
    pool = BlockPool(num_blocks=4, block_size=2)
    assert pool.capacity == 3
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(4)
    with pytest.raises(ValueError, match="reserved"):
        BlockPool(num_blocks=1, block_size=2)


def test_blocks_needed_excludes_final_token():
    # prompt 4 + gen 5 caches positions 0..7 -> 2 blocks of 4, not 3
    assert blocks_needed(4, 5, 4) == 2
    assert blocks_needed(4, 6, 4) == 3
    assert blocks_needed(9, 1, 4) == 0  # gen-1 finishes at prefill: no KV


# ------------------------------------------------------------- overflow --
def test_capacity_overflow_queues_not_crashes(engine):
    """7 requests into 2 rows and a pool sized for exactly 2 worst-case
    sequences: later arrivals must wait, everyone must finish correct."""
    prompts = _prompts([8, 3, 12, 5, 9, 4, 6], engine.cfg.vocab_size, seed=3)
    gen = 4
    per_seq = max(blocks_needed(p.size, gen, 4) for p in prompts)
    res = engine.serve(prompts, SamplingParams(max_tokens=gen),
                       max_batch=2, block_size=4,
                       num_blocks=2 * per_seq + 1)
    assert res.max_queue_depth >= 5, "overflow should have queued requests"
    for p, out in zip(prompts, res.outputs):
        np.testing.assert_array_equal(out, _solo(engine, p, gen))


def test_oversized_request_fails_loudly():
    pool = BlockPool(num_blocks=3, block_size=2)
    sched = Scheduler(pool, max_batch=2)
    with pytest.raises(ValueError, match="blocks"):
        sched.submit(Request(tokens=np.arange(1, 20), max_tokens=4))
    with pytest.raises(ValueError, match="unresolved"):
        sched.submit(Request(tokens=np.arange(1, 4)))  # max_tokens=None


def test_scheduler_fcfs_head_of_line():
    """Admission is FCFS: a small later request does not jump a head
    request that is waiting on blocks."""
    pool = BlockPool(num_blocks=9, block_size=2)   # capacity 8
    sched = Scheduler(pool, max_batch=4)
    sched.submit(Request(tokens=np.arange(1, 9), max_tokens=4))   # 6 blocks
    big = sched.try_admit()
    assert big is not None and len(big.block_ids) == 6
    sched.submit(Request(tokens=np.arange(1, 9), max_tokens=4))   # waits
    sched.submit(Request(tokens=np.arange(1, 3), max_tokens=2))   # would fit
    assert sched.try_admit() is None
    assert sched.num_waiting == 2 and sched.max_queue_depth == 2
    sched.finish(big)
    nxt = sched.try_admit()
    assert nxt is not None and nxt.req.tokens.size == 8, "FCFS violated"
