"""Prefix caching: refcounted copy-on-write KV-block sharing tests.

The contract, per docs/serving.md:
  * `kvblocks.prefix_digests` chains full-block digests — equal digests
    iff equal position-aligned prefixes under the same fingerprint;
  * `BlockPool` register/share/free keeps a content index over the
    free-list allocator: idle cached blocks still count as available and
    are LRU-evicted only when the free list runs dry;
  * scheduler admission maps the longest cached prefix by reference,
    charges only new blocks, and copy-on-writes the final block of a
    fully-cached prompt so its last position's logits are recomputed
    into a private block;
  * greedy serve with the cache ON is TOKEN-IDENTICAL to cache OFF for
    every request — across dtypes, KV precisions, speculation, and
    tensor-parallel meshes. Cached K/V equals recomputed K/V bit for bit
    (same tokens, same positions, same per-(token, head) int8 scales),
    so this is an exactness property, not a tolerance;
  * `hw.tpu_model.prefix_cache_point` prices the skipped prefill work
    monotonically in the hit rate.

Mesh cases run in a subprocess (forced host devices) exactly like
tests/test_tp_serving.py, so this process keeps seeing one device.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import (DraftSpec, InferenceEngine,
                       Request, SamplingParams)
from repro.configs import get_config
from repro.core.compress import CompressionConfig
from repro.hw import tpu_model
from repro.models import init_params
from repro.runtime.kvblocks import BlockPool, blocks_needed, prefix_digests
from repro.runtime.scheduler import Scheduler
from repro.runtime.scheduler import Request as SchedRequest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# -------------------------------------------------------- prefix_digests --

def test_prefix_digests_chain_commits_to_whole_prefix():
    """digest[i] pins tokens[0 : (i+1)*bs]: flipping ANY earlier token
    changes every digest from that block on, while a tail change leaves
    earlier digests alone. Partial tail blocks get no digest."""
    toks = np.arange(1, 15, dtype=np.int32)           # 14 tokens, bs 4
    d = prefix_digests(toks, 4)
    assert len(d) == 3                                # 14 // 4 full blocks
    assert len({*d}) == 3                             # chain never repeats
    mut = toks.copy()
    mut[1] += 1                                       # inside block 0
    d2 = prefix_digests(mut, 4)
    assert all(a != b for a, b in zip(d, d2)), "early flip must cascade"
    mut = toks.copy()
    mut[9] += 1                                       # inside block 2
    d3 = prefix_digests(mut, 4)
    assert d3[:2] == d[:2] and d3[2] != d[2]
    # partial tail (tokens 12..13) is never digested
    assert prefix_digests(toks[:12], 4) == d


def test_prefix_digests_fingerprint_and_block_size_disjoint():
    """Same tokens under a different model fingerprint or block size must
    never collide — cached K/V is only reusable for the exact engine
    geometry that wrote it."""
    toks = np.arange(8, dtype=np.int32)
    base = prefix_digests(toks, 4)
    assert prefix_digests(toks, 4, b"other-plan") != base
    assert set(prefix_digests(toks, 2)).isdisjoint(base)
    with pytest.raises(ValueError, match="1-D"):
        prefix_digests(toks.reshape(2, 4), 4)


# ------------------------------------------------------------ BlockPool --

def test_register_share_free_lifecycle():
    """register indexes a held block; free parks it idle (still
    available, still shareable); share revives it with refcount 1;
    register of an unheld block is a hard error; first writer wins."""
    pool = BlockPool(num_blocks=6, block_size=4)
    d = prefix_digests(np.arange(4), 4)
    (b,) = pool.alloc(1)
    assert pool.register(b, d[0]) is True
    assert pool.refcount(b) == 1 and pool.lookup(d[0]) == b
    # duplicate content from another writer stays private
    (b2,) = pool.alloc(1)
    assert pool.register(b2, d[0]) is False
    # a block carries at most one digest
    assert pool.register(b, prefix_digests(np.arange(9, 13), 4)[0]) is False
    pool.free([b])                                    # -> idle, not free
    assert pool.refcount(b) == 0
    assert pool.idle_cached_blocks == 1
    assert pool.available == pool.capacity - 1        # b2 still live
    got = pool.share(d[0])
    assert got == b and pool.refcount(b) == 1
    assert pool.idle_cached_blocks == 0
    assert pool.share(b"\x00" * 32) is None
    pool.free([b, b2])
    with pytest.raises(RuntimeError, match="double free"):
        pool.free([b2])
    with pytest.raises(RuntimeError, match="unheld"):
        pool.register(b2, prefix_digests(np.arange(20, 24), 4)[0])


def test_idle_blocks_evict_lru_when_free_list_dry():
    """alloc prefers the free list; once dry it evicts idle cached
    blocks oldest-idle-first, dropping their digests and counting
    evictions. Shared (refcount >= 1) cached blocks are never evicted."""
    pool = BlockPool(num_blocks=5, block_size=2)      # capacity 4
    ds = prefix_digests(np.arange(8), 2)              # 4 digests
    ids = pool.alloc(4)
    for b, d in zip(ids, ds):
        pool.register(b, d)
    keep = pool.share(ds[0])                          # rc 2: pinned
    pool.free(ids)                                    # ids[1:] idle; keep live
    assert pool.idle_cached_blocks == 3
    assert pool.available == 3
    got = pool.alloc(2)                               # evicts oldest two idles
    assert pool.evictions == 2
    assert got == [ids[1], ids[2]], "eviction must be oldest-idle-first"
    assert pool.lookup(ds[1]) is None and pool.lookup(ds[2]) is None
    assert pool.lookup(ds[0]) == keep, "held cached block evicted"
    assert pool.lookup(ds[3]) == ids[3]
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(2)                                 # only ids[3] evictable
    pool.free(got + [keep])


# -------------------------------------------------- scheduler admission --

def _drain_prefill(sched, seq):
    """Chunk-prefill a sequence to completion, registering its blocks
    the way the engine does (advance_prefill at dispatch time)."""
    while not seq.prefill_done:
        sched.advance_prefill(seq, min(4, seq.prompt_len - seq.prefilled))


def test_admission_maps_cached_prefix_by_reference():
    pool = BlockPool(num_blocks=32, block_size=4)
    sched = Scheduler(pool, max_batch=2, prefix_cache=True)
    prefix = np.arange(1, 13, dtype=np.int32)               # 3 full blocks
    a = SchedRequest(tokens=np.concatenate([prefix, [90, 91]]),
                     max_tokens=2, rid=0)
    sched.submit(a)
    sa = sched.try_admit()
    assert sa.n_shared == 0 and sa.cow_src is None
    _drain_prefill(sched, sa)
    assert pool.cached_blocks == 3
    prefix_ids = sa.block_ids[:3]
    sched.finish(sa)
    assert pool.available == pool.capacity              # idle counts free
    b = SchedRequest(tokens=np.concatenate([prefix, [70, 71, 72]]),
                     max_tokens=2, rid=1)
    sched.submit(b)
    sb = sched.try_admit()
    assert sb.n_shared == 3
    assert sb.prefilled == 12, "prefill must resume at first uncached pos"
    assert sb.block_ids[:3] == prefix_ids, "cached blocks not mapped by ref"
    assert all(pool.refcount(x) == 1 for x in sb.block_ids[:3])
    assert sched.cache_hit_blocks == 3 and sched.cache_hit_tokens == 12
    assert sched.cache_cow_blocks == 0
    # worst case charged minus the shared blocks
    need = blocks_needed(b.tokens.size, 2, 4)
    assert len(sb.block_ids) == need
    _drain_prefill(sched, sb)
    sched.finish(sb)
    assert pool.available == pool.capacity


def test_fully_cached_prompt_takes_cow_block():
    """An exact-duplicate prompt shares all but its last matched block,
    pins the last one as cow_src, allocates a private cow_dst, and
    prefills exactly one position (prompt_len - 1) for its logits."""
    pool = BlockPool(num_blocks=16, block_size=4)
    sched = Scheduler(pool, max_batch=2, prefix_cache=True)
    toks = np.arange(1, 9, dtype=np.int32)                  # exactly 2 blocks
    a = SchedRequest(tokens=toks, max_tokens=3, rid=0)
    sched.submit(a)
    sa = sched.try_admit()
    _drain_prefill(sched, sa)
    first, second = sa.block_ids[0], sa.block_ids[1]
    sched.finish(sa)
    dup = SchedRequest(tokens=toks.copy(), max_tokens=3, rid=1)
    sched.submit(dup)
    sd = sched.try_admit()
    assert sd.n_shared == 1 and sd.block_ids[0] == first
    assert sd.cow_src == second and sd.cow_dst == sd.block_ids[1]
    assert sd.cow_dst != second, "COW must be a private block"
    assert sd.prefilled == 7, "only the final position is recomputed"
    assert sched.cache_cow_blocks == 1 and sched.cache_hit_blocks == 2
    assert pool.refcount(second) == 1                       # the pin
    sched.release_cow(sd)
    assert sd.cow_src is None and pool.refcount(second) == 0
    # the dup's private final block must NOT be re-registered over the
    # cached one: first writer won
    sched.advance_prefill(sd, 1)
    assert pool.lookup(sd.digests[1]) == second
    sched.finish(sd)
    assert pool.available == pool.capacity


def test_admission_unwinds_shares_when_pool_cannot_back_rest():
    """If the uncached remainder does not fit, the head stays queued and
    its provisional shares/pins are returned (no refcount leak)."""
    pool = BlockPool(num_blocks=8, block_size=4)            # capacity 7
    sched = Scheduler(pool, max_batch=3, prefix_cache=True, preempt=False)
    prefix = np.arange(1, 9, dtype=np.int32)                # 2 blocks
    a = SchedRequest(tokens=prefix, max_tokens=2, rid=0)
    sched.submit(a)
    sa = sched.try_admit()
    _drain_prefill(sched, sa)
    # hog the rest of the pool so the next admit can't take new blocks
    hog = pool.alloc(pool.available - 2)
    b = SchedRequest(tokens=np.concatenate([prefix, np.arange(40, 48)]),
                     max_tokens=4, rid=1)            # 2 cached + 3 new blocks
    sched.submit(b)
    assert sched.try_admit() is None
    assert all(pool.refcount(x) == 1 for x in sa.block_ids[:2]), \
        "failed admission leaked share refcounts"
    pool.free(hog)
    sched.finish(sa)
    assert pool.available == pool.capacity


# ------------------------------------------------------ engine identity --

@pytest.fixture(scope="module")
def base():
    cfg = get_config("opus-mt", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _shared_workload(vocab, seed=0):
    """9 requests: 6 share a 12-token prefix (3 full blocks at bs=4) with
    distinct tails, 1 is an exact duplicate of the first, 1 is unrelated,
    and the last IS the bare prefix — a fully-cached prompt, so its
    admission must take the copy-on-write path (prompt_len a multiple of
    the block size, every block already registered by then)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, vocab, size=12).astype(np.int32)
    reqs = [np.concatenate([prefix,
                            rng.integers(1, vocab, size=2 + i % 4)
                            .astype(np.int32)])
            for i in range(6)]
    reqs.append(np.concatenate([prefix, reqs[0][12:]]))     # duplicate
    reqs.append(rng.integers(1, vocab, size=9).astype(np.int32))
    reqs.append(prefix.copy())                              # COW trigger
    return reqs


def test_cache_on_matches_cache_off_dtype_kv_matrix(base):
    """The headline exactness claim: for every request, cache-on greedy
    output equals cache-off, across fp32/bf16 models and bf16/int8 KV —
    and the cache actually engaged (hits and at least one COW)."""
    cfg0, params = base
    sp = SamplingParams(max_tokens=5)
    for dtype in ("float32", "bfloat16"):
        for kv_bits in (16, 8):
            cfg = dataclasses.replace(cfg0, dtype=dtype,
                                      kv_cache_bits=kv_bits)
            eng = InferenceEngine(cfg, params, max_batch=3, block_size=4,
                                  chunk_tokens=8)
            prompts = _shared_workload(cfg.vocab_size)
            off = eng.serve(prompts, sp, prefix_cache=False)
            on = eng.serve(prompts, sp, prefix_cache=True)
            assert not off.prefix_cache and on.prefix_cache
            assert off.cache_lookup_blocks == 0
            assert on.cache_hit_blocks > 0, (dtype, kv_bits)
            assert on.cache_cow_blocks >= 1, "duplicate prompt skipped COW"
            assert on.cache_hit_tokens == sum(
                p.size for p in prompts) - on.prefill_tokens
            for i, (a, b) in enumerate(zip(off.outputs, on.outputs)):
                np.testing.assert_array_equal(
                    b, a, err_msg=f"{dtype}/kv{kv_bits} request {i}")


def test_cache_hits_across_serve_calls_do_not_exist(base):
    """Each serve call builds a fresh pool: nothing leaks between calls
    (a stale cross-call hit would reuse K/V from freed device memory)."""
    cfg, params = base
    eng = InferenceEngine(cfg, params, max_batch=2, block_size=4,
                          chunk_tokens=8)
    p = [np.arange(1, 14, dtype=np.int32)]
    r1 = eng.serve(p, SamplingParams(max_tokens=3))
    r2 = eng.serve(p, SamplingParams(max_tokens=3))
    assert r1.cache_hit_blocks == 0 and r2.cache_hit_blocks == 0
    np.testing.assert_array_equal(r1.outputs[0], r2.outputs[0])


def test_speculative_serve_identical_with_cache_on(base):
    """Speculation + prefix cache compose: greedy outputs unchanged, and
    speculative rollback never rewinds into a shared block."""
    cfg, _ = base
    plan = CompressionConfig(method="itera", weight_wl=8, rank_fraction=0.75)
    eng = InferenceEngine.build(cfg, plan, max_batch=3, block_size=4,
                                chunk_tokens=8,
                                speculate=DraftSpec(k=3, rank_fraction=0.7))
    prompts = _shared_workload(cfg.vocab_size, seed=3)
    sp = SamplingParams(max_tokens=6)
    off = eng.serve(prompts, sp, prefix_cache=False)
    on = eng.serve(prompts, sp, prefix_cache=True)
    assert on.spec_rounds > 0 and on.cache_hit_blocks > 0
    for i, (a, b) in enumerate(zip(off.outputs, on.outputs)):
        np.testing.assert_array_equal(b, a, err_msg=f"request {i}")


def test_preemption_under_pool_pressure_keeps_outputs_exact(base):
    """A pool sized so a co-admitted prefill row must yield its blocks:
    the victim requeues, everyone still finishes with solo-identical
    output, and the preemption is surfaced in ServeResult."""
    cfg, params = base
    eng = InferenceEngine(cfg, params, max_batch=3, block_size=4,
                          chunk_tokens=16)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (20, 18, 16)]
    gen = 3
    need = [blocks_needed(p.size, gen, 4) for p in prompts]
    # two rows' worth of blocks minus one: co-admitted prefills collide
    res = eng.serve(prompts, SamplingParams(max_tokens=gen),
                    num_blocks=need[0] + need[1])
    assert res.preemptions >= 1, "pool pressure never triggered preemption"
    solo = InferenceEngine(cfg, params, max_batch=3, block_size=4,
                           chunk_tokens=16)
    for i, p in enumerate(prompts):
        want = solo.generate(p[None], SamplingParams(max_tokens=gen)).tokens[0]
        np.testing.assert_array_equal(res.outputs[i], np.asarray(want),
                                      err_msg=f"request {i}")


def test_tp_serve_cache_identity_mesh2():
    """Cache-on == cache-off on a forced 2-device mesh (bf16 + int8 KV):
    the COW device copy moves along the block axis while the pool shards
    heads, so every shard copies exactly its own slice."""
    out = run_sub("""
        import dataclasses
        import numpy as np
        import jax
        from repro.api.engine import InferenceEngine, SamplingParams
        from repro.configs import get_config
        from repro.launch.mesh import make_serving_mesh
        from repro.models import transformer as tfm

        rng = np.random.default_rng(0)
        sp = SamplingParams(max_tokens=5)
        cfg = dataclasses.replace(get_config("opus-mt", smoke=True),
                                  dtype="bfloat16", kv_cache_bits=8)
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        prefix = rng.integers(1, cfg.vocab_size, size=12).astype(np.int32)
        prompts = [np.concatenate([prefix,
                                   rng.integers(1, cfg.vocab_size,
                                                size=2 + i % 3)
                                   .astype(np.int32)]) for i in range(5)]
        prompts.append(prefix.copy())     # fully-cached prompt -> COW
        eng = InferenceEngine.build(cfg, params=params,
                                    mesh=make_serving_mesh(2),
                                    max_batch=3, block_size=4,
                                    chunk_tokens=8)
        off = eng.serve(prompts, sp, prefix_cache=False)
        on = eng.serve(prompts, sp, prefix_cache=True)
        assert on.cache_hit_blocks > 0 and on.cache_cow_blocks >= 1
        for i, (a, b) in enumerate(zip(off.outputs, on.outputs)):
            assert np.array_equal(a, b), f"tp2 request {i}: {b} != {a}"
        print("TP_CACHE_OK")
        """)
    assert "TP_CACHE_OK" in out


# ----------------------------------------------------- analytical model --

def test_prefix_cache_point_monotone_in_hit_rate():
    """More cache hits never cost more: MACs and KV writeback saved are
    non-decreasing, priced prefill time non-increasing, TTFT speedup
    >= 1 — over the whole hit-rate range at several prompt lengths."""
    geom = dict(num_layers=4, d_model=256, d_ff=1024, num_heads=8,
                num_kv_heads=4, head_dim=32, block_size=16)
    for plen in (17, 256, 2048):
        prev = None
        for hr in np.linspace(0.0, 1.0, 9):
            pt = tpu_model.prefix_cache_point(plen, float(hr), **geom)
            assert pt.tokens_cached + pt.tokens_computed == plen
            assert pt.tokens_cached <= plen - 1, "last position always runs"
            assert pt.macs + pt.macs_saved == pytest.approx(pt.macs_nocache)
            assert pt.ttft_speedup >= 1.0
            if prev is not None:
                assert pt.macs_saved >= prev.macs_saved
                assert pt.kv_bytes_saved >= prev.kv_bytes_saved
                assert pt.prefill_s <= prev.prefill_s + 1e-12
            prev = pt
        assert prev.tokens_cached > 0, "full hit rate cached nothing"


def test_prefix_cache_point_kv_bits_and_validation():
    """int8 KV writes fewer bytes per token, so the bandwidth saved per
    cached token is smaller than bf16's; bad inputs are hard errors."""
    geom = dict(num_layers=4, d_model=256, d_ff=1024, num_heads=8,
                num_kv_heads=4, head_dim=32, block_size=16)
    p16 = tpu_model.prefix_cache_point(512, 0.75, kv_bits=16, **geom)
    p8 = tpu_model.prefix_cache_point(512, 0.75, kv_bits=8, **geom)
    assert p8.tokens_cached == p16.tokens_cached
    assert p8.kv_bytes_saved < p16.kv_bytes_saved
    assert p8.macs_saved == pytest.approx(p16.macs_saved)
    with pytest.raises(ValueError, match="prompt_len"):
        tpu_model.prefix_cache_point(0, 0.5, **geom)
    with pytest.raises(ValueError, match="hit_rate"):
        tpu_model.prefix_cache_point(64, 1.5, **geom)
    with pytest.raises(ValueError, match="kv_bits"):
        tpu_model.prefix_cache_point(64, 0.5, kv_bits=4, **geom)
