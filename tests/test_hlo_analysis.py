"""HLO-text analyzer validation: exact agreement with hand-computed costs
and with XLA's cost_analysis on loop-free programs."""
import jax
import jax.numpy as jnp

from repro.hw.hlo_analysis import HloModule, analyze


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_cost(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax returns a list
        ca = ca[0]
    return ca


def test_simple_dot_matches_xla():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    mine = analyze(c.as_text())["flops_per_device"]
    xla = _xla_cost(c)["flops"]
    assert mine == xla == 2 * 128 * 256 * 64


def test_chained_dots():
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 96), jnp.float32)
    c = jax.ShapeDtypeStruct((96, 16), jnp.float32)
    comp = _compile(lambda x, y, z: (x @ y) @ z, a, b, c)
    mine = analyze(comp.as_text())["flops_per_device"]
    assert mine == 2 * 32 * 64 * 96 + 2 * 32 * 96 * 16


def test_while_trip_count_multiplies():
    """A scan of 7 identical matmuls must cost 7x one matmul."""
    w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def f(w, x):
        def body(h, wl):
            return h @ wl, None
        h, _ = jax.lax.scan(body, x, w)
        return h

    c = _compile(f, w, x)
    mine = analyze(c.as_text())["flops_per_device"]
    assert mine == 7 * 2 * 8 * 64 * 64
    # XLA's aggregate counts the body once -> analyzer must exceed it
    assert mine > _xla_cost(c)["flops"]


def test_batched_dot_general():
    a = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)
    c = _compile(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
    mine = analyze(c.as_text())["flops_per_device"]
    assert mine == 2 * 4 * 16 * 32 * 8


def test_parser_handles_tuples_and_fusions():
    def f(x):
        y = jnp.sin(x) + jnp.cos(x)
        return y.sum(), y * 2

    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    res = analyze(c.as_text())
    assert res["flops_per_device"] == 0          # no dots
    assert res["mem_bytes_per_device"] > 128 * 128 * 4
    assert res["collective_bytes_per_device"] == 0


def test_module_structure():
    def f(w, x):
        def body(h, wl):
            return h @ wl, None
        return jax.lax.scan(body, x, w)[0]

    c = _compile(f, jax.ShapeDtypeStruct((3, 8, 8), jnp.float32),
                 jax.ShapeDtypeStruct((2, 8), jnp.float32))
    mod = HloModule(c.as_text())
    assert mod.entry is not None
    assert any("region" in k for k in mod.computations)
