"""Plan→engine API tests: JSON round-trips, per-layer mixed-precision
plans, validation, and the end-to-end DSE→deployment loop
(co_design -> DesignPoint -> from_design_point -> JSON -> Engine -> tokens).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CompressionPlan, InferenceEngine, LayerPlan, SamplingParams, merge_plans,
)
from repro.configs import get_config
from repro.core.compress import CompressionConfig, compress_params
from repro.hw import dse
from repro.models import init_params
from repro.models.transformer import forward


@pytest.fixture(scope="module")
def smoke():
    cfg = get_config("opus-mt", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ------------------------------------------------------------------ plan --
def test_uniform_plan_matches_config_shim(smoke):
    """CompressionConfig is a thin shim: lowering it to a uniform plan and
    executing either one must produce bit-identical compressed trees."""
    cfg, params = smoke
    ccfg = CompressionConfig(method="quant", weight_wl=4)
    plan = CompressionPlan.uniform(params, method="quant", weight_wl=4)
    cp_plan, rep_plan = compress_params(params, plan)
    cp_cfg, rep_cfg = compress_params(params, ccfg)
    assert _leaves_equal(cp_plan, cp_cfg)
    # both reports carry per-layer plan provenance
    assert rep_cfg.plan is not None
    assert [lp.to_dict() for lp in rep_cfg.plan] == \
           [lp.to_dict() for lp in rep_plan.plan]


def test_json_roundtrip_bit_identical(smoke):
    """serialize -> deserialize -> compress must be bit-identical to
    compressing with the original plan (the deployment artifact is exact)."""
    cfg, params = smoke
    plan = CompressionPlan.uniform(params, method="itera", weight_wl=4,
                                   rank_fraction=0.3, label="rt")
    restored = CompressionPlan.loads(plan.dumps())
    assert restored == plan
    cp1, _ = compress_params(params, plan)
    cp2, _ = compress_params(params, restored)
    assert _leaves_equal(cp1, cp2)


def test_plan_file_roundtrip(tmp_path, smoke):
    _, params = smoke
    plan = CompressionPlan.uniform(params, method="quant", weight_wl=6,
                                   label="disk")
    p = tmp_path / "plan.json"
    plan.save(str(p))
    assert CompressionPlan.load(str(p)) == plan


def test_mixed_precision_plan(smoke):
    """W4 attention / W8 MLP with differing ranks — inexpressible by the
    single-method CompressionConfig — compresses and runs end-to-end."""
    cfg, params = smoke
    base = CompressionPlan.uniform(params, method="itera", weight_wl=8,
                                   rank_fraction=0.5)
    mixed = base.replace(label="w4attn_w8mlp", layers=tuple(
        LayerPlan(lp.path, "itera",
                  4 if "attn" in lp.path else 8,
                  max(1, lp.rank // 2) if "attn" in lp.path else lp.rank)
        for lp in base.layers))
    assert len({lp.wl for lp in mixed.layers}) == 2, \
        "smoke model must yield both attn and mlp plan entries"
    cp, rep = compress_params(params, mixed)
    assert {lr.wl for lr in rep.layers} == {4, 8}
    assert len({lr.rank for lr in rep.layers}) > 1
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    h, _ = forward(cp, toks, cfg)
    assert bool(jnp.isfinite(h).all())


def test_merge_plans(smoke):
    _, params = smoke
    base = CompressionPlan.uniform(params, method="quant", weight_wl=8)
    override = LayerPlan(base.layers[0].path, "quant", 4)
    merged = merge_plans(base, [override])
    assert merged.layers[0].wl == 4
    assert all(lp.wl == 8 for lp in merged.layers[1:])
    assert len(merged) == len(base)


def test_validate_rejects_bad_plans(smoke):
    _, params = smoke
    good = CompressionPlan.uniform(params, method="itera", weight_wl=4,
                                   rank_fraction=0.5)
    path = good.layers[0].path
    with pytest.raises(ValueError, match="not found"):
        CompressionPlan(layers=(LayerPlan("no/such/weight", "quant", 8),)
                        ).validate(params)
    with pytest.raises(ValueError, match="exceeds"):
        CompressionPlan(layers=(LayerPlan(path, "itera", 4, rank=10_000),)
                        ).validate(params)
    with pytest.raises(ValueError, match="duplicate"):
        CompressionPlan(layers=(LayerPlan(path, "quant", 8),
                                LayerPlan(path, "quant", 4))).validate()
    with pytest.raises(ValueError, match="rank"):
        CompressionPlan(layers=(LayerPlan(path, "itera", 4),)).validate()
    with pytest.raises(ValueError, match="wl"):
        CompressionPlan(layers=(LayerPlan(path, "quant", 16),)).validate()
    with pytest.raises(ValueError, match="method"):
        CompressionPlan(layers=(LayerPlan(path, "magic", 8),)).validate()


# ---------------------------------------------------------------- engine --
def test_engine_greedy_deterministic(smoke):
    cfg, params = smoke
    eng = InferenceEngine.build(cfg, None, params=params)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                                 cfg.vocab_size)
    a = eng.generate(prompts, SamplingParams(max_tokens=6))
    b = eng.generate(prompts, SamplingParams(max_tokens=6))
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens.shape == (2, 6) and a.prompt_len == 12


def test_engine_sampling_modes(smoke):
    cfg, params = smoke
    eng = InferenceEngine.build(
        cfg, CompressionConfig(method="quant", weight_wl=8), params=params)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                                 cfg.vocab_size)
    out = eng.generate(prompts, SamplingParams(
        max_tokens=5, temperature=0.7, top_k=13, seed=7))
    assert out.tokens.shape == (2, 5)
    assert out.tokens.min() >= 0 and out.tokens.max() < cfg.vocab_size
    # same seed -> same sample; different seed -> (almost surely) different
    out2 = eng.generate(prompts, SamplingParams(
        max_tokens=5, temperature=0.7, top_k=13, seed=7))
    np.testing.assert_array_equal(out.tokens, out2.tokens)


def test_engine_prompt_length_bucketing(smoke):
    """Prompts are right-padded to power-of-two buckets before prefill:
    tokens stay identical to the unbucketed path, and N distinct prompt
    lengths compile O(log N) prefill variants instead of N."""
    cfg, params = smoke
    eng = InferenceEngine.build(cfg, None, params=params)
    ref = InferenceEngine(cfg, eng.params, bucket_prompts=False)
    assert eng.bucket_prompts and not ref.bucket_prompts
    rng = np.random.default_rng(11)
    lens = [5, 6, 7, 9, 11, 13, 15]                 # buckets: 8 and 16
    for n in lens:
        prompts = rng.integers(0, cfg.vocab_size, size=(2, n))
        a = eng.generate(prompts, SamplingParams(max_tokens=4))
        b = ref.generate(prompts, SamplingParams(max_tokens=4))
        np.testing.assert_array_equal(a.tokens, b.tokens)
    if hasattr(eng._prefill, "_cache_size"):        # jax-version dependent
        assert eng._prefill._cache_size() == 2      # one per bucket
        assert ref._prefill._cache_size() == len(lens)


def test_engine_accepts_ragged_requests(smoke):
    """Ragged prompt lists route through the continuous-batching scheduler
    and come back per-request, greedy-identical to solo generation."""
    cfg, params = smoke
    eng = InferenceEngine.build(cfg, None, params=params)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
    res = eng.generate(prompts, SamplingParams(max_tokens=3))
    assert res.tokens.shape == (3, 3)
    assert res.prompt_lens == [3, 2, 4] and res.prompt_len == 4
    for p, got in zip(prompts, res.tokens):
        solo = eng.generate(np.asarray([p]), SamplingParams(max_tokens=3))
        np.testing.assert_array_equal(got, solo.tokens[0])
    with pytest.raises(ValueError, match="empty"):
        eng.generate([], SamplingParams(max_tokens=2))
    with pytest.raises(ValueError, match="1-D"):    # no silent flattening
        eng.generate([np.zeros((2, 3), np.int32)], SamplingParams(max_tokens=2))


def test_co_design_rejects_dict_candidates(smoke):
    """Legacy dict candidates must fail loudly, not score at wrong wl."""
    _, params = smoke
    with pytest.raises(TypeError, match="CompressionPlan"):
        dse.co_design([{"label": "quant_W4", "wl": 4}],
                      quality_fn=lambda c: 0.0, params=params)


def test_serve_cli_consumes_plan_file(tmp_path, smoke):
    """launch.serve is a thin CLI over the engine: --plan plan.json."""
    from repro.launch import serve as serve_mod

    _, params = smoke
    plan = CompressionPlan.uniform(params, method="quant", weight_wl=6,
                                   label="cli")
    p = tmp_path / "plan.json"
    plan.save(str(p))
    toks = serve_mod.main([
        "--arch", "opus-mt", "--smoke", "--plan", str(p),
        "--prompt-len", "12", "--gen", "4", "--batch", "2",
    ])
    assert toks.shape == (2, 4)
    assert np.asarray(toks).min() >= 0


# ------------------------------------------------- DSE -> deployment loop --
def test_design_point_to_engine_end_to_end(smoke):
    """The ISSUE acceptance demo: co_design over plan candidates -> pick a
    Pareto DesignPoint -> CompressionPlan.from_design_point -> JSON round
    trip -> Engine.build -> generate returns tokens."""
    cfg, params = smoke
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0,
                              cfg.vocab_size)
    h_ref, _ = forward(params, toks, cfg)

    base = CompressionPlan.uniform(params, method="itera", weight_wl=4,
                                   rank_fraction=0.5, label="itera_W4")
    mixed = base.replace(label="mixed_w4_w8", layers=tuple(
        LayerPlan(lp.path, "itera",
                  4 if "attn" in lp.path else 8,
                  max(1, lp.rank // 2) if "attn" in lp.path else lp.rank)
        for lp in base.layers))
    candidates = [
        CompressionPlan.uniform(params, method="quant", weight_wl=8),
        base, mixed,
    ]

    def quality(plan):
        cp, rep = compress_params(params, plan)
        plan.meta["ratio"] = rep.compression_ratio
        h, _ = forward(cp, toks, cfg)
        return -float(jnp.linalg.norm(h - h_ref) / jnp.linalg.norm(h_ref))

    front = dse.co_design(candidates, quality, params=params, batch_m=64)
    assert front, "co_design returned an empty Pareto front"
    assert all(dp.plan is not None for dp in front)

    dp = front[-1]                              # highest-quality point
    plan = CompressionPlan.from_design_point(dp)
    assert plan.meta["design_point"] == dp.label
    assert plan.meta["latency"] == pytest.approx(dp.latency)
    restored = CompressionPlan.loads(plan.dumps())

    engine = InferenceEngine.build(cfg, restored, params=params)
    res = engine.generate(toks[:, :12], SamplingParams(max_tokens=4))
    assert res.tokens.shape == (2, 4)
    assert res.tokens.min() >= 0 and res.tokens.max() < cfg.vocab_size
    assert engine.report is not None and engine.report.plan is not None
