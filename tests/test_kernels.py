"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles,
in interpret mode (the kernel body executes on CPU exactly as written)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.itera import LowRankQ, itera_decompose, svd_decompose
from repro.core.quant import pack_weights, quant_linear_ref, quantize
from repro.kernels import ops, ref
from repro.kernels.lowrank_qmm import lowrank_qmm, vmem_bytes as lr_vmem
from repro.kernels.quant_matmul import quant_matmul, vmem_bytes as qm_vmem


def _pack_lr(lr: LowRankQ) -> LowRankQ:
    return LowRankQ(pack_weights(lr.w1), pack_weights(lr.w2))

SHAPES_QMM = [
    (8, 128, 128),       # minimal aligned
    (48, 192, 320),      # nothing divides the defaults -> padding path
    (256, 512, 512),     # the paper's workload (M=K=N=512 with batch 256)
    (1, 96, 640),        # decode-like M=1
    (130, 1024, 256),    # M just over a block
]


@pytest.mark.parametrize("m,k,n", SHAPES_QMM)
@pytest.mark.parametrize("wl", [4, 8])
def test_quant_matmul_vs_oracle(m, k, n, wl):
    key = jax.random.PRNGKey(m * 7 + k + n + wl)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    wq = quantize(w, wl, axis=0)
    y_kernel = ops.qmm(x, wq, use_kernel=True, interpret=True)
    y_oracle = ops.qmm(x, wq, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_out_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 256), jnp.float32)
    wq = quantize(jax.random.normal(key, (256, 128)) * 0.1, 8, axis=0)
    y = ops.qmm(x, wq, use_kernel=True, interpret=True, out_dtype=dtype)
    assert y.dtype == dtype


SHAPES_LR = [
    (8, 128, 128, 16),
    (48, 192, 320, 96),     # all-padding path
    (256, 512, 512, 128),   # paper Fig. 10 workload (rank 128)
    (1, 256, 512, 32),      # decode-like
    (64, 1024, 768, 200),   # rank not 128-aligned
]


@pytest.mark.parametrize("m,k,n,r", SHAPES_LR)
@pytest.mark.parametrize("wl", [4, 6, 8])
def test_lowrank_qmm_vs_oracle(m, k, n, r, wl):
    key = jax.random.PRNGKey(m + k + n + r + wl)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    lr = svd_decompose(w, r, wl)
    y_kernel = ops.lrmm(x, lr, use_kernel=True, interpret=True, fused=True)
    y_oracle = ops.lrmm(x, lr, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fused", [True, False])
def test_cascade_vs_single_engine_same_math(fused):
    """Single (unfused) and Cascade (fused) schedules agree bit-for-bit."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (32, 256), jnp.float32)
    w = jax.random.normal(key, (256, 384), jnp.float32) * 0.05
    lr = itera_decompose(w, 64, 6)
    y = ops.lrmm(x, lr, use_kernel=True, interpret=True, fused=fused)
    y_ref = ops.lrmm(x, lr, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_lowrank_error_vs_exact_small():
    """End-to-end quantized cascade stays close to the fp product."""
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (64, 512), jnp.float32)
    w = jax.random.normal(key, (512, 512), jnp.float32) / 22.6
    lr = itera_decompose(w, 256, 8)
    y = ops.lrmm(x, lr, use_kernel=True, interpret=True)
    y_exact = x @ (lr.w1.dequant() @ lr.w2.dequant())
    rel = float(jnp.linalg.norm(y - y_exact) / jnp.linalg.norm(y_exact))
    assert rel < 0.03


def test_batched_leading_dims():
    """ops wrappers accept (..., K) activations."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 5, 96), jnp.float32)
    wq = quantize(jax.random.normal(key, (96, 64)) * 0.1, 8, axis=0)
    y = ops.qmm(x, wq, use_kernel=True, interpret=True)
    assert y.shape == (2, 5, 64)
    lr = svd_decompose(jax.random.normal(key, (96, 64)) * 0.1, 16, 8)
    y2 = ops.lrmm(x, lr, use_kernel=True, interpret=True)
    assert y2.shape == (2, 5, 64)


def test_vmem_budget_respected():
    """Auto-chosen blocks keep the working set under the VMEM budget."""
    for (m, k, n, r) in [(4096, 18432, 73728, 512), (256, 512, 512, 128),
                         (1, 8192, 1024, 64)]:
        bm, bk, bn = ops.choose_blocks(m, k, n, r)
        assert lr_vmem(bm, bk, bn, r) <= ops.VMEM_BUDGET
        bm2, bk2, bn2 = ops.choose_blocks(m, k, n)
        assert qm_vmem(bm2, bk2, bn2) <= ops.VMEM_BUDGET
        for b, d in ((bk, 128), (bn, 128)):
            assert b % d == 0


# ------------------------------------------------------- packed residency --
@pytest.mark.parametrize("m,k,n", [(48, 192, 320), (8, 128, 128),
                                   (130, 1024, 256)])
@pytest.mark.parametrize("wl", [4, 6, 8])
def test_qmm_packed_identical_to_carrier(m, k, n, wl):
    """pack_weights never changes a single output bit: W4 moves to the
    packed-nibble layout and unpacks in-kernel; W6/W8 are no-op carriers."""
    key = jax.random.PRNGKey(m + n + wl)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    wq = quantize(w, wl, axis=0)
    wp = pack_weights(wq)
    # pack_weights only packs pad-ok axes; a pad-inflating N (e.g. 320,
    # 128) stays a carrier and wp is then wq itself — the identity below
    # still proves the no-op. The hand-built bad-axis case is covered by
    # test_qmm_forced_packed_bad_axis_demoted.
    from repro.core.quant import packed_pad_ok

    assert wp.packed == (wl == 4 and packed_pad_ok(n))
    if wp.packed:
        assert wp.values.shape == (k, n // 2)
    y_carrier = ops.qmm(x, wq, use_kernel=True, interpret=True)
    y_packed = ops.qmm(x, wp, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_packed),
                                  np.asarray(y_carrier))
    y_ref = ops.qmm(x, wp, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_qmm_forced_packed_bad_axis_demoted():
    """A hand-built packed tensor on a pad-inflating axis (something
    compress_params never produces) still computes bit-identically: the
    dispatch demotes it to a carrier up front instead of fat-padding."""
    import dataclasses

    from repro.core.quant import pack_int4

    key = jax.random.PRNGKey(3)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (8, 128), jnp.float32)
    w = jax.random.normal(kw, (128, 128), jnp.float32) / np.sqrt(128)
    wq = quantize(w, 4, axis=0)
    forced = dataclasses.replace(wq, values=pack_int4(wq.values),
                                 packed=True)
    y_carrier = ops.qmm(x, wq, use_kernel=True, interpret=True)
    y_forced = ops.qmm(x, forced, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_forced),
                                  np.asarray(y_carrier))
    # and the byte model charges the demotion round-trip, so a forced
    # pack can never *report* fewer bytes than its own carrier saves
    assert ops.qmm_hbm_bytes(8, forced) > ops.qmm_hbm_bytes(8, wq)


@pytest.mark.parametrize("fused", [True, False])
@pytest.mark.parametrize("wl", [4, 6, 8])
def test_lrmm_packed_identical_to_carrier(fused, wl):
    """Both cascade factors (W1 packed along R, W2 along N) stream packed
    and unpack in-kernel, bit-identical to the carrier path — in the fused
    cascade AND the two-launch single-engine schedule."""
    key = jax.random.PRNGKey(11 + wl)
    x = jax.random.normal(key, (48, 192), jnp.float32)
    # R=192 and N=512 are both pad-ok axes, so a W4 decomposition packs
    # both factors (pad-inflating axes would stay carriers — see
    # test_lrmm_forced_packed_bad_axes_demoted)
    w = jax.random.normal(key, (192, 512), jnp.float32) * 0.05
    lr = svd_decompose(w, 192, wl)
    lrp = _pack_lr(lr)
    assert lrp.w1.packed == (wl == 4) and lrp.w2.packed == (wl == 4)
    assert lrp.rank == 192 and lrp.w2.shape == (192, 512)
    y_carrier = ops.lrmm(x, lr, use_kernel=True, interpret=True, fused=fused)
    y_packed = ops.lrmm(x, lrp, use_kernel=True, interpret=True, fused=fused)
    np.testing.assert_array_equal(np.asarray(y_packed),
                                  np.asarray(y_carrier))
    y_ref = ops.lrmm(x, lrp, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fused", [True, False])
def test_lrmm_forced_packed_bad_axes_demoted(fused):
    """Hand-built packed factors on pad-inflating axes (R=96, N=320)
    still compute bit-identically through both schedules — the dispatch
    demotes them to carriers up front."""
    import dataclasses

    from repro.core.quant import pack_int4

    key = jax.random.PRNGKey(29)
    x = jax.random.normal(key, (48, 192), jnp.float32)
    w = jax.random.normal(key, (192, 320), jnp.float32) * 0.05
    lr = svd_decompose(w, 96, 4)

    def force(q):
        return dataclasses.replace(q, values=pack_int4(q.values),
                                   packed=True)

    lrp = LowRankQ(force(lr.w1), force(lr.w2))
    assert lrp.w1.packed and lrp.w2.packed
    y_carrier = ops.lrmm(x, lr, use_kernel=True, interpret=True, fused=fused)
    y_forced = ops.lrmm(x, lrp, use_kernel=True, interpret=True, fused=fused)
    np.testing.assert_array_equal(np.asarray(y_forced),
                                  np.asarray(y_carrier))
    assert ops.lrmm_hbm_bytes(48, lrp) > ops.lrmm_hbm_bytes(48, lr)


def test_lrmm_mixed_packing():
    """Odd rank leaves W1 carrier while W2 still packs — the dispatch
    handles each factor's layout independently."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (16, 128), jnp.float32)
    w = jax.random.normal(key, (128, 256), jnp.float32) * 0.1
    lr = svd_decompose(w, 25, 4)           # odd rank: w1 (128, 25) unpackable
    lrp = _pack_lr(lr)
    assert not lrp.w1.packed and lrp.w2.packed
    y = ops.lrmm(x, lrp, use_kernel=True, interpret=True)
    y_ref = ops.lrmm(x, lr, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_hbm_bytes_moved_packed_halves_weight_term():
    """The bytes-moved model shows the W4 win: packed weight traffic is
    half the carrier's, and the total strictly shrinks."""
    wq8 = quantize(jnp.ones((4096, 4096)), 8, axis=0)
    wq4 = pack_weights(quantize(jnp.ones((4096, 4096)), 4, axis=0))
    b8 = ops.qmm_hbm_bytes(8, wq8)
    b4 = ops.qmm_hbm_bytes(8, wq4)
    assert b4 < b8
    # decode-like M=8: weight streaming dominates, so packed ~halves total
    assert b4 < 0.6 * b8
    lr8 = svd_decompose(jnp.ones((1024, 1024)), 512, 8)
    lr4 = _pack_lr(svd_decompose(jnp.ones((1024, 1024)), 512, 4))
    assert ops.lrmm_hbm_bytes(8, lr4) < ops.lrmm_hbm_bytes(8, lr8)


# ------------------------------------------------------------- act_wl -----
def test_act_wl_honored_at_runtime():
    """A4 and A8 plans produce different outputs (the clamp really is
    qmax(act_wl)), and the A4 kernel agrees with quant_linear_ref A4."""
    key = jax.random.PRNGKey(7)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (32, 192), jnp.float32)
    w = jax.random.normal(kw, (192, 256), jnp.float32) * 0.1
    wq = quantize(w, 8, axis=0)                       # act_wl=8 default
    wq_a4 = dataclasses.replace(wq, act_wl=4)
    y8 = ops.qmm(x, wq, use_kernel=True, interpret=True)
    y4 = ops.qmm(x, wq_a4, use_kernel=True, interpret=True)
    assert not np.allclose(np.asarray(y8), np.asarray(y4))
    # kernel == ref oracle == quant_linear_ref at A4
    y4_ref = ops.qmm(x, wq_a4, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y4_ref),
                               rtol=1e-5, atol=1e-5)
    y4_gold = quant_linear_ref(x, w, 8, 4)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y4_gold),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fused", [True, False])
def test_act_wl_cascade_phase_boundary(fused):
    """The cascade's intermediate requant clamps to qmax(act_wl) too:
    A6 differs from A8 and matches the qm-threaded oracle."""
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (24, 256), jnp.float32)
    w = jax.random.normal(key, (256, 384), jnp.float32) * 0.05
    lr = itera_decompose(w, 64, 8)
    lr_a6 = LowRankQ(dataclasses.replace(lr.w1, act_wl=6),
                     dataclasses.replace(lr.w2, act_wl=6))
    y8 = ops.lrmm(x, lr, use_kernel=True, interpret=True, fused=fused)
    y6 = ops.lrmm(x, lr_a6, use_kernel=True, interpret=True, fused=fused)
    assert not np.allclose(np.asarray(y8), np.asarray(y6))
    y6_ref = ops.lrmm(x, lr_a6, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y6), np.asarray(y6_ref),
                               rtol=1e-5, atol=1e-5)


def test_single_engine_phase1_uses_kernel(monkeypatch):
    """lrmm(fused=False, use_kernel=True) must not fall back to the jnp
    reference for phase 1 — the engine-comparison bench measures
    kernel-vs-kernel."""
    calls = []
    orig = ops._qm.quant_matmul

    def counting(*a, **k):
        calls.append(k.get("w_packed", False))
        return orig(*a, **k)

    monkeypatch.setattr(ops._qm, "quant_matmul", counting)
    monkeypatch.setattr(
        ops._ref, "quant_matmul_ref",
        lambda *a, **k: (_ for _ in ()).throw(
            AssertionError("phase 1 took the jnp reference path")))
    key = jax.random.PRNGKey(17)
    x = jax.random.normal(key, (9, 136), jnp.float32)   # odd shapes: fresh trace
    w = jax.random.normal(key, (136, 264), jnp.float32) * 0.1
    lr = svd_decompose(w, 40, 8)
    y = ops.lrmm(x, lr, use_kernel=True, interpret=True, fused=False)
    assert len(calls) == 2                 # phase 1 AND phase 2 launches
    y_ref = ops.lrmm(x, lr, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_requant_rows_matches_kernel_phase_boundary():
    t = jnp.array([[0.5, -3.0, 2.0], [0.0, 0.0, 0.0]])
    tq, st = ref.requant_rows(t)
    assert tq.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(tq[1]), 0)
    np.testing.assert_allclose(np.asarray(tq.astype(np.float32) * st),
                               np.asarray(t), atol=3e-2)
