"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles,
in interpret mode (the kernel body executes on CPU exactly as written)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.itera import itera_decompose, svd_decompose
from repro.core.quant import quantize
from repro.kernels import ops, ref
from repro.kernels.lowrank_qmm import lowrank_qmm, vmem_bytes as lr_vmem
from repro.kernels.quant_matmul import quant_matmul, vmem_bytes as qm_vmem

SHAPES_QMM = [
    (8, 128, 128),       # minimal aligned
    (48, 192, 320),      # nothing divides the defaults -> padding path
    (256, 512, 512),     # the paper's workload (M=K=N=512 with batch 256)
    (1, 96, 640),        # decode-like M=1
    (130, 1024, 256),    # M just over a block
]


@pytest.mark.parametrize("m,k,n", SHAPES_QMM)
@pytest.mark.parametrize("wl", [4, 8])
def test_quant_matmul_vs_oracle(m, k, n, wl):
    key = jax.random.PRNGKey(m * 7 + k + n + wl)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    wq = quantize(w, wl, axis=0)
    y_kernel = ops.qmm(x, wq, use_kernel=True, interpret=True)
    y_oracle = ops.qmm(x, wq, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_out_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 256), jnp.float32)
    wq = quantize(jax.random.normal(key, (256, 128)) * 0.1, 8, axis=0)
    y = ops.qmm(x, wq, use_kernel=True, interpret=True, out_dtype=dtype)
    assert y.dtype == dtype


SHAPES_LR = [
    (8, 128, 128, 16),
    (48, 192, 320, 96),     # all-padding path
    (256, 512, 512, 128),   # paper Fig. 10 workload (rank 128)
    (1, 256, 512, 32),      # decode-like
    (64, 1024, 768, 200),   # rank not 128-aligned
]


@pytest.mark.parametrize("m,k,n,r", SHAPES_LR)
@pytest.mark.parametrize("wl", [4, 6, 8])
def test_lowrank_qmm_vs_oracle(m, k, n, r, wl):
    key = jax.random.PRNGKey(m + k + n + r + wl)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    lr = svd_decompose(w, r, wl)
    y_kernel = ops.lrmm(x, lr, use_kernel=True, interpret=True, fused=True)
    y_oracle = ops.lrmm(x, lr, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fused", [True, False])
def test_cascade_vs_single_engine_same_math(fused):
    """Single (unfused) and Cascade (fused) schedules agree bit-for-bit."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (32, 256), jnp.float32)
    w = jax.random.normal(key, (256, 384), jnp.float32) * 0.05
    lr = itera_decompose(w, 64, 6)
    y = ops.lrmm(x, lr, use_kernel=True, interpret=True, fused=fused)
    y_ref = ops.lrmm(x, lr, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_lowrank_error_vs_exact_small():
    """End-to-end quantized cascade stays close to the fp product."""
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (64, 512), jnp.float32)
    w = jax.random.normal(key, (512, 512), jnp.float32) / 22.6
    lr = itera_decompose(w, 256, 8)
    y = ops.lrmm(x, lr, use_kernel=True, interpret=True)
    y_exact = x @ (lr.w1.dequant() @ lr.w2.dequant())
    rel = float(jnp.linalg.norm(y - y_exact) / jnp.linalg.norm(y_exact))
    assert rel < 0.03


def test_batched_leading_dims():
    """ops wrappers accept (..., K) activations."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 5, 96), jnp.float32)
    wq = quantize(jax.random.normal(key, (96, 64)) * 0.1, 8, axis=0)
    y = ops.qmm(x, wq, use_kernel=True, interpret=True)
    assert y.shape == (2, 5, 64)
    lr = svd_decompose(jax.random.normal(key, (96, 64)) * 0.1, 16, 8)
    y2 = ops.lrmm(x, lr, use_kernel=True, interpret=True)
    assert y2.shape == (2, 5, 64)


def test_vmem_budget_respected():
    """Auto-chosen blocks keep the working set under the VMEM budget."""
    for (m, k, n, r) in [(4096, 18432, 73728, 512), (256, 512, 512, 128),
                         (1, 8192, 1024, 64)]:
        bm, bk, bn = ops.choose_blocks(m, k, n, r)
        assert lr_vmem(bm, bk, bn, r) <= ops.VMEM_BUDGET
        bm2, bk2, bn2 = ops.choose_blocks(m, k, n)
        assert qm_vmem(bm2, bk2, bn2) <= ops.VMEM_BUDGET
        for b, d in ((bk, 128), (bn, 128)):
            assert b % d == 0


def test_requant_rows_matches_kernel_phase_boundary():
    t = jnp.array([[0.5, -3.0, 2.0], [0.0, 0.0, 0.0]])
    tq, st = ref.requant_rows(t)
    assert tq.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(tq[1]), 0)
    np.testing.assert_allclose(np.asarray(tq.astype(np.float32) * st),
                               np.asarray(t), atol=3e-2)
