"""Sharding & distribution tests. Mesh-dependent cases run in a subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests in this
process must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_param_spec_rules_unit():
    """Pure-rule checks that need no real mesh: use a fake mesh object."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import spec_for

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    assert spec_for("layers/attn/wq", jnp.zeros((64, 5120, 5120)), m) == \
        P(None, "data", "model")
    assert spec_for("layers/mlp/down", jnp.zeros((64, 13824, 5120)), m) == \
        P(None, "model", "data")
    assert spec_for("embed", jnp.zeros((100352, 5120)), m) == P("model", None)
    assert spec_for("final_norm/gamma", jnp.zeros((5120,)), m) == P(None)
    # non-divisible non-head dims fall back to replicated
    assert spec_for("layers/mlp/up", jnp.zeros((100, 100)), m) == P(None, None)
    # GQA head dims keep 'model' (GSPMD padding is intended)
    assert spec_for("layers/attn/wk", jnp.zeros((3584, 2048)), m)[1] == "model"
    # low-rank factors
    assert spec_for("layers/mlp/up/w1/values", jnp.zeros((5120, 512)), m) == \
        P("data", "model")
    assert spec_for("layers/mlp/up/w2/values", jnp.zeros((512, 13824)), m) == \
        P(None, "model")


def test_moe_expert_rules():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.launch.sharding import spec_for

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    ds = get_config("deepseek-moe-16b")     # E=64 -> expert parallel
    assert spec_for("layers/moe/experts/up", jnp.zeros((64, 2048, 1408)),
                    m, ds) == P("model", "data", None)
    mx = get_config("mixtral-8x22b")        # E=8 -> tensor parallel
    assert spec_for("layers/moe/experts/up", jnp.zeros((8, 6144, 16384)),
                    m, mx) == P(None, "data", "model")


def test_small_mesh_train_and_decode_compile():
    run_sub("""
        import jax
        from repro.launch import steps
        from repro.launch.mesh import make_test_mesh
        from repro.runtime import shardctx
        from repro.models import set_linear_mode
        import repro.configs as C

        set_linear_mode("ref")
        orig = C.get_config
        steps.get_config = lambda a, smoke=False: orig(a, smoke=True)
        steps.SHAPES = {
            "train_4k": C.ShapeSpec("train_4k", 64, 8, "train"),
            "decode_32k": C.ShapeSpec("decode_32k", 64, 8, "decode"),
        }
        for arch in ["phi3-medium-14b", "mixtral-8x22b", "falcon-mamba-7b"]:
            for shape in ["train_4k", "decode_32k"]:
                with shardctx.use_mesh(mesh := make_test_mesh(2, 4)):
                    cell = steps.build_cell(arch, shape, mesh)
                    jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                            out_shardings=cell["out_shardings"],
                            donate_argnums=cell["donate_argnums"]
                            ).lower(*cell["args"]).compile()
                print(arch, shape, "OK")
    """)


def test_sharded_train_step_matches_single_device():
    """Same params+batch -> same loss on (1,1) mesh vs (2,4) mesh."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch import sharding as shd, steps
        from repro.launch.mesh import make_test_mesh
        from repro.models import transformer as tfm, set_linear_mode
        from repro.optim import adamw
        from repro.runtime import shardctx

        set_linear_mode("ref")
        cfg = get_config("opus-mt", smoke=True)
        opt_cfg = adamw.AdamWConfig()
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(key, cfg)
        opt = adamw.init(params, opt_cfg)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
        fn = steps.make_train_step(cfg, opt_cfg)

        losses = []
        for (d, m) in [(1, 1), (2, 4)]:
            mesh = make_test_mesh(d, m)
            with shardctx.use_mesh(mesh):
                ps = shd.param_shardings(params, mesh, cfg)
                os_ = shd.opt_shardings(opt, params, mesh, cfg)
                bs = shd.batch_shardings(batch, mesh)
                p = jax.device_put(params, ps)
                o = jax.device_put(opt, os_)
                b = jax.device_put(batch, bs)
                _, _, metrics = jax.jit(fn)(p, o, b)
                losses.append(float(metrics["loss"]))
        print("LOSSES", losses)
        assert abs(losses[0] - losses[1]) < 5e-3, losses
    """)
    assert "LOSSES" in out


def test_elastic_restore_across_meshes():
    run_sub("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import ckpt
        from repro.launch.mesh import make_test_mesh
        from repro.runtime.elastic import elastic_restore, shrink_mesh, viable_meshes
        from jax.sharding import NamedSharding, PartitionSpec as P

        tree = {"w": jnp.arange(64.).reshape(8, 8),
                "b": jnp.arange(8.)}
        with tempfile.TemporaryDirectory() as d:
            mesh_a = make_test_mesh(4, 2)
            pa = jax.device_put(tree, {"w": NamedSharding(mesh_a, P("data", "model")),
                                        "b": NamedSharding(mesh_a, P("data"))})
            ckpt.save(d, 5, pa)

            mesh_b = make_test_mesh(2, 2)   # different topology (4 devices)
            like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
            def spec_fn(path, leaf):
                return P("data", "model") if leaf.ndim == 2 else P("data")
            restored, step = elastic_restore(d, like, mesh_b, spec_fn)
            assert step == 5
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(tree["w"]))
            shards = restored["w"].sharding.device_set
            assert len(shards) == 4
        small = shrink_mesh(mesh_a, drop_axis="data")
        assert small.devices.size == 6
        assert (8, 1) in [(d_, m_) for d_, m_ in viable_meshes(8)]
        print("ELASTIC OK")
    """)


def test_multipod_mesh_axes():
    run_sub("""
        from repro.launch.mesh import make_test_mesh
        m = make_test_mesh(2, 2, pod=2)
        assert m.axis_names == ("pod", "data", "model")
        assert m.devices.shape == (2, 2, 2)
        from repro.runtime.shardctx import resolve_axis
        assert resolve_axis("batch", m) == ("pod", "data")
        assert resolve_axis("seq", m) == "model"
        print("MESH OK")
    """)
