"""Per-architecture smoke tests (reduced configs) + model-level invariants:
every assigned arch runs forward/loss/train-grad, prefill+decode matches
full forward, and the compressed (quant / ITERA) layouts run end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.compress import CompressionConfig, compress_params
from repro.models import init_params, loss_fn, prefill, decode_step
from repro.models.transformer import forward, logits_for

ALL_ARCHS = ARCH_IDS + ["opus-mt"]


def make_batch(cfg, key, b=2, s=16):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.frontend in ("audio", "vision"):
        emb = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        return {"inputs_embeds": emb, "labels": labels}, emb
    return {"tokens": toks, "labels": labels}, toks


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch, _ = make_batch(cfg, key)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    assert np.isfinite(float(metrics["ce"]))
    gnorm = sum(float(jnp.sum(jnp.square(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_prefill_decode_consistency(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    S, steps = 12, 2
    batch, inputs = make_batch(cfg, key, b=2, s=S + steps)

    h, _ = forward(params, inputs, cfg)
    ref = logits_for(params, h, cfg)

    lg, cache = prefill(params, inputs[:, :S], cfg, max_len=S + steps)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - ref[:, S - 1])))]
    for t in range(steps):
        if cfg.frontend in ("audio", "vision"):
            nxt = inputs[:, S + t: S + t + 1]
        else:
            nxt = inputs[:, S + t: S + t + 1]
        lg, cache = decode_step(params, cache, nxt, jnp.asarray(S + t), cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - ref[:, S + t]))))
    assert max(errs) < 5e-3, (arch, errs)


@pytest.mark.parametrize("method", ["quant", "svd", "itera"])
def test_compressed_model_runs(method):
    cfg = get_config("opus-mt", smoke=True)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    cp, report = compress_params(
        params, CompressionConfig(method=method, weight_wl=6,
                                  rank_fraction=0.6))
    # honest resident accounting: W6 has no byte-aligned packing, so it
    # stays an int8 carrier and a quant-only W6 model lands just under 4x
    assert report.compression_ratio > 3.5
    batch, inputs = make_batch(cfg, key)
    loss, _ = loss_fn(cp, batch, cfg)
    assert np.isfinite(float(loss))
    lg, cache = prefill(cp, inputs[:, :8], cfg, max_len=12)
    lg, _ = decode_step(cp, cache, inputs[:, 8:9], jnp.asarray(8), cfg)
    assert np.isfinite(np.asarray(lg)).all()


def test_compression_quality_ordering():
    """On a structured model, itera W4 ≥ svd W4 in output fidelity."""
    cfg = get_config("opus-mt", smoke=True)
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    batch, inputs = make_batch(cfg, key, b=4, s=32)
    h_ref, _ = forward(params, inputs, cfg)

    def distortion(method):
        cp, _ = compress_params(
            params, CompressionConfig(method=method, weight_wl=4,
                                      rank_fraction=0.5))
        h, _ = forward(cp, inputs, cfg)
        return float(jnp.linalg.norm(h - h_ref) / jnp.linalg.norm(h_ref))

    d_itera, d_svd = distortion("itera"), distortion("svd")
    assert d_itera <= d_svd * 1.05, (d_itera, d_svd)


def test_long_context_flags():
    assert get_config("falcon-mamba-7b").supports_long_context
    assert get_config("zamba2-2.7b").supports_long_context
    assert get_config("mixtral-8x22b").supports_long_context
    assert not get_config("phi3-medium-14b").supports_long_context
    assert not get_config("gemma2-9b").supports_long_context


def test_rolling_window_cache_decode():
    """SWA decode with pos far beyond the window stays finite & correct."""
    cfg = get_config("mixtral-8x22b", smoke=True)
    key = jax.random.PRNGKey(4)
    params = init_params(key, cfg)
    S = 20  # window is 8 -> rolling wraps twice
    toks = jax.random.randint(key, (1, S + 1), 0, cfg.vocab_size)
    h, _ = forward(params, toks, cfg)
    ref = logits_for(params, h, cfg)
    lg, cache = prefill(params, toks[:, :S], cfg, max_len=S + 1)
    err = float(jnp.max(jnp.abs(lg[:, 0] - ref[:, S - 1])))
    lg2, _ = decode_step(params, cache, toks[:, S:S + 1], jnp.asarray(S), cfg)
    err2 = float(jnp.max(jnp.abs(lg2[:, 0] - ref[:, S])))
    assert err < 5e-3 and err2 < 5e-3, (err, err2)


def test_param_counts_match_published():
    expected = {
        "mixtral-8x22b": 141e9, "deepseek-moe-16b": 16.4e9,
        "nemotron-4-340b": 340e9, "stablelm-12b": 12.1e9,
        "phi3-medium-14b": 14e9, "gemma2-9b": 9.2e9,
        "chameleon-34b": 34e9, "falcon-mamba-7b": 7.3e9,
    }
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.12, (arch, got, n)
