"""Paper Fig. 7: accuracy vs compression-ratio Pareto fronts for the four
methods — quant-only, SVD+quant, ITERA (ours), ITERA+SRA (ours). The
paper's claims checked here:
  * ITERA dominates SVD+quant across the ratio spectrum;
  * SRA adds the biggest gains at lower compression;
  * at W4A8 / comparable ratio, ITERA(+SRA) beats quant-only.
"""
from common import BLOCK_LINEARS, DecompCache, train_proxy, token_accuracy, csv_row
from repro.core.compress import CompressionConfig
from repro.core.sra import sra_allocate, uniform_allocation


def run_method(params, cfg, task, method, wl, rank_fracs, use_sra=False):
    dc = DecompCache(params, CompressionConfig(method="itera", weight_wl=wl, exclude=BLOCK_LINEARS))
    L = dc.num_layers
    full = max(dc.max_rank(p) for p in dc.targets)
    rows = []
    for frac in rank_fracs:
        budget = max(L, int(L * full * frac))
        if use_sra:
            def ev(ranks):
                cp = dc.compressed_params(params, list(ranks), method)
                return token_accuracy(cp, cfg, task, batches=2)

            res = sra_allocate(ev, L, budget, [full] * L,
                               delta0=max(1, full // 8), max_iters=12,
                               patience=4)
            ranks = res.ranks
        else:
            ranks = uniform_allocation(L, budget, [full] * L)
        cp = dc.compressed_params(params, ranks, method)
        acc = token_accuracy(cp, cfg, task)
        ratio, nops, dnops = dc.accounting(ranks, method)
        rows.append((ratio, acc, nops, dnops, ranks))
    return rows


def main():
    params, cfg, task = train_proxy()
    base = token_accuracy(params, cfg, task)
    csv_row("fig7_fp32", 0.0, f"acc={base:.4f};ratio=1.0")

    fracs = (0.9, 0.6, 0.4, 0.25)

    # quant-only reference points (ratio fixed by wl); W3/W2 extend into
    # the proxy's actual degradation region (see EXPERIMENTS.md note).
    quant_pts = {}
    for qwl in (8, 6, 4, 3, 2):
        dcq = DecompCache(params, CompressionConfig(method="quant",
                                                    weight_wl=qwl, exclude=BLOCK_LINEARS))
        cp = dcq.compressed_params(params, 0, "quant")
        acc = token_accuracy(cp, cfg, task)
        ratio, _, _ = dcq.accounting(0, "quant")
        quant_pts[qwl] = (ratio, acc)
        csv_row(f"fig7_quant_W{qwl}", 0.0, f"acc={acc:.4f};ratio={ratio:.2f}")

    for wl in (4, 2):
        results = {}
        for label, method, sra in (("svd", "svd", False),
                                   ("itera", "itera", False),
                                   ("itera_sra", "itera", True)):
            rows = run_method(params, cfg, task, method, wl, fracs,
                              use_sra=sra)
            results[label] = rows
            for ratio, acc, *_ in rows:
                csv_row(f"fig7_{label}_W{wl}_r{ratio:.1f}", 0.0,
                        f"acc={acc:.4f};ratio={ratio:.2f}")

        # claim checks at this word length
        it = {round(r[0], 1): r[1] for r in results["itera"]}
        sv = {round(r[0], 1): r[1] for r in results["svd"]}
        common_ratios = sorted(set(it) & set(sv))
        wins = sum(it[r] >= sv[r] - 0.005 for r in common_ratios)
        csv_row(f"fig7_claim_itera_ge_svd_W{wl}", 0.0,
                f"wins={wins}/{len(common_ratios)}")
        best_sra = max(r[1] for r in results["itera_sra"])
        best_it = max(r[1] for r in results["itera"])
        csv_row(f"fig7_claim_sra_gain_W{wl}", 0.0,
                f"best_sra={best_sra:.4f};best_itera={best_it:.4f};"
                f"gain={best_sra-best_it:+.4f}")
        # crossover vs quant-only at comparable ratio (the paper's Fig. 7
        # "region of interest"): compare itera points against the quant
        # point of equal-or-lower ratio.
        qr, qa = quant_pts[wl]
        near = [r for r in results["itera"] if r[0] >= qr * 0.95]
        if near:
            best = max(near, key=lambda r: r[1])
            csv_row(f"fig7_claim_vs_quant_W{wl}", 0.0,
                    f"itera_acc={best[1]:.4f}@ratio{best[0]:.1f};"
                    f"quant_acc={qa:.4f}@ratio{qr:.1f};"
                    f"delta={100*(best[1]-qa):+.2f}pts")


if __name__ == "__main__":
    main()
