"""Paper Fig. 12: per-layer occupancy of selected design points. Occupancy
= useful-MAC cycles / total latency (tile-padding + port stalls are the
loss terms). The paper observes <5% variation across layers and higher
occupancy in the bandwidth-limited regime (smaller tiles -> less padding).
"""
from common import BLOCK_LINEARS, csv_row, train_proxy, DecompCache
from repro.core.compress import CompressionConfig
from repro.hw import tpu_model as tm


def occupancy(point, m, k, n, r=None):
    macs = m * k * (r or n) + (m * r * n if r else 0)
    ideal_s = 2 * macs / tm.PEAK_OPS_INT8
    return ideal_s / point.latency_s


def main():
    params, cfg, task = train_proxy()
    dc = DecompCache(params, CompressionConfig(method="itera", weight_wl=4, exclude=BLOCK_LINEARS))
    m = 512
    for bw_scale, regime in ((1.0, "compute_bound"),
                             (0.25, "bandwidth_limited")):
        occs = []
        for (p, i), w in sorted(dc.mats.items()):
            k, n = int(w.shape[0]), int(w.shape[1])
            r = min(k, n) // 2
            pt = tm.best_point(m, k, n, r, weight_wl=4,
                               hbm_bw=tm.HBM_BW * bw_scale)
            occ = occupancy(pt, m, k, n, r)
            occs.append(occ)
            csv_row(f"fig12_{regime}_{p.replace('/', '.')}#{i}",
                    pt.latency_s * 1e6, f"occupancy={occ:.3f};"
                    f"engine={pt.kind}")
        spread = max(occs) - min(occs)
        csv_row(f"fig12_{regime}_spread", 0.0,
                f"min={min(occs):.3f};max={max(occs):.3f};"
                f"spread={spread:.3f}")


if __name__ == "__main__":
    main()
