"""Paper Fig. 9: generality across source->target language pairs (EN-DE,
FR-EN). Offline analog: two *different* seeded Markov worlds = two tasks;
the comparison at compression ratio ~8 (W4A8) mirrors the paper's bars:
quant-only vs ITERA (+1.2% claimed) vs ITERA+SRA (up to +4.9% claimed)."""
from common import BLOCK_LINEARS, DecompCache, train_proxy, token_accuracy, csv_row
from repro.core.compress import CompressionConfig
from repro.core.sra import sra_allocate


def matched_ratio_ranks(dc, L, full, target_ratio):
    """Largest uniform rank whose compression ratio >= the quant point's."""
    for r in range(full, 0, -1):
        ratio, _, _ = dc.accounting([r] * L, "itera")
        if ratio >= target_ratio:
            return [r] * L
    return [1] * L


def main():
    # W4 = the paper's operating point (above the proxy's degradation
    # threshold -> expect parity); W2 = the proxy's actual sub-precision
    # threshold, where the paper's crossover manifests (EXPERIMENTS.md).
    for pair, seed in (("EN-DE", 0), ("FR-EN", 1)):
        params, cfg, task = train_proxy(name=f"pair_{seed}", seed=seed)
        base = token_accuracy(params, cfg, task)
        for wl in (4, 2):
            dcq = DecompCache(params, CompressionConfig(
                method="quant", weight_wl=wl, exclude=BLOCK_LINEARS))
            acc_q = token_accuracy(
                dcq.compressed_params(params, 0, "quant"), cfg, task)
            ratio_q, _, _ = dcq.accounting(0, "quant")

            dc = DecompCache(params, CompressionConfig(
                method="itera", weight_wl=wl, exclude=BLOCK_LINEARS))
            L = dc.num_layers
            full = max(dc.max_rank(p) for p in dc.targets)
            ranks = matched_ratio_ranks(dc, L, full, ratio_q)
            acc_it = token_accuracy(
                dc.compressed_params(params, ranks, "itera"), cfg, task)

            budget = sum(ranks)

            def ev(rs):
                cp = dc.compressed_params(params, list(rs), "itera")
                return token_accuracy(cp, cfg, task, batches=2)

            res = sra_allocate(ev, L, budget, [full] * L,
                               delta0=max(1, full // 8), max_iters=12,
                               patience=4)
            acc_sra = token_accuracy(
                dc.compressed_params(params, res.ranks, "itera"), cfg, task)

            csv_row(f"fig9_{pair}_W{wl}", 0.0,
                    f"fp32={base:.4f};quant={acc_q:.4f}@r{ratio_q:.1f};"
                    f"itera={acc_it:.4f};itera_sra={acc_sra:.4f};"
                    f"itera_gain={100*(acc_it-acc_q):+.2f}pts;"
                    f"sra_gain={100*(acc_sra-acc_q):+.2f}pts")


if __name__ == "__main__":
    main()
