"""Serving throughput: in-flight batching (chunked prefill, unified
token-budget step) vs static rectangular batching.

Not a paper figure — ITERA-LLM stops at the compressed linear layer; this
benchmark extends the reproduction to the serving regime the ROADMAP
targets (cf. TensorRT-LLM inflight batching and the batching survey in
arXiv:2408.03130). Both modes run the SAME mixed-length synthetic
workload on the SAME compiled engine:

  * static     — requests grouped FCFS into rectangular batches; prompts
    right-padded to the group max, every row decodes until the group's
    longest request finishes (the pre-scheduler `generate` path);
  * continuous — `InferenceEngine.serve`: ONE jitted token-budget step
    per iteration that mixes prefill chunks of newly admitted prompts
    with in-flight decode rows over the blocked KV pool — admissions
    never stall decode (the old loop prefilled each admitted prompt
    alone while the whole decode batch waited; `mixed_steps` counts the
    steps where chunks and decode now overlap, the decode-stall
    elimination this benchmark exists to measure, and the TTFT/TPOT
    percentiles show where that time goes).

Throughput counts only *useful* tokens (each request's own max_tokens),
so static batching pays for its padding and tail steps. Emits
BENCH_serving.json; the acceptance bar is continuous >= static tok/s.

  PYTHONPATH=src:benchmarks python benchmarks/fig13_serving.py \
      --out BENCH_serving.json

  # CI smoke: tiny workload, seconds on CPU, asserts both modes agree
  PYTHONPATH=src:benchmarks python benchmarks/fig13_serving.py --smoke
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.api import DraftSpec, InferenceEngine, Request, SamplingParams
from repro.configs import get_config
from repro.core.compress import CompressionConfig, shape_spectra
from repro.models import transformer as tfm

# length buckets keep the number of distinct jit shapes small; the mix of
# short/long generations is what continuous batching exploits.
PROMPT_LENS = (8, 16, 24, 32)
GEN_LENS = (2, 4, 8, 24)

# the speculation section runs decode-heavy (short prompts, long
# generations): that is the regime where a round's k cheap draft passes
# amortize — prefill-heavy mixes leave no per-step budget for drafting.
SPEC_PROMPT_LENS = (8, 12, 16)
SPEC_GEN_LENS = (32, 48, 64)
SPEC_MAX_BATCH = 2
SPEC_CHUNK_TOKENS = 16
SPEC_ALPHA = 3.0          # power-law spectrum exponent for the proxy
SPEC_N = 8                # short admission queue: decode rounds, not
                          # prefill churn, must dominate the section


def make_workload(n, vocab, seed=0, prompt_lens=PROMPT_LENS,
                  gen_lens=GEN_LENS):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        plen = int(rng.choice(prompt_lens))
        gen = int(rng.choice(gen_lens))
        reqs.append(Request(tokens=rng.integers(0, vocab, size=plen),
                            max_tokens=gen))
    return reqs


def run_static(engine, reqs, max_batch):
    """FCFS rectangular groups: pad prompts to the group max (repeating
    each row's last token), decode to the group's longest request."""
    seconds = 0.0
    steps = 0
    for i in range(0, len(reqs), max_batch):
        group = reqs[i:i + max_batch]
        s = max(r.tokens.size for r in group)
        gen = max(r.max_tokens for r in group)
        batch = np.stack([np.pad(r.tokens, (0, s - r.tokens.size),
                                 mode="edge") for r in group])
        res = engine.generate(batch, SamplingParams(max_tokens=gen))
        seconds += res.seconds
        steps += gen
    useful = sum(r.max_tokens for r in reqs)
    return {"seconds": seconds, "decode_steps": steps,
            "useful_tokens": useful,
            "tokens_per_second": useful / max(seconds, 1e-9)}


def run_continuous(engine, reqs, max_batch, block_size, chunk_tokens):
    res = engine.serve(reqs, max_batch=max_batch, block_size=block_size,
                       chunk_tokens=chunk_tokens)
    return {"seconds": res.seconds, "steps": res.steps,
            "prefill_chunks": res.prefill_chunks,
            "prefill_tokens": res.prefill_tokens,
            "mixed_steps": res.mixed_steps,
            "chunk_tokens": res.chunk_tokens,
            "max_queue_depth": res.max_queue_depth,
            "ttft_p50_s": res.ttft_p50, "ttft_p95_s": res.ttft_p95,
            "tpot_p50_s": res.tpot_p50, "tpot_p95_s": res.tpot_p95,
            "useful_tokens": res.total_tokens,
            "tokens_per_second": res.tokens_per_second}, res.outputs


def run_speculation(args):
    """Self-speculative decoding section: the SAME low-rank engine served
    with its truncated-cascade draft model on vs off, decode-heavy
    workload. The reported speedup is real tokens per second, so
    rejected drafts are paid for honestly, and token identity vs the
    plain path is hard-asserted request by request on every run.

    This section pins its own regime instead of inheriting the timed
    comparison's, because speculation only ever pays where a decode step
    does NOT cost proportionally to the tokens it carries. On the TPU
    target that is ordinary decode (weight-streaming-bound: a width-k+1
    verify moves the same bytes as a width-1 step — the premise the
    paper's sub-8-bit residency work is built on). The CPU proxy at full
    size is the opposite — compute-bound, cost ∝ tokens, so every
    drafted-then-verified token is paid twice and NO draft can win; its
    dispatch-bound regime (smoke geometry, small batch) is the regime
    where step cost is ~flat, so that is what this section serves.

    The proxy's weights are spectrum-shaped before compression
    (`shape_spectra`): random-init matrices have near-flat singular
    spectra, which makes ANY rank truncation argmax-flipping — an
    artifact of the proxy, not a property of the trained weights the
    paper targets, whose decaying spectra are the reason low-rank
    compression works at all. Shaping restores that regime so the
    draft's acceptance rate measures the design, not init noise."""
    plan = CompressionConfig(method="svd", weight_wl=8, rank_fraction=0.75)
    spec = DraftSpec(k=args.speculate,
                     rank_fraction=args.draft_rank_fraction)
    cfg = get_config("opus-mt", smoke=True)
    params = shape_spectra(tfm.init_params(jax.random.PRNGKey(args.seed),
                                           cfg), alpha=SPEC_ALPHA)
    engine = InferenceEngine.build(cfg, plan, params=params,
                                   max_batch=SPEC_MAX_BATCH,
                                   block_size=args.block_size,
                                   chunk_tokens=SPEC_CHUNK_TOKENS,
                                   speculate=spec)
    n = min(args.n, SPEC_N)
    reqs = make_workload(n, engine.cfg.vocab_size, seed=args.seed,
                         prompt_lens=SPEC_PROMPT_LENS,
                         gen_lens=SPEC_GEN_LENS)
    engine.serve(reqs, speculate=False)                # warmup both modes
    engine.serve(reqs, speculate=True)
    base = on = None
    ratios = []
    # the section is seconds long, so extra paired repeats are cheap and
    # the median ratio needs them (smoke-scale walltime is noisy)
    for _ in range(max(args.repeat, 5)):
        r0 = engine.serve(reqs, speculate=False)
        r1 = engine.serve(reqs, speculate=True)
        mism = [i for i in range(len(reqs))
                if not np.array_equal(r0.outputs[i], r1.outputs[i])]
        assert not mism, (
            f"request {mism[0]}: speculative {r1.outputs[mism[0]]} "
            f"!= plain {r0.outputs[mism[0]]}")
        if base is None or r0.seconds < base.seconds:
            base = r0
        if on is None or r1.seconds < on.seconds:
            on = r1
        ratios.append(r1.tokens_per_second / r0.tokens_per_second)
    print(f"speculation: k={on.spec_k} accept {on.accept_rate:.2f} "
          f"({on.accepted}/{on.drafted} over {on.spec_rounds} rounds), "
          f"{on.tokens_per_second:.1f} tok/s vs "
          f"{base.tokens_per_second:.1f} plain "
          f"({float(np.median(ratios)):.2f}x); "
          f"{len(reqs)}/{len(reqs)} requests token-identical")
    return {
        "k": on.spec_k,
        "rank_fraction": args.draft_rank_fraction,
        "plan": "svd_W8_r0.75",
        "regime": {"model": cfg.name, "max_batch": SPEC_MAX_BATCH,
                   "chunk_tokens": SPEC_CHUNK_TOKENS,
                   "spectrum_alpha": SPEC_ALPHA},
        "workload": {"n": n, "prompt_lens": list(SPEC_PROMPT_LENS),
                     "gen_lens": list(SPEC_GEN_LENS), "seed": args.seed},
        "accept_rate": on.accept_rate,
        "drafted": on.drafted, "accepted": on.accepted,
        "spec_rounds": on.spec_rounds, "steps": on.steps,
        "baseline_steps": base.steps,
        "mismatched_requests": 0,
        "tokens_per_second": on.tokens_per_second,
        "baseline_tokens_per_second": base.tokens_per_second,
        "speedup_vs_plain": float(np.median(ratios)),
    }


# the prefix-cache section models production traffic: most requests open
# with the same long system prompt, so their full KV blocks are shared by
# reference and prefill restarts at the first uncached position. A small
# max_batch keeps first-step co-admissions (which cannot share: their
# blocks are unwritten) from diluting the hit rate the workload offers.
PC_PREFIX_LEN = 512
PC_PREFIX_LEN_SMOKE = 48
PC_SHARED_FRACTION = 0.9
PC_TAIL_LENS = (4, 8, 12, 16)
PC_GEN_LENS = (4, 8)
PC_N = 32
PC_MAX_BATCH = 3
PC_REPEAT = 2             # cache-off prefills ~n*prefix tokens per run


def make_shared_prefix_workload(n, vocab, prefix_len, seed=0,
                                shared_fraction=PC_SHARED_FRACTION,
                                tail_lens=PC_TAIL_LENS,
                                gen_lens=PC_GEN_LENS):
    """`shared_fraction` of n requests = the same `prefix_len`-token
    system prompt + a private tail; the rest are short unrelated prompts
    (arrival order shuffled, so sharers and strangers interleave)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=prefix_len)
    shared = np.zeros(n, bool)
    shared[:round(n * shared_fraction)] = True
    rng.shuffle(shared)
    reqs = []
    for s in shared:
        tail = int(rng.choice(tail_lens))
        toks = (np.concatenate([prefix, rng.integers(0, vocab, size=tail)])
                if s else rng.integers(0, vocab, size=2 * tail))
        reqs.append(Request(tokens=toks,
                            max_tokens=int(rng.choice(gen_lens))))
    return reqs


def run_prefix_cache(engine, args):
    """Prefix-caching section: the SAME engine serves the shared-prefix
    workload with the cache on vs off. Identity is hard-asserted request
    by request on every repeat (the cache must be a pure perf feature);
    the full-size run also hard-asserts the acceptance bar — hit rate
    > 0.8, better TTFT p50, higher tok/s — while --smoke only checks
    identity and a nonzero hit rate (its workload is too small for the
    0.8 bar). The pool gets headroom beyond the worst-case rows so the
    shared prefix blocks survive request rotation instead of being
    LRU-evicted the moment their holders finish."""
    from repro.hw.tpu_model import prefix_cache_point

    prefix_len = PC_PREFIX_LEN_SMOKE if args.smoke else PC_PREFIX_LEN
    n = min(args.n, 8) if args.smoke else PC_N
    cap = min(args.max_batch, PC_MAX_BATCH)
    bs = args.block_size
    cfg = engine.cfg
    reqs = make_shared_prefix_workload(n, cfg.vocab_size, prefix_len,
                                       seed=args.seed)
    from repro.runtime.kvblocks import blocks_needed

    mb = max(blocks_needed(r.tokens.size, r.max_tokens, bs) for r in reqs)
    kw = dict(max_batch=cap, block_size=bs, chunk_tokens=args.chunk_tokens,
              num_blocks=cap * mb + prefix_len // bs + 1)
    engine.serve(reqs, prefix_cache=False, **kw)       # warmup both modes
    engine.serve(reqs, prefix_cache=True, **kw)
    base = on = None
    ratios = []
    for _ in range(max(min(args.repeat, PC_REPEAT), 1)):
        r0 = engine.serve(reqs, prefix_cache=False, **kw)
        r1 = engine.serve(reqs, prefix_cache=True, **kw)
        mism = [i for i in range(len(reqs))
                if not np.array_equal(r0.outputs[i], r1.outputs[i])]
        assert not mism, (
            f"request {mism[0]}: cache-on {r1.outputs[mism[0]]} "
            f"!= cache-off {r0.outputs[mism[0]]}")
        if base is None or r0.seconds < base.seconds:
            base = r0
        if on is None or r1.seconds < on.seconds:
            on = r1
        ratios.append(r1.tokens_per_second / r0.tokens_per_second)
    hit_rate = on.cache_hit_token_rate
    if args.smoke:
        assert hit_rate > 0.0, "smoke shared-prefix workload never hit"
    else:
        assert hit_rate > 0.8, f"hit rate {hit_rate:.3f} <= 0.8"
        assert on.ttft_p50 < base.ttft_p50, (
            f"TTFT p50 did not improve: {on.ttft_p50:.3f}s cached vs "
            f"{base.ttft_p50:.3f}s")
        assert on.tokens_per_second > base.tokens_per_second, (
            f"tok/s did not improve: {on.tokens_per_second:.1f} cached "
            f"vs {base.tokens_per_second:.1f}")
    point = prefix_cache_point(
        prefix_len, hit_rate, num_layers=cfg.num_layers,
        d_model=cfg.d_model, d_ff=cfg.d_ff, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        block_size=bs, kv_bits=getattr(cfg, "kv_cache_bits", 16))
    print(f"prefix cache: hit rate {hit_rate:.2f} "
          f"({on.cache_hit_blocks}/{on.cache_lookup_blocks} blocks, "
          f"{on.cache_hit_tokens} prompt tokens skipped, "
          f"{on.cache_blocks_saved} blocks saved, {on.cache_cow_blocks} "
          f"COW), {on.tokens_per_second:.1f} tok/s vs "
          f"{base.tokens_per_second:.1f} off "
          f"({float(np.median(ratios)):.2f}x), TTFT p50 "
          f"{on.ttft_p50 * 1e3:.0f}ms vs {base.ttft_p50 * 1e3:.0f}ms; "
          f"{len(reqs)}/{len(reqs)} requests token-identical")
    return {
        "workload": {"n": n, "prefix_len": prefix_len,
                     "shared_fraction": PC_SHARED_FRACTION,
                     "tail_lens": list(PC_TAIL_LENS),
                     "gen_lens": list(PC_GEN_LENS), "seed": args.seed,
                     "max_batch": cap, "block_size": bs,
                     "num_blocks": kw["num_blocks"]},
        "hit_rate": hit_rate,
        "hit_rate_blocks": on.cache_hit_rate,
        "cache_hit_tokens": on.cache_hit_tokens,
        "cache_hit_blocks": on.cache_hit_blocks,
        "cache_lookup_blocks": on.cache_lookup_blocks,
        "blocks_saved": on.cache_blocks_saved,
        "cow_blocks": on.cache_cow_blocks,
        "evictions": on.cache_evictions,
        "preemptions": on.preemptions,
        "mismatched_requests": 0,
        "ttft_p50_s_on": on.ttft_p50, "ttft_p95_s_on": on.ttft_p95,
        "ttft_p50_s_off": base.ttft_p50, "ttft_p95_s_off": base.ttft_p95,
        "tokens_per_second_on": on.tokens_per_second,
        "tokens_per_second_off": base.tokens_per_second,
        "speedup_vs_off": float(np.median(ratios)),
        "modeled": {"prefill_s": point.prefill_s,
                    "prefill_s_nocache": point.prefill_s_nocache,
                    "ttft_speedup": point.ttft_speedup,
                    "macs_saved": point.macs_saved,
                    "kv_bytes_saved": point.kv_bytes_saved},
    }


SAMPLE_TEMP = 0.8
SAMPLE_TOP_K = 40
SAMPLE_TOP_P = 0.95


def run_sampling(engine, reqs, args):
    """Sampled-serving section: the SAME engine serves the SAME workload
    greedy vs sampled (per-row temperature/top_k/top_p riding the one
    packed dispatch buffer — the fused step's only extra work is the
    static top-k candidate window). Three properties are hard-asserted
    on every run:

      * seeded reproducibility — two sampled serves under one seed are
        token-identical request by request;
      * temperature-0 identity — greedy rows inside a mixed sampled
        batch emit bit-identical tokens to the all-greedy serve (the
        fused sample branch reduces exactly to argmax for them);
      * at full size, sampled throughput >= 0.95x greedy tok/s (the
        acceptance bar: sampling must not fall off the greedy path).
        The ratio compares each mode's FASTEST run over the paired
        repeats (timeit-style min): serve-to-serve walltime on a
        shared box swings ~10%, which additive load noise explains and
        a per-pair median at small N cannot reject, while the ~2%
        true sampler cost is exactly what best-vs-best resolves.

    --smoke checks the identities only; smoke-scale walltime is
    dispatch noise, not a throughput claim. `hw.tpu_model.sampling_point`
    prices the fused selection against the host round-trip alternative
    (full logits over PCIe + a second dispatch per step) at this
    geometry — the comparison the fused design wins by construction."""
    from repro.hw.tpu_model import sampling_point

    cfg = engine.cfg
    sampled_sp = SamplingParams(
        max_tokens=max(r.max_tokens for r in reqs),
        temperature=SAMPLE_TEMP, top_k=SAMPLE_TOP_K, top_p=SAMPLE_TOP_P,
        seed=args.seed)

    def with_sampling(temp_every_other=False):
        return [Request(tokens=r.tokens, max_tokens=r.max_tokens,
                        temperature=0.0 if temp_every_other and i % 2
                        else SAMPLE_TEMP, top_k=SAMPLE_TOP_K,
                        top_p=SAMPLE_TOP_P, seed=args.seed)
                for i, r in enumerate(reqs)]

    engine.serve(reqs)                                 # warmup both modes
    engine.serve(with_sampling(), sampled_sp)
    greedy = samp = None
    ratios = []
    for _ in range(max(args.repeat, 1) if args.smoke
                   else max(args.repeat, 5)):
        r0 = engine.serve(reqs)
        r1 = engine.serve(with_sampling(), sampled_sp)
        r2 = engine.serve(with_sampling(), sampled_sp)
        mism = [i for i in range(len(reqs))
                if not np.array_equal(r1.outputs[i], r2.outputs[i])]
        assert not mism, (
            f"request {mism[0]}: seeded sampled serve not reproducible: "
            f"{r2.outputs[mism[0]]} != {r1.outputs[mism[0]]}")
        if greedy is None or r0.seconds < greedy.seconds:
            greedy = r0
        if samp is None or r1.seconds < samp.seconds:
            samp = r1
        ratios.append(r1.tokens_per_second / r0.tokens_per_second)
    # greedy rows in a mixed batch == the all-greedy serve, bit for bit
    mixed = engine.serve(with_sampling(temp_every_other=True), sampled_sp)
    for i in range(1, len(reqs), 2):
        assert np.array_equal(mixed.outputs[i], greedy.outputs[i]), (
            f"request {i}: temperature-0 row diverged from greedy serve: "
            f"{mixed.outputs[i]} != {greedy.outputs[i]}")
    ratio = samp.tokens_per_second / greedy.tokens_per_second
    if not args.smoke:
        assert ratio >= 0.95, (
            f"sampled serve {ratio:.3f}x greedy tok/s < 0.95x bar "
            f"({samp.tokens_per_second:.1f} vs "
            f"{greedy.tokens_per_second:.1f})")
    point = sampling_point(batch=args.max_batch, vocab=cfg.vocab_size)
    print(f"sampled:    {samp.tokens_per_second:8.1f} tok/s vs "
          f"{greedy.tokens_per_second:.1f} greedy ({ratio:.2f}x), "
          f"queue p50 {samp.queue_p50 * 1e3:.0f}ms, seeded runs + "
          f"temperature-0 rows token-identical; fused selection "
          f"{point.speedup_vs_host:.0f}x over host round-trip (modeled)")
    return {
        "temperature": SAMPLE_TEMP, "top_k": SAMPLE_TOP_K,
        "top_p": SAMPLE_TOP_P, "seed": args.seed,
        "reproducible_requests": len(reqs),
        "mismatched_requests": 0,
        "steps": samp.steps,
        "tokens_per_second": samp.tokens_per_second,
        "greedy_tokens_per_second": greedy.tokens_per_second,
        "throughput_vs_greedy": ratio,
        "paired_ratio_median": float(np.median(ratios)),
        "queue_p50_s": samp.queue_p50, "queue_p95_s": samp.queue_p95,
        "goodput_tok_per_s_at_2x_median": samp.goodput(
            2 * float(np.median(samp.finish_times))),
        "modeled": {"fused_s": point.fused_s, "host_s": point.host_s,
                    "speedup_vs_host": point.speedup_vs_host,
                    "overhead_vs_greedy": point.overhead_vs_greedy},
    }


TP_N = 8                  # requests in the TP section: identity + bytes
TP_REPEAT = 2             # accounting, not a perf claim (see run_tp)


def run_tp(args):
    """Tensor-parallel serving section: the SAME weights served through
    the shard_map engine on a (1, --mesh) device mesh vs the
    single-device engine, greedy outputs hard-asserted token-identical
    request by request on every run.

    On CPU the "devices" are forced host-platform devices sharing one
    processor (XLA_FLAGS=--xla_force_host_platform_device_count), so the
    tok/s column is a bookkeeping canary, NOT a scaling claim — the row
    that matters for the DSE is the communication side:
    `hw.tpu_model.tp_point` prices the step's 2L boundary all-reduces
    (ring wire bytes per chip, ICI seconds) at this geometry, which is
    what a real multi-chip deployment pays."""
    from repro.hw import tpu_model
    from repro.launch.mesh import make_serving_mesh

    if len(jax.devices()) < args.mesh:
        raise SystemExit(
            f"--mesh {args.mesh} needs {args.mesh} devices, have "
            f"{len(jax.devices())}: run under XLA_FLAGS=--xla_force_"
            f"host_platform_device_count={args.mesh}")
    cfg = get_config("opus-mt", smoke=args.smoke)
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)
    n = min(args.n, TP_N)
    reqs = make_workload(n, cfg.vocab_size, seed=args.seed)
    kw = dict(params=params, max_batch=args.max_batch,
              block_size=args.block_size, chunk_tokens=args.chunk_tokens)
    solo = InferenceEngine.build(cfg, None, **kw)
    tp = InferenceEngine.build(cfg, None, mesh=make_serving_mesh(args.mesh),
                               **kw)
    solo.serve(reqs)                                   # warmup both engines
    tp.serve(reqs)
    base = on = None
    for _ in range(max(min(args.repeat, TP_REPEAT), 1)):
        r0 = solo.serve(reqs)
        r1 = tp.serve(reqs)
        mism = [i for i in range(len(reqs))
                if not np.array_equal(r0.outputs[i], r1.outputs[i])]
        assert not mism, (
            f"request {mism[0]}: tp={args.mesh} {r1.outputs[mism[0]]} "
            f"!= single-device {r0.outputs[mism[0]]}")
        if base is None or r0.seconds < base.seconds:
            base = r0
        if on is None or r1.seconds < on.seconds:
            on = r1
    import jax.numpy as jnp

    point = tpu_model.tp_point(
        batch=args.max_batch, span_w=1, d_model=cfg.d_model,
        num_layers=cfg.num_layers, tp=args.mesh,
        dtype_bytes=jnp.dtype(cfg.dtype).itemsize)
    print(f"tp: mesh {args.mesh}, {on.tokens_per_second:.1f} tok/s vs "
          f"{base.tokens_per_second:.1f} single-device, "
          f"{point.allreduce_bytes / 1024:.1f} KiB all-reduce wire/chip/"
          f"step ({point.boundaries} boundaries, "
          f"{point.allreduce_s * 1e6:.1f}us ICI); "
          f"{len(reqs)}/{len(reqs)} requests token-identical")
    return {
        "mesh": args.mesh,
        "model": cfg.name,
        "workload": {"n": n, "prompt_lens": list(PROMPT_LENS),
                     "gen_lens": list(GEN_LENS), "seed": args.seed,
                     "max_batch": args.max_batch,
                     "block_size": args.block_size,
                     "chunk_tokens": args.chunk_tokens},
        "identical_requests": n,
        "mismatched_requests": 0,
        "steps": on.steps,
        "tokens_per_second": on.tokens_per_second,
        "baseline_tokens_per_second": base.tokens_per_second,
        "allreduce_boundaries_per_step": point.boundaries,
        "allreduce_payload_bytes": point.payload_bytes,
        "allreduce_bytes_per_step": point.allreduce_bytes,
        "allreduce_s_per_step": point.allreduce_s,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24, help="number of requests")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--chunk-tokens", type=int, default=256,
                    help="unified-step token budget")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=3,
                    help="timed repetitions per mode; the fastest run "
                         "is reported (wall-clock noise rejection)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload (seconds on CPU): fewer "
                         "requests, one warmup, and a hard assert that "
                         "greedy outputs match between the two modes")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="also benchmark self-speculative decoding at "
                         "draft depth K on a low-rank engine "
                         "(dedicated dispatch-bound decode-heavy "
                         "regime, spec on vs off; outputs are asserted "
                         "token-identical)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="also benchmark tensor-parallel serving on a "
                         "(1, N) device mesh: greedy outputs are hard-"
                         "asserted token-identical to the single-device "
                         "engine and the step's all-reduce traffic is "
                         "priced by hw.tpu_model.tp_point (needs N "
                         "devices; on CPU force them with XLA_FLAGS=--"
                         "xla_force_host_platform_device_count=N)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="also benchmark prefix caching on a workload "
                         "where 90%% of requests share a long system "
                         "prompt: cache on vs off on the same engine, "
                         "outputs hard-asserted token-identical, hit "
                         "rate / blocks saved / TTFT / tok/s recorded")
    ap.add_argument("--sample", action="store_true",
                    help="also benchmark sampled serving (per-row "
                         "temperature/top_k/top_p fused into the one "
                         "jitted step): seeded reproducibility and "
                         "temperature-0 bit-identity are hard-asserted "
                         "on every run, and at full size sampled tok/s "
                         "must stay >= 0.95x greedy")
    ap.add_argument("--draft-rank-fraction", type=float, default=0.17,
                    help="rank fraction the speculation draft keeps "
                         "(0.17 of the r0.75 plan's rank 48 = rank 8 at "
                         "the section's geometry: the draft streams ~1/6 "
                         "of the cascade bytes, and the shaped spectrum "
                         "keeps its argmax agreeing with the full rank)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.n = min(args.n, 8)
        args.max_batch = min(args.max_batch, 2)
        args.chunk_tokens = min(args.chunk_tokens, 16)
        args.repeat = 1

    # the timed comparison runs the FULL-SIZE proxy (d=512, 12 layers,
    # 32k vocab): at smoke-model scale every step is host-overhead-bound
    # and the tok/s ratio measures dispatch noise, not serving design.
    # --smoke keeps the tiny config for the CI smoke job (seconds, CPU).
    engine = InferenceEngine.build("opus-mt", None, smoke=args.smoke,
                                   max_batch=args.max_batch,
                                   block_size=args.block_size,
                                   chunk_tokens=args.chunk_tokens)
    reqs = make_workload(args.n, engine.cfg.vocab_size, seed=args.seed)

    # warmup pass compiles every (shape-bucketed) prefill/step variant so
    # the timed passes measure steady-state serving, not XLA compilation
    run_static(engine, reqs, args.max_batch)
    run_continuous(engine, reqs, args.max_batch, args.block_size,
                   args.chunk_tokens)

    # repeats run the two modes back to back, so each pair sees the same
    # background load; the reported speedup is the median of the paired
    # ratios (robust to load drift), absolute numbers are each mode's
    # fastest run.
    static = ct_out = cont = None
    ratios = []
    for _ in range(max(args.repeat, 1)):
        st = run_static(engine, reqs, args.max_batch)
        if static is None or st["seconds"] < static["seconds"]:
            static = st
        ct, out = run_continuous(engine, reqs, args.max_batch,
                                 args.block_size, args.chunk_tokens)
        if cont is None or ct["seconds"] < cont["seconds"]:
            cont, ct_out = ct, out
        ratios.append(ct["tokens_per_second"] / st["tokens_per_second"])
    speedup = float(np.median(ratios))

    if args.smoke:
        # greedy serve outputs must match per-prompt solo runs — the
        # serve loop can't silently rot behind a green tok/s number.
        # (Static-mode outputs are not the oracle: its edge-padding
        # extends short prompts, legitimately changing their tokens.)
        for i, r in enumerate(reqs):
            solo = engine.generate(
                np.asarray(r.tokens)[None],
                SamplingParams(max_tokens=r.max_tokens)).tokens[0]
            assert np.array_equal(np.asarray(ct_out[i]), solo), (
                f"request {i}: continuous {np.asarray(ct_out[i])} "
                f"!= solo {solo}")
        print(f"smoke: continuous outputs == solo generate for "
              f"{len(reqs)} requests")

    report = {
        "workload": {"n": args.n, "prompt_lens": list(PROMPT_LENS),
                     "gen_lens": list(GEN_LENS), "seed": args.seed,
                     "max_batch": args.max_batch,
                     "block_size": args.block_size,
                     "chunk_tokens": args.chunk_tokens},
        "static": static,
        "continuous": cont,
        "speedup": speedup,
    }
    if args.sample:
        report["sampled"] = run_sampling(engine, reqs, args)
    if args.shared_prefix:
        report["prefix_cache"] = run_prefix_cache(engine, args)
    if args.speculate > 0:
        report["speculation"] = run_speculation(args)
    if args.mesh > 0:
        report["tp"] = run_tp(args)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"static:     {static['tokens_per_second']:8.1f} tok/s "
          f"({static['decode_steps']} decode steps)")
    print(f"continuous: {cont['tokens_per_second']:8.1f} tok/s "
          f"({cont['steps']} unified steps, {cont['mixed_steps']} mixed, "
          f"{cont['prefill_chunks']} prefill chunks)")
    print(f"latency:    TTFT p50 {cont['ttft_p50_s'] * 1e3:.0f}ms / "
          f"p95 {cont['ttft_p95_s'] * 1e3:.0f}ms, "
          f"TPOT p50 {cont['tpot_p50_s'] * 1e3:.1f}ms")
    print(f"speedup:    {speedup:.2f}x  -> {args.out}")
    return report


if __name__ == "__main__":
    main()
