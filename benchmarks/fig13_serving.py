"""Serving throughput: continuous batching vs static rectangular batching.

Not a paper figure — ITERA-LLM stops at the compressed linear layer; this
benchmark extends the reproduction to the serving regime the ROADMAP
targets (cf. TensorRT-LLM inflight batching and the batching survey in
arXiv:2408.03130). Both modes run the SAME mixed-length synthetic
workload on the SAME compiled engine:

  * static     — requests grouped FCFS into rectangular batches; prompts
    right-padded to the group max, every row decodes until the group's
    longest request finishes (the pre-scheduler `generate` path);
  * continuous — `InferenceEngine.serve`: individual prefills, a shared
    masked decode batch over the blocked KV pool, rows admitted/evicted
    mid-flight.

Throughput counts only *useful* tokens (each request's own max_tokens),
so static batching pays for its padding and tail steps. Emits
BENCH_serving.json; the acceptance bar is continuous >= static tok/s.

  PYTHONPATH=src:benchmarks python benchmarks/fig13_serving.py \
      --out BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.api import InferenceEngine, Request, SamplingParams

# length buckets keep the number of distinct jit shapes small; the mix of
# short/long generations is what continuous batching exploits.
PROMPT_LENS = (8, 16, 24, 32)
GEN_LENS = (2, 4, 8, 24)


def make_workload(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        plen = int(rng.choice(PROMPT_LENS))
        gen = int(rng.choice(GEN_LENS))
        reqs.append(Request(tokens=rng.integers(0, vocab, size=plen),
                            max_tokens=gen))
    return reqs


def run_static(engine, reqs, max_batch):
    """FCFS rectangular groups: pad prompts to the group max (repeating
    each row's last token), decode to the group's longest request."""
    seconds = 0.0
    steps = 0
    for i in range(0, len(reqs), max_batch):
        group = reqs[i:i + max_batch]
        s = max(r.tokens.size for r in group)
        gen = max(r.max_tokens for r in group)
        batch = np.stack([np.pad(r.tokens, (0, s - r.tokens.size),
                                 mode="edge") for r in group])
        res = engine.generate(batch, SamplingParams(max_tokens=gen))
        seconds += res.seconds
        steps += gen
    useful = sum(r.max_tokens for r in reqs)
    return {"seconds": seconds, "decode_steps": steps,
            "useful_tokens": useful,
            "tokens_per_second": useful / max(seconds, 1e-9)}


def run_continuous(engine, reqs, max_batch, block_size):
    res = engine.serve(reqs, max_batch=max_batch, block_size=block_size)
    return {"seconds": res.seconds, "decode_steps": res.steps,
            "prefills": res.prefills,
            "max_queue_depth": res.max_queue_depth,
            "useful_tokens": res.total_tokens,
            "tokens_per_second": res.tokens_per_second}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24, help="number of requests")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    engine = InferenceEngine.build("opus-mt", None, smoke=True,
                                   max_batch=args.max_batch,
                                   block_size=args.block_size)
    reqs = make_workload(args.n, engine.cfg.vocab_size, seed=args.seed)

    # warmup pass compiles every (shape-bucketed) prefill/decode variant so
    # the timed pass measures steady-state serving, not XLA compilation
    run_static(engine, reqs, args.max_batch)
    run_continuous(engine, reqs, args.max_batch, args.block_size)

    static = run_static(engine, reqs, args.max_batch)
    cont = run_continuous(engine, reqs, args.max_batch, args.block_size)
    speedup = cont["tokens_per_second"] / static["tokens_per_second"]

    report = {
        "workload": {"n": args.n, "prompt_lens": list(PROMPT_LENS),
                     "gen_lens": list(GEN_LENS), "seed": args.seed,
                     "max_batch": args.max_batch,
                     "block_size": args.block_size},
        "static": static,
        "continuous": cont,
        "speedup": speedup,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"static:     {static['tokens_per_second']:8.1f} tok/s "
          f"({static['decode_steps']} decode steps)")
    print(f"continuous: {cont['tokens_per_second']:8.1f} tok/s "
          f"({cont['decode_steps']} decode steps, "
          f"{cont['prefills']} prefills)")
    print(f"speedup:    {speedup:.2f}x  -> {args.out}")
    return report


if __name__ == "__main__":
    main()
