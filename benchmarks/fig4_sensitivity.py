"""Paper Fig. 4: per-layer sensitivity to rank truncation. Each layer is
truncated to a percentage of full rank (others untouched); the accuracy
drop profiles differ per layer — the motivation for SRA."""
from common import BLOCK_LINEARS, DecompCache, train_proxy, token_accuracy, csv_row
from repro.core.compress import CompressionConfig


def main():
    params, cfg, task = train_proxy()
    base = token_accuracy(params, cfg, task)
    dc = DecompCache(params, CompressionConfig(method="itera", weight_wl=8, exclude=BLOCK_LINEARS))
    L = dc.num_layers
    full = max(dc.max_rank(p) for p in dc.targets)
    for pct in (75, 50, 25, 12):
        for layer in range(L):
            ranks = [full] * L
            ranks[layer] = max(1, full * pct // 100)
            cp = dc.compressed_params(params, ranks, "itera")
            acc = token_accuracy(cp, cfg, task, batches=3)
            csv_row(f"fig4_layer{layer}_rank{pct}pct", 0.0,
                    f"acc={acc:.4f};delta={acc-base:+.4f}")


if __name__ == "__main__":
    main()
