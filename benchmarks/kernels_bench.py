"""Kernel microbenchmarks: Pallas kernels (interpret mode — CPU wall time
is NOT TPU latency; reported for relative sanity only) plus the analytical
TPU latencies the DSE actually uses (modeled compute/memory terms).

Besides the csv rows on stdout, writes a machine-readable summary to
BENCH_kernels.json (path override: --out / $BENCH_KERNELS_OUT) that
`tools/perf_compare.py --kernels` diffs across runs.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from common import csv_row, timed
from repro.core.itera import svd_decompose
from repro.core.quant import quantize
from repro.hw import tpu_model as tm
from repro.kernels import ops


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out",
                    default=os.environ.get("BENCH_KERNELS_OUT",
                                           "BENCH_kernels.json"))
    args = ap.parse_args(argv)

    rows = []

    def record(name, us_per_call, derived=""):
        csv_row(name, us_per_call, derived)
        rows.append({"name": name, "us_per_call": round(us_per_call, 3),
                     "derived": derived})

    key = jax.random.PRNGKey(0)
    cases = [
        ("paper512", 512, 512, 512, 128),
        ("ffn_like", 256, 1024, 4096, 256),
        ("decode_like", 8, 4096, 4096, 512),
    ]
    for name, m, k, n, r in cases:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32) / np.sqrt(k)
        wq = quantize(w, 8, axis=0)
        lr = svd_decompose(w, r, 8)

        dt, _ = timed(lambda: ops.qmm(x, wq, use_kernel=True,
                                      interpret=True), iters=1)
        record(f"kernel_qmm_interp_{name}", dt * 1e6,
               f"M={m};K={k};N={n}")
        dt, _ = timed(lambda: ops.lrmm(x, lr, use_kernel=True,
                                       interpret=True), iters=1)
        record(f"kernel_lrmm_interp_{name}", dt * 1e6,
               f"M={m};K={k};N={n};R={r}")
        dt, _ = timed(lambda: ops.qmm(x, wq, use_kernel=False), iters=3)
        record(f"kernel_qmm_ref_{name}", dt * 1e6, "jnp-reference")

        # modeled TPU latencies (what the roofline/DSE uses)
        bp = tm.best_point(m, k, n, None, weight_wl=8)
        cp = tm.best_point(m, k, n, r, weight_wl=8,
                           engines=("cascade",))
        record(f"kernel_qmm_tpu_model_{name}", bp.latency_s * 1e6,
               f"bound={'compute' if bp.compute_s >= bp.memory_s else 'memory'}")
        record(f"kernel_lrmm_tpu_model_{name}", cp.latency_s * 1e6,
               f"bound={'compute' if cp.compute_s >= cp.memory_s else 'memory'};"
               f"speedup_vs_dense={bp.latency_s / cp.latency_s:.2f}x")

    with open(args.out, "w") as f:
        json.dump({"schema": "kernels_bench/v1",
                   "backend": jax.default_backend(),
                   "jax_version": jax.__version__,
                   "rows": rows}, f, indent=2)
        f.write("\n")
    print(f"# wrote {len(rows)} rows to {args.out}", flush=True)


if __name__ == "__main__":
    main()
