"""Kernel microbenchmarks: Pallas kernels (interpret mode — CPU wall time
is NOT TPU latency; reported for relative sanity only) plus the analytical
TPU latencies the DSE actually uses (modeled compute/memory terms), plus —
the number the packed-residency work is about — the modeled HBM bytes each
launch moves (`hbm_mb`): W4 packed streams half the weight bytes of the
W8/carrier path, so the bandwidth win is measured per case, not asserted.

Besides the csv rows on stdout, writes a machine-readable summary to
BENCH_kernels.json (path override: --out / $BENCH_KERNELS_OUT) that
`tools/perf_compare.py --kernels` diffs across runs.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from common import csv_row, timed
from repro.configs.base import ModelConfig
from repro.core.itera import LowRankQ, svd_decompose
from repro.core.quant import pack_weights, quantize
from repro.hw import tpu_model as tm
from repro.kernels import ops
from repro.kernels import paged_attention as pa
from repro.models import attention as mattn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out",
                    default=os.environ.get("BENCH_KERNELS_OUT",
                                           "BENCH_kernels.json"))
    args = ap.parse_args(argv)

    rows = []

    def record(name, us_per_call, derived="", hbm_mb=None):
        csv_row(name, us_per_call,
                derived + (f";hbm_mb={hbm_mb:.3f}" if hbm_mb is not None
                           else ""))
        row = {"name": name, "us_per_call": round(us_per_call, 3),
               "derived": derived}
        if hbm_mb is not None:
            row["hbm_mb"] = round(hbm_mb, 3)
        rows.append(row)

    key = jax.random.PRNGKey(0)
    cases = [
        ("paper512", 512, 512, 512, 128),
        ("ffn_like", 256, 1024, 4096, 256),
        ("decode_like", 8, 4096, 4096, 512),
    ]
    for name, m, k, n, r in cases:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32) / np.sqrt(k)
        wq8 = quantize(w, 8, axis=0)
        wq4 = pack_weights(quantize(w, 4, axis=0))
        lr8 = svd_decompose(w, r, 8)
        lr4f = svd_decompose(w, r, 4)
        lr4 = LowRankQ(pack_weights(lr4f.w1), pack_weights(lr4f.w2))

        qmm_mb = {}
        for wl, wq in ((8, wq8), (4, wq4)):
            tag = f"W{wl}" + ("_packed" if wq.packed else "")
            dt, _ = timed(lambda: ops.qmm(x, wq, use_kernel=True,
                                          interpret=True), iters=1)
            qmm_mb[wl] = ops.qmm_hbm_bytes(m, wq) / 2**20
            record(f"kernel_qmm_interp_{tag}_{name}", dt * 1e6,
                   f"M={m};K={k};N={n}", hbm_mb=qmm_mb[wl])
        lrmm_mb = {}
        for wl, lr in ((8, lr8), (4, lr4)):
            # a factor whose pack axis would pad-inflate stays carrier
            # (core.quant.packable); the row is "packed" if any factor is
            tag = f"W{wl}" + ("_packed" if (lr.w1.packed or lr.w2.packed)
                              else "")
            dt, _ = timed(lambda: ops.lrmm(x, lr, use_kernel=True,
                                           interpret=True), iters=1)
            lrmm_mb[wl] = ops.lrmm_hbm_bytes(m, lr) / 2**20
            record(f"kernel_lrmm_interp_{tag}_{name}", dt * 1e6,
                   f"M={m};K={k};N={n};R={r}", hbm_mb=lrmm_mb[wl])
        # packing must never lose to its own carrier: the W4 launch (with
        # ops.packed_pad_ok demoting pad-inflating axes) streams at most
        # the W8 bytes. Tracked here so a choose_blocks / padding change
        # that reintroduces the old lrmm paper512 regression (packed
        # rp->256 padding costing more than the nibble halving saved)
        # fails the bench, not just a note in a JSON diff.
        assert qmm_mb[4] <= qmm_mb[8] + 1e-9, (name, qmm_mb)
        assert lrmm_mb[4] <= lrmm_mb[8] + 1e-9, (name, lrmm_mb)
        dt, _ = timed(lambda: ops.qmm(x, wq8, use_kernel=False), iters=3)
        record(f"kernel_qmm_ref_{name}", dt * 1e6, "jnp-reference")

        # modeled TPU latencies (what the roofline/DSE uses); weight_wl=4
        # is now a *deliverable* bandwidth model — packed W4 really moves
        # wl/8 bytes — not an aspiration
        for wl in (8, 4):
            bp = tm.best_point(m, k, n, None, weight_wl=wl)
            cp = tm.best_point(m, k, n, r, weight_wl=wl,
                               engines=("cascade",))
            record(f"kernel_qmm_tpu_model_W{wl}_{name}", bp.latency_s * 1e6,
                   f"bound={'compute' if bp.compute_s >= bp.memory_s else 'memory'}")
            record(f"kernel_lrmm_tpu_model_W{wl}_{name}", cp.latency_s * 1e6,
                   f"bound={'compute' if cp.compute_s >= cp.memory_s else 'memory'};"
                   f"speedup_vs_dense={bp.latency_s / cp.latency_s:.2f}x")

    # ---- paged serving attention: streamed kernel vs jnp gather oracle ----
    # Same mixed span batch (chunk + decode + idle rows, GQA) against the
    # same blocked KV pool; short vs long context shows the point of the
    # kernel — its bytes scale with ctx_lens while the gather path reads
    # the full MB*bs logical view either way.
    B, W, hk, g, dh, bs, mb = 4, 8, 4, 2, 64, 16, 16
    h = hk * g
    cfg_attn = ModelConfig(name="bench-attn", d_model=h * dh, num_heads=h,
                           num_kv_heads=hk, head_dim=dh, dtype="bfloat16")
    q_lens = [8, 1, 0, 8]                       # chunk, decode, idle, chunk
    ctx_cases = {"short": [40, 17, 0, 9], "long": [216, 230, 0, 188]}
    for cname, ctx in ctx_cases.items():
        ctx_a = jnp.asarray(ctx, jnp.int32)
        ql_a = jnp.asarray(q_lens, jnp.int32)
        bt = np.zeros((B, mb), np.int32)
        nxt = 1                                 # block 0 = reserved trash
        for r in range(B):
            need = -(-(ctx[r] + q_lens[r]) // bs)
            bt[r, :need] = np.arange(nxt, nxt + need)
            nxt += need
        bt_a = jnp.asarray(bt)
        nb_pool = B * mb + 1
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (B, W, h, dh), jnp.bfloat16)
        for kv_bits, tag in ((16, "bf16kv"), (8, "int8kv")):
            if kv_bits == 8:
                pool_l = {
                    "k": jax.random.randint(ks[1], (nb_pool, bs, hk, dh),
                                            -127, 128).astype(jnp.int8),
                    "v": jax.random.randint(ks[2], (nb_pool, bs, hk, dh),
                                            -127, 128).astype(jnp.int8),
                    "ks": jnp.ones((nb_pool, bs, hk, 1), jnp.float32) * 0.02,
                    "vs": jnp.ones((nb_pool, bs, hk, 1), jnp.float32) * 0.02,
                }
            else:
                pool_l = {
                    "k": jax.random.normal(ks[1], (nb_pool, bs, hk, dh),
                                           jnp.bfloat16),
                    "v": jax.random.normal(ks[2], (nb_pool, bs, hk, dh),
                                           jnp.bfloat16),
                }
            geom = f"B={B};W={W};Hk={hk};G={g};Dh={dh};bs={bs};MB={mb}"
            stream_mb = pa.stream_hbm_bytes(ctx, q_lens, bs, hk, dh,
                                            kv_bits=kv_bits,
                                            n_q_heads=h) / 2**20
            gather_mb = pa.gather_hbm_bytes(B, mb, bs, hk, dh,
                                            kv_bits=kv_bits, w=W,
                                            n_q_heads=h) / 2**20
            dt, _ = timed(lambda: pa.paged_attention(
                q, pool_l, bt_a, ctx_a, ql_a, interpret=True), iters=1)
            record(f"kernel_pattn_interp_{tag}_{cname}_ctx", dt * 1e6,
                   geom, hbm_mb=stream_mb)
            pos = ctx_a[:, None] + jnp.arange(W)[None, :]
            dt, _ = timed(lambda: mattn._span_attend_gather(
                q, pool_l, bt_a, pos, cfg_attn), iters=1)
            record(f"kernel_pattn_gather_{tag}_{cname}_ctx", dt * 1e6,
                   "jnp-gather-oracle", hbm_mb=gather_mb)
            # the acceptance bar: streamed bytes scale with ctx and stay
            # strictly below the gather whenever ctx < pool capacity
            assert stream_mb < gather_mb, (cname, tag, stream_mb, gather_mb)
            sp = tm.paged_attention_point(
                ctx, q_lens, num_kv_heads=hk, head_dim=dh, num_heads=h,
                block_size=bs, max_blocks=mb, kv_bits=kv_bits,
                streamed=True)
            gp = tm.paged_attention_point(
                ctx, q_lens, num_kv_heads=hk, head_dim=dh, num_heads=h,
                block_size=bs, max_blocks=mb, kv_bits=kv_bits,
                streamed=False)
            record(f"kernel_pattn_tpu_model_{tag}_{cname}_ctx",
                   sp.latency_s * 1e6,
                   f"bound={'compute' if sp.compute_s >= sp.memory_s else 'memory'};"
                   f"speedup_vs_gather={gp.latency_s / sp.latency_s:.2f}x")

    with open(args.out, "w") as f:
        json.dump({"schema": "kernels_bench/v2",
                   "backend": jax.default_backend(),
                   "jax_version": jax.__version__,
                   "rows": rows}, f, indent=2)
        f.write("\n")
    print(f"# wrote {len(rows)} rows to {args.out}", flush=True)


if __name__ == "__main__":
    main()
