"""Kernel microbenchmarks: Pallas kernels (interpret mode — CPU wall time
is NOT TPU latency; reported for relative sanity only) plus the analytical
TPU latencies the DSE actually uses (modeled compute/memory terms)."""
import jax
import jax.numpy as jnp
import numpy as np

from common import csv_row, timed
from repro.core.itera import svd_decompose
from repro.core.quant import quantize
from repro.hw import tpu_model as tm
from repro.kernels import ops


def main():
    key = jax.random.PRNGKey(0)
    cases = [
        ("paper512", 512, 512, 512, 128),
        ("ffn_like", 256, 1024, 4096, 256),
        ("decode_like", 8, 4096, 4096, 512),
    ]
    for name, m, k, n, r in cases:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32) / np.sqrt(k)
        wq = quantize(w, 8, axis=0)
        lr = svd_decompose(w, r, 8)

        dt, _ = timed(lambda: ops.qmm(x, wq, use_kernel=True,
                                      interpret=True), iters=1)
        csv_row(f"kernel_qmm_interp_{name}", dt * 1e6,
                f"M={m};K={k};N={n}")
        dt, _ = timed(lambda: ops.lrmm(x, lr, use_kernel=True,
                                       interpret=True), iters=1)
        csv_row(f"kernel_lrmm_interp_{name}", dt * 1e6,
                f"M={m};K={k};N={n};R={r}")
        dt, _ = timed(lambda: ops.qmm(x, wq, use_kernel=False), iters=3)
        csv_row(f"kernel_qmm_ref_{name}", dt * 1e6, "jnp-reference")

        # modeled TPU latencies (what the roofline/DSE uses)
        bp = tm.best_point(m, k, n, None, weight_wl=8)
        cp = tm.best_point(m, k, n, r, weight_wl=8,
                           engines=("cascade",))
        csv_row(f"kernel_qmm_tpu_model_{name}", bp.latency_s * 1e6,
                f"bound={'compute' if bp.compute_s >= bp.memory_s else 'memory'}")
        csv_row(f"kernel_lrmm_tpu_model_{name}", cp.latency_s * 1e6,
                f"bound={'compute' if cp.compute_s >= cp.memory_s else 'memory'};"
                f"speedup_vs_dense={bp.latency_s / cp.latency_s:.2f}x")


if __name__ == "__main__":
    main()
