"""Kernel microbenchmarks: Pallas kernels (interpret mode — CPU wall time
is NOT TPU latency; reported for relative sanity only) plus the analytical
TPU latencies the DSE actually uses (modeled compute/memory terms), plus —
the number the packed-residency work is about — the modeled HBM bytes each
launch moves (`hbm_mb`): W4 packed streams half the weight bytes of the
W8/carrier path, so the bandwidth win is measured per case, not asserted.

Besides the csv rows on stdout, writes a machine-readable summary to
BENCH_kernels.json (path override: --out / $BENCH_KERNELS_OUT) that
`tools/perf_compare.py --kernels` diffs across runs.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from common import csv_row, timed
from repro.core.itera import LowRankQ, svd_decompose
from repro.core.quant import pack_weights, quantize
from repro.hw import tpu_model as tm
from repro.kernels import ops


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out",
                    default=os.environ.get("BENCH_KERNELS_OUT",
                                           "BENCH_kernels.json"))
    args = ap.parse_args(argv)

    rows = []

    def record(name, us_per_call, derived="", hbm_mb=None):
        csv_row(name, us_per_call,
                derived + (f";hbm_mb={hbm_mb:.3f}" if hbm_mb is not None
                           else ""))
        row = {"name": name, "us_per_call": round(us_per_call, 3),
               "derived": derived}
        if hbm_mb is not None:
            row["hbm_mb"] = round(hbm_mb, 3)
        rows.append(row)

    key = jax.random.PRNGKey(0)
    cases = [
        ("paper512", 512, 512, 512, 128),
        ("ffn_like", 256, 1024, 4096, 256),
        ("decode_like", 8, 4096, 4096, 512),
    ]
    for name, m, k, n, r in cases:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32) / np.sqrt(k)
        wq8 = quantize(w, 8, axis=0)
        wq4 = pack_weights(quantize(w, 4, axis=0))
        lr8 = svd_decompose(w, r, 8)
        lr4f = svd_decompose(w, r, 4)
        lr4 = LowRankQ(pack_weights(lr4f.w1), pack_weights(lr4f.w2))

        for wl, wq in ((8, wq8), (4, wq4)):
            tag = f"W{wl}" + ("_packed" if wq.packed else "")
            dt, _ = timed(lambda: ops.qmm(x, wq, use_kernel=True,
                                          interpret=True), iters=1)
            record(f"kernel_qmm_interp_{tag}_{name}", dt * 1e6,
                   f"M={m};K={k};N={n}",
                   hbm_mb=ops.qmm_hbm_bytes(m, wq) / 2**20)
        for wl, lr in ((8, lr8), (4, lr4)):
            tag = f"W{wl}" + ("_packed" if lr.w1.packed else "")
            dt, _ = timed(lambda: ops.lrmm(x, lr, use_kernel=True,
                                           interpret=True), iters=1)
            record(f"kernel_lrmm_interp_{tag}_{name}", dt * 1e6,
                   f"M={m};K={k};N={n};R={r}",
                   hbm_mb=ops.lrmm_hbm_bytes(m, lr) / 2**20)
        dt, _ = timed(lambda: ops.qmm(x, wq8, use_kernel=False), iters=3)
        record(f"kernel_qmm_ref_{name}", dt * 1e6, "jnp-reference")

        # modeled TPU latencies (what the roofline/DSE uses); weight_wl=4
        # is now a *deliverable* bandwidth model — packed W4 really moves
        # wl/8 bytes — not an aspiration
        for wl in (8, 4):
            bp = tm.best_point(m, k, n, None, weight_wl=wl)
            cp = tm.best_point(m, k, n, r, weight_wl=wl,
                               engines=("cascade",))
            record(f"kernel_qmm_tpu_model_W{wl}_{name}", bp.latency_s * 1e6,
                   f"bound={'compute' if bp.compute_s >= bp.memory_s else 'memory'}")
            record(f"kernel_lrmm_tpu_model_W{wl}_{name}", cp.latency_s * 1e6,
                   f"bound={'compute' if cp.compute_s >= cp.memory_s else 'memory'};"
                   f"speedup_vs_dense={bp.latency_s / cp.latency_s:.2f}x")

    with open(args.out, "w") as f:
        json.dump({"schema": "kernels_bench/v2",
                   "backend": jax.default_backend(),
                   "jax_version": jax.__version__,
                   "rows": rows}, f, indent=2)
        f.write("\n")
    print(f"# wrote {len(rows)} rows to {args.out}", flush=True)


if __name__ == "__main__":
    main()
