"""Paper Fig. 1: post-training quantization accuracy degradation as
precision drops (FP32 -> W8A8 -> W6A8 -> W4A8). Reproduces the paper's
motivating observation: sub-8-bit quantization-only compression loses
accuracy fast (the paper reports -5.37% BLEU at W4A8)."""
from common import BLOCK_LINEARS, DecompCache, train_proxy, token_accuracy, csv_row
from repro.core.compress import CompressionConfig


def main():
    params, cfg, task = train_proxy()
    base = token_accuracy(params, cfg, task)
    csv_row("fig1_fp32", 0.0, f"acc={base:.4f}")
    # W3/W2 extend the sweep to where degradation sets in for the proxy:
    # small outlier-free models quantize losslessly at W4 (EXPERIMENTS.md
    # discusses the threshold shift vs the paper's OPUS-MT).
    for wl in (8, 6, 4, 3, 2):
        dc = DecompCache(params, CompressionConfig(method="quant",
                                                   weight_wl=wl, exclude=BLOCK_LINEARS))
        cp = dc.compressed_params(params, 0, "quant")
        acc = token_accuracy(cp, cfg, task)
        drop = 100 * (base - acc) / max(base, 1e-9)
        csv_row(f"fig1_W{wl}A8", 0.0,
                f"acc={acc:.4f};drop_pct={drop:.2f}")


if __name__ == "__main__":
    main()
