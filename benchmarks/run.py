"""Benchmark driver: one module per paper table/figure + kernels +
roofline. Prints ``name,us_per_call,derived`` CSV rows (benchmarks with no
wall-time axis report 0.0 and carry their numbers in `derived`).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig7 fig10 # subset
"""
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

MODULES = [
    "fig1_quant",
    "fig4_sensitivity",
    "fig7_pareto",
    "fig8_nops",
    "fig9_generality",
    "fig10_engines",
    "fig11_codesign",
    "fig12_occupancy",
    "kernels_bench",
    "roofline",
]


def main() -> None:
    want = sys.argv[1:]
    mods = [m for m in MODULES if not want or any(w in m for w in want)]
    failures = []
    here = os.path.dirname(os.path.abspath(__file__))
    for name in mods:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        # each module runs in its own process: XLA's JIT memory is not
        # reclaimable in-process and hundreds of compiles across benches
        # otherwise exhaust it
        import subprocess
        r = subprocess.run(
            [sys.executable, os.path.join(here, name + ".py")],
            text=True, capture_output=True,
            env={**os.environ,
                 "PYTHONPATH": os.pathsep.join(
                     [os.path.join(here, "..", "src"), here,
                      os.environ.get("PYTHONPATH", "")])})
        sys.stdout.write(r.stdout)
        if r.returncode != 0:
            failures.append(name)
            print(f"# {name} FAILED (exit {r.returncode}):", flush=True)
            sys.stdout.write(r.stderr[-2000:])
        else:
            print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}", flush=True)
        raise SystemExit(1)
    print("# all benchmarks complete", flush=True)


if __name__ == "__main__":
    main()
