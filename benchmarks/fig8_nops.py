"""Paper Fig. 8: accuracy vs number-of-operations Pareto; checks the
"-12.5% NOps at W6A8 vs quant-only at similar accuracy" claim."""
from common import BLOCK_LINEARS, DecompCache, train_proxy, token_accuracy, csv_row
from repro.core.compress import CompressionConfig
from repro.core.sra import uniform_allocation


def main():
    params, cfg, task = train_proxy()
    # W6 = the paper's operating point; W2 = the proxy's degradation
    # threshold, where the matched-accuracy comparison has signal.
    for wl in (6, 2):
        dcq = DecompCache(params, CompressionConfig(
            method="quant", weight_wl=wl, exclude=BLOCK_LINEARS))
        cpq = dcq.compressed_params(params, 0, "quant")
        acc_q = token_accuracy(cpq, cfg, task)
        _, nops_q, dense_nops = dcq.accounting(0, "quant")
        csv_row(f"fig8_quant_W{wl}", 0.0, f"acc={acc_q:.4f};nops={nops_q}")

        dc = DecompCache(params, CompressionConfig(
            method="itera", weight_wl=wl, exclude=BLOCK_LINEARS))
        L = dc.num_layers
        full = max(dc.max_rank(p) for p in dc.targets)
        best_saving = None
        for frac in (0.9, 0.8, 0.7, 0.6, 0.5, 0.4):
            ranks = uniform_allocation(L, max(L, int(L * full * frac)),
                                       [full] * L)
            cp = dc.compressed_params(params, ranks, "itera")
            acc = token_accuracy(cp, cfg, task)
            _, nops, _ = dc.accounting(ranks, "itera")
            save_pct = 100 * (1 - nops / nops_q)
            csv_row(f"fig8_itera_W{wl}_f{frac}", 0.0,
                    f"acc={acc:.4f};nops={nops};saving_pct={save_pct:.1f}")
            # "similar accuracy": within 1 point of quant-only
            if acc >= acc_q - 0.01 and (best_saving is None
                                        or save_pct > best_saving):
                best_saving = save_pct
        csv_row(f"fig8_claim_nops_saving_at_similar_acc_W{wl}", 0.0,
                f"best_saving_pct="
                f"{best_saving if best_saving is not None else 'none'}"
                f";paper_claims=12.5")


if __name__ == "__main__":
    main()
