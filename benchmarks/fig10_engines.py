"""Paper Fig. 10: latency vs off-chip-bandwidth Pareto for the three
MatMul engines at M x K x N = 512x512x512, W4A8, rank 128 — on BOTH the
faithful ZCU111 model (paper eqs. 12-19) and the TPU v5e adaptation.

Checks the paper's qualitative structure:
  * bandwidth-limited region: SVD engines match baseline latency at lower
    bandwidth (fewer off-chip weight bits);
  * compute-bound region: SVD engines win outright (fewer MACs);
  * the cascade engine populates a finer front than the single engine.
"""
from common import csv_row
from repro.hw import engine_model as em
from repro.hw import tpu_model as tm


def zcu111():
    m = k = n = 512
    r = 128
    pts = em.explore(m, k, n, r, weight_wl=4, act_wl=8)
    fronts = {}
    for kind in ("baseline", "single", "cascade"):
        sub = [p for p in pts if p.kind == kind]
        fronts[kind] = em.pareto_front(sub)
        for p in fronts[kind][:8]:
            csv_row(f"fig10_zcu111_{kind}", p.latency_cycles / 200e6 * 1e6,
                    f"bw_bits_per_cycle={p.bandwidth:.0f};dsp={p.dsp};"
                    f"bram={p.bram}")
    # claims
    lowbw = min(fronts["cascade"], key=lambda p: p.bandwidth)
    base_best = min(fronts["baseline"], key=lambda p: p.latency_cycles)
    casc_best = min(fronts["cascade"], key=lambda p: p.latency_cycles)
    sing_best = min(fronts["single"], key=lambda p: p.latency_cycles)
    csv_row("fig10_zcu111_claim_compute_bound", 0.0,
            f"baseline_best_us={base_best.latency_cycles/200:.1f};"
            f"single_best_us={sing_best.latency_cycles/200:.1f};"
            f"cascade_best_us={casc_best.latency_cycles/200:.1f};"
            f"svd_speedup={base_best.latency_cycles/casc_best.latency_cycles:.2f}x")
    csv_row("fig10_zcu111_claim_bandwidth", 0.0,
            f"cascade_min_bw={lowbw.bandwidth:.0f};"
            f"baseline_min_bw={min(p.bandwidth for p in fronts['baseline']):.0f}")
    csv_row("fig10_zcu111_claim_finer_front", 0.0,
            f"cascade_front_points={len(fronts['cascade'])};"
            f"single_front_points={len(fronts['single'])}")


def tpu():
    m = k = n = 512
    r = 128
    for bw_scale in (1.0, 0.25, 0.0625):
        rows = {}
        for kind, fn in (
            ("baseline", lambda b: tm.dense_engine(
                m, k, n, b, weight_wl=4, hbm_bw=tm.HBM_BW * bw_scale)),
            ("single", lambda b: tm.single_engine(
                m, k, n, r, b, weight_wl=4, hbm_bw=tm.HBM_BW * bw_scale)),
            ("cascade", lambda b: tm.cascade_engine(
                m, k, n, r, b, weight_wl=4, hbm_bw=tm.HBM_BW * bw_scale)),
        ):
            best = None
            for b in tm.block_space(max_bm=512):
                p = fn(b)
                if p.vmem_bytes > tm.VMEM_BYTES:
                    continue
                if best is None or p.latency_s < best.latency_s:
                    best = p
            rows[kind] = best
            csv_row(f"fig10_tpu_{kind}_bw{bw_scale}",
                    best.latency_s * 1e6,
                    f"compute_us={best.compute_s*1e6:.3f};"
                    f"memory_us={best.memory_s*1e6:.3f};"
                    f"hbm_bytes={best.hbm_bytes:.0f}")
        speed = rows["baseline"].latency_s / rows["cascade"].latency_s
        csv_row(f"fig10_tpu_claim_bw{bw_scale}", 0.0,
                f"cascade_vs_baseline={speed:.2f}x")


def main():
    zcu111()
    tpu()


if __name__ == "__main__":
    main()
