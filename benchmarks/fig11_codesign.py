"""Paper Fig. 11: accuracy-latency trade-off of full co-design under two
bandwidth regimes (full bandwidth = compute-bound; quarter bandwidth =
memory-bound). Claims checked:
  * compute-bound: W6A8 ITERA(+SRA) design points dominate (higher bits,
    lower rank, fewer ops);
  * bandwidth-limited: W4A8 ITERA(+SRA) dominates (higher compression);
  * in both regimes, ITERA beats quant-only at comparable accuracy
    (paper: 12.1%..41.1% linear-layer latency reduction).
"""
from common import BLOCK_LINEARS, DecompCache, train_proxy, token_accuracy, csv_row
from repro.core.compress import CompressionConfig
from repro.core.sra import uniform_allocation
from repro.hw import dse
from repro.hw.dse import LayerShape


def candidate_points(params, cfg, task):
    """(label, wl, method, acc, per-layer shapes+ranks) candidates."""
    out = []
    for wl in (8, 6, 4):
        dcq = DecompCache(params, CompressionConfig(method="quant",
                                                    weight_wl=wl, exclude=BLOCK_LINEARS))
        acc = token_accuracy(dcq.compressed_params(params, 0, "quant"),
                             cfg, task)
        layers = [LayerShape(f"{p}#{i}", w.shape[0], w.shape[1], None)
                  for (p, i), w in dcq.mats.items()]
        out.append({"label": f"quant_W{wl}", "wl": wl, "acc": acc,
                    "layers": layers})

        dc = DecompCache(params, CompressionConfig(method="itera",
                                                   weight_wl=wl, exclude=BLOCK_LINEARS))
        L = dc.num_layers
        full = max(dc.max_rank(p) for p in dc.targets)
        for frac in (0.7, 0.5, 0.35):
            ranks = uniform_allocation(L, max(L, int(L * full * frac)),
                                       [full] * L)
            acc = token_accuracy(
                dc.compressed_params(params, ranks, "itera"), cfg, task,
                batches=3)
            layers = [
                LayerShape(f"{p}#{i}", w.shape[0], w.shape[1],
                           min(ranks[i if i is not None else 0],
                               min(w.shape)))
                for (p, i), w in dc.mats.items()]
            out.append({"label": f"itera_W{wl}_f{frac}", "wl": wl,
                        "acc": acc, "layers": layers})
    return out


def main():
    params, cfg, task = train_proxy()
    cands = candidate_points(params, cfg, task)
    batch_m = 512  # paper's batch for engine evaluation

    for bw_scale, regime in ((1.0, "compute_bound"),
                             (0.25, "bandwidth_limited")):
        pts = []
        for c in cands:
            lat, chosen = dse.total_latency_tpu(
                c["layers"], batch_m, weight_wl=c["wl"], bw_scale=bw_scale)
            pts.append((c["label"], c["acc"], lat))
            csv_row(f"fig11_{regime}_{c['label']}", lat * 1e6,
                    f"acc={c['acc']:.4f}")
        # latency reduction vs quant baseline at comparable accuracy
        quant_pts = {l: (a, t) for l, a, t in pts if l.startswith("quant")}
        best_claims = []
        for ql, (qa, qt) in quant_pts.items():
            ok = [(l, a, t) for l, a, t in pts
                  if l.startswith("itera") and a >= qa - 0.01]
            if ok:
                l, a, t = min(ok, key=lambda x: x[2])
                best_claims.append((ql, l, 100 * (1 - t / qt)))
        for ql, il, red in best_claims:
            csv_row(f"fig11_{regime}_latency_reduction", 0.0,
                    f"vs={ql};using={il};reduction_pct={red:.1f};"
                    f"paper_claims=12.1..41.1")


if __name__ == "__main__":
    main()
