"""Paper Fig. 11: accuracy-latency trade-off of full co-design under two
bandwidth regimes (full bandwidth = compute-bound; quarter bandwidth =
memory-bound). Claims checked:
  * compute-bound: W6A8 ITERA(+SRA) design points dominate (higher bits,
    lower rank, fewer ops);
  * bandwidth-limited: W4A8 ITERA(+SRA) dominates (higher compression);
  * in both regimes, ITERA beats quant-only at comparable accuracy
    (paper: 12.1%..41.1% linear-layer latency reduction).

Candidates are `CompressionPlan`s; each regime's best design point is
serialized to results/fig11_best_plan_<regime>.json, directly consumable
by `python -m repro.launch.serve --plan <file>` — the DSE→deployment loop.
"""
import os

from common import (
    BLOCK_LINEARS, RESULTS, DecompCache, train_proxy, token_accuracy, csv_row,
)
from repro.api import CompressionPlan, LayerPlan
from repro.core.compress import CompressionConfig
from repro.hw import dse
from repro.hw.dse import LayerShape


def candidate_points(params, cfg, task):
    """(plan, acc, per-layer shapes+ranks) candidates. The LayerShape list
    keeps DecompCache's per-slice ranks for the latency model; the plan is
    the deployable per-layer (method, wl, rank) record."""
    out = []
    for wl in (8, 6, 4):
        qcfg = CompressionConfig(method="quant", weight_wl=wl,
                                 exclude=BLOCK_LINEARS)
        dcq = DecompCache(params, qcfg)
        acc = token_accuracy(dcq.compressed_params(params, 0, "quant"),
                             cfg, task)
        layers = [LayerShape(f"{p}#{i}", w.shape[0], w.shape[1], None, wl=wl)
                  for (p, i), w in dcq.mats.items()]
        out.append({"plan": CompressionPlan.from_config(params, qcfg),
                    "acc": acc, "layers": layers})

        icfg = CompressionConfig(method="itera", weight_wl=wl,
                                 exclude=BLOCK_LINEARS)
        dc = DecompCache(params, icfg)
        L = dc.num_layers
        full = max(dc.max_rank(p) for p in dc.targets)
        for frac in (0.7, 0.5, 0.35):
            # a plan-expressible allocation: one rank per path, identical
            # across the scan stack, so the serialized plan encodes EXACTLY
            # the ranks this candidate is scored at (no rank_for rounding).
            r = max(1, int(round(full * frac)))
            acc = token_accuracy(
                dc.compressed_params(params, [r] * L, "itera"), cfg, task,
                batches=3)
            layers = [
                LayerShape(f"{p}#{i}", w.shape[0], w.shape[1],
                           min(r, min(w.shape)), wl=wl)
                for (p, i), w in dc.mats.items()]
            plan = CompressionPlan(
                layers=tuple(LayerPlan(p, "itera", wl,
                                       min(r, dc.max_rank(p)))
                             for p in dc.targets),
                label=f"itera_W{wl}_f{frac}").validate(params)
            out.append({"plan": plan, "acc": acc, "layers": layers})
    return out


def main():
    params, cfg, task = train_proxy()
    cands = candidate_points(params, cfg, task)
    batch_m = 512  # paper's batch for engine evaluation

    for bw_scale, regime in ((1.0, "compute_bound"),
                             (0.25, "bandwidth_limited")):
        pts = []
        points = []
        for c in cands:
            lat, chosen = dse.total_latency_tpu(
                c["layers"], batch_m, bw_scale=bw_scale)
            if lat is None:
                continue
            pts.append((c["plan"].label, c["acc"], lat))
            points.append(dse.DesignPoint(
                label=c["plan"].label, quality=c["acc"], latency=lat,
                compression_ratio=0.0, nops=0.0, per_layer=chosen,
                plan=c["plan"]))
            csv_row(f"fig11_{regime}_{c['plan'].label}", lat * 1e6,
                    f"acc={c['acc']:.4f}")
        # latency reduction vs quant baseline at comparable accuracy
        quant_pts = {l: (a, t) for l, a, t in pts if l.startswith("quant")}
        best_claims = []
        for ql, (qa, qt) in quant_pts.items():
            ok = [(l, a, t) for l, a, t in pts
                  if l.startswith("itera") and a >= qa - 0.01]
            if ok:
                l, a, t = min(ok, key=lambda x: x[2])
                best_claims.append((ql, l, 100 * (1 - t / qt)))
        for ql, il, red in best_claims:
            csv_row(f"fig11_{regime}_latency_reduction", 0.0,
                    f"vs={ql};using={il};reduction_pct={red:.1f};"
                    f"paper_claims=12.1..41.1")

        # Pareto front over the already-evaluated design points; serialize
        # the highest-accuracy one for direct deployment via serve --plan.
        front = dse.pareto(points)
        if front:
            best = front[-1]
            os.makedirs(RESULTS, exist_ok=True)
            out = os.path.join(RESULTS, f"fig11_best_plan_{regime}.json")
            CompressionPlan.from_design_point(best).save(out)
            csv_row(f"fig11_{regime}_best_plan", best.latency * 1e6,
                    f"label={best.label};acc={best.quality:.4f};plan={out}")


if __name__ == "__main__":
    main()
