"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON cache. Usage:

    PYTHONPATH=src python benchmarks/report.py [results/dryrun] > tables.md
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from roofline import roofline_row  # noqa: E402


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def main(out_dir="results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))

    oks = [r for r in recs if r.get("status") == "ok"]
    skips = [r for r in recs if r.get("status") == "skipped"]

    print("### Dry-run results (per (arch x shape x mesh) cell)\n")
    print(f"{len(oks)} compiled cells, {len(skips)} documented skips, "
          f"{sum(1 for r in recs if r.get('status') == 'error')} failures.\n")
    print("| cell | chips | compile s | args GiB/dev | peak GiB/dev | "
          "HLO TFLOP/dev | HBM GB/dev | coll GB/dev | top collective |")
    print("|---|--:|--:|--:|--:|--:|--:|--:|---|")
    for r in sorted(oks, key=lambda r: r["cell"]):
        h = r["hlo_analysis"]
        m = r["memory_analysis"]
        top = max(h["collective_breakdown"],
                  key=h["collective_breakdown"].get, default="-") \
            if h["collective_breakdown"] else "-"
        print(f"| {r['cell']} | {r['n_chips']} "
              f"| {r['seconds']['compile']:.0f} "
              f"| {fmt_bytes(m['argument_bytes_per_device'])} "
              f"| {fmt_bytes(m['peak_bytes_per_device'])} "
              f"| {h['flops_per_device']/1e12:.2f} "
              f"| {h['mem_bytes_per_device']/1e9:.1f} "
              f"| {h['collective_bytes_per_device']/1e9:.2f} "
              f"| {top} |")
    print()
    if skips:
        print("Skipped cells (DESIGN.md §5):\n")
        for r in sorted(skips, key=lambda r: r["cell"]):
            print(f"* `{r['cell']}` — {r['reason']}")
        print()

    print("### Roofline (single-pod baseline cells)\n")
    print("| cell | compute s | memory s | collective s | dominant | "
          "useful | roofline-MFU | fits 16 GiB |")
    print("|---|--:|--:|--:|---|--:|--:|:--:|")
    base = [r for r in oks
            if "__single" in r["cell"] and r["cell"].count("__") == 2]
    for r in sorted(base, key=lambda r: r["cell"]):
        x = roofline_row(r)
        print(f"| {x['cell']} | {x['t_compute_s']:.4g} "
              f"| {x['t_memory_s']:.4g} | {x['t_collective_s']:.4g} "
              f"| {x['dominant']} | {x['useful_ratio']:.2f} "
              f"| {x['roofline_mfu']:.3f} "
              f"| {'yes' if x['fits_16g'] else 'NO'} |")
    print()


if __name__ == "__main__":
    main(*sys.argv[1:])
