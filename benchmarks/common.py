"""Shared benchmark infrastructure.

The paper's quality metric is BLEU on WMT; offline we use **held-out token
accuracy of a trained proxy model on a seeded Markov task** (DESIGN.md §7).
The proxy is trained once and cached under results/proxy/<name>; every
figure benchmark reuses it, so compression methods are compared on the
exact same trained weights.

SRA evaluations memoize per-(matrix, rank, wl) decompositions — the
finite-difference probes revisit neighbouring ranks constantly and ITERA
decomposition is the expensive step.
"""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint import ckpt as ckpt_lib                 # noqa: E402
from repro.configs.base import ModelConfig                    # noqa: E402
from repro.core.compress import (                             # noqa: E402
    CompressionConfig, eligible_linears,
)
from repro.core.itera import itera_decompose, svd_decompose   # noqa: E402
from repro.core.quant import quantize                         # noqa: E402
from repro.data.pipeline import MarkovTask                    # noqa: E402
from repro.models import transformer as tfm                   # noqa: E402
from repro.optim import adamw                                 # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# The paper compresses the transformer-block linear layers (Q/K/V/O, FFN);
# embeddings and the LM head stay uncompressed. All figure benchmarks use
# this scope so methods are compared on the paper's own terms.
BLOCK_LINEARS = r"(embed|router|norm|scale|bias|ln|pos|lm_head)"


def proxy_config(name="proxy", vocab=512) -> ModelConfig:
    """OPUS-MT-geometry-inspired small LM that trains to structure on CPU
    in ~2 minutes (12 layers are grouped into 4 SRA groups in figs)."""
    return ModelConfig(
        name=name, layout="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=vocab,
        mlp_act="gelu", norm="layernorm", pos_emb="sinusoidal",
        dtype="float32", remat=False, loss_chunk=256,
    )


def train_proxy(name="proxy", *, steps=300, seed=0, lr=2e-3, batch=8,
                seq=64, force=False):
    """Train (or load) the cached proxy model. Returns (params, cfg, task)."""
    cfg = proxy_config(name)
    task = MarkovTask(cfg.vocab_size, seed=seed)
    ckpt_dir = os.path.join(RESULTS, "proxy", name)
    key = jax.random.PRNGKey(seed)
    params = tfm.init_params(key, cfg)
    if not force and ckpt_lib.latest_step(ckpt_dir) == steps:
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        params, _ = ckpt_lib.restore(ckpt_dir, like)
        return params, cfg, task

    opt_cfg = adamw.AdamWConfig(lr=lr, total_steps=steps,
                                warmup_steps=steps // 10)
    opt = adamw.init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(tfm.loss_fn, has_aux=True)(
            params, batch, cfg)
        p, o, _ = adamw.update(g, opt, params, opt_cfg)
        return p, o, loss

    t0 = time.time()
    for s in range(steps):
        b = task.batch(s, batch, seq)
        params, opt, loss = step(params, opt, b)
    print(f"# trained proxy '{name}' {steps} steps in {time.time()-t0:.0f}s "
          f"(final loss {float(loss):.3f}, entropy floor "
          f"{task.entropy_floor():.3f})", flush=True)
    ckpt_lib.save(ckpt_dir, steps, params)
    return params, cfg, task


def token_accuracy(params, cfg, task, *, batches=6, batch=8, seq=64,
                   offset=10_000) -> float:
    """Held-out greedy next-token accuracy — the BLEU stand-in."""
    @jax.jit
    def acc_fn(params, b):
        h, _ = tfm.forward(params, b["tokens"], cfg)
        logits = tfm.logits_for(params, h, cfg)
        pred = jnp.argmax(logits, axis=-1)
        return jnp.mean((pred == b["labels"]).astype(jnp.float32))

    accs = [float(acc_fn(params, task.batch(offset + i, batch, seq)))
            for i in range(batches)]
    return float(np.mean(accs))


# ------------------------------------------------- memoized decompositions --
class DecompCache:
    """Per-(matrix, layer-slice, rank) memoized decompositions.

    Models scan-stack layer params (leading dim L). Per-layer SRA ranks are
    realized by decomposing each slice at its own rank and zero-padding the
    factors to the stack's max rank — quality is exact (padded columns
    contribute nothing) while storage/NOps accounting uses the true ranks.
    """

    def __init__(self, params, cfg: CompressionConfig):
        self.cfg = cfg
        self.targets = dict(eligible_linears(params, cfg))
        # (path, slice) -> (K, N) matrix; slice=None for unstacked 2-D
        self.mats = {}
        for p, w in self.targets.items():
            if w.ndim == 3:
                for i in range(w.shape[0]):
                    self.mats[(p, i)] = w[i]
            else:
                self.mats[(p, None)] = w
        self._cache = {}

    @property
    def num_layers(self) -> int:
        return max((i + 1 for (_, i) in self.mats if i is not None),
                   default=1)

    def max_rank(self, path) -> int:
        w = self.targets[path]
        return int(min(w.shape[-2:]))

    def slice_node(self, path, i, rank, method):
        """Decompositions are computed ONCE at full rank per (matrix,
        method) and truncated to `rank` (prefix consistency) — one XLA
        compilation per shape instead of one per SRA rank probe."""
        from repro.core.itera import truncate

        if method == "quant":
            key = (path, i, "quant", self.cfg.weight_wl)
            if key not in self._cache:
                w = self.mats[(path, i)]
                self._cache[key] = jax.tree_util.tree_map(
                    np.asarray, quantize(w, self.cfg.weight_wl, axis=0))
            return self._cache[key]

        key = (path, i, "full", method, self.cfg.weight_wl)
        if key not in self._cache:
            w = self.mats[(path, i)]
            full = int(min(w.shape))
            if method == "itera":
                node = itera_decompose(w, full, self.cfg.weight_wl)
            elif method == "svd":
                node = svd_decompose(w, full, self.cfg.weight_wl)
            else:
                raise ValueError(method)
            self._cache[key] = jax.tree_util.tree_map(np.asarray, node)
        return truncate(self._cache[key], rank)

    def compressed_params(self, params, layer_ranks, method):
        """layer_ranks: list of per-layer ranks (or a single int). Returns
        params with every eligible weight replaced by padded-stacked
        low-rank nodes (or QuantizedTensor stacks for method='quant')."""
        from repro.core.compress import path_str
        from repro.core.itera import LowRankQ
        from repro.core.quant import QuantizedTensor

        def stack_nodes(nodes, rmax):
            if method == "quant":
                return QuantizedTensor(
                    jnp.stack([n.values for n in nodes]),
                    jnp.stack([n.scale for n in nodes]),
                    nodes[0].wl, nodes[0].axis)
            padded = []
            for n in nodes:
                r = n.rank
                w1v = np.pad(np.asarray(n.w1.values), ((0, 0), (0, rmax - r)))
                w1s = np.pad(np.asarray(n.w1.scale), ((0, 0), (0, rmax - r)),
                             constant_values=1.0)
                w2v = np.pad(np.asarray(n.w2.values), ((0, rmax - r), (0, 0)))
                w2s = np.pad(np.asarray(n.w2.scale), ((0, rmax - r), (0, 0)),
                             constant_values=1.0)
                padded.append((w1v, w1s, w2v, w2s))
            return LowRankQ(
                QuantizedTensor(jnp.stack([p[0] for p in padded]),
                                jnp.stack([p[1] for p in padded]),
                                nodes[0].w1.wl, 0),
                QuantizedTensor(jnp.stack([p[2] for p in padded]),
                                jnp.stack([p[3] for p in padded]),
                                nodes[0].w2.wl, 1))

        def visit(path, leaf):
            p = path_str(path)
            if p not in self.targets:
                return leaf
            if leaf.ndim == 3:
                L = leaf.shape[0]
                ranks = ([layer_ranks] * L if isinstance(layer_ranks, int)
                         else list(layer_ranks))
                ranks = [min(r, self.max_rank(p)) for r in ranks]
                nodes = [self.slice_node(p, i, ranks[i], method)
                         for i in range(L)]
                # pad to FULL rank: factor shapes stay identical across
                # every rank allocation, so the jitted eval fn compiles
                # once per method instead of once per SRA probe (which
                # exhausts the in-process XLA JIT allocator).
                return stack_nodes(nodes, self.max_rank(p))
            r = (layer_ranks if isinstance(layer_ranks, int)
                 else max(layer_ranks))
            return self.slice_node(p, None, min(r, self.max_rank(p)), method)

        return jax.tree_util.tree_map_with_path(visit, params)

    def accounting(self, layer_ranks, method):
        """(compression_ratio, nops_per_row) with TRUE per-layer ranks.

        Bits here are PAPER-style word-length accounting (wl bits per
        code) — the figure-reproduction axis for the FPGA target, whose
        native sub-8-bit datapath really stores W6/W3/W2 at wl bits.
        TPU *residency* accounting (packed W4 = 4, everything else an
        int8 carrier = 8) lives in core.compress.CompressionReport /
        QuantizedTensor.storage_bits; the two ratios legitimately differ
        for any wl not in {4, 8} and must not be mixed in one table."""
        bits = fp32 = nops = dense_nops = 0
        for (p, i), w in self.mats.items():
            k, n = int(w.shape[0]), int(w.shape[1])
            fp32 += 32 * k * n
            dense_nops += k * n
            if method == "quant":
                bits += self.cfg.weight_wl * k * n + 32 * n
                nops += k * n
            else:
                r = (layer_ranks if isinstance(layer_ranks, int)
                     else layer_ranks[i if i is not None else 0])
                r = min(r, min(k, n))
                bits += self.cfg.weight_wl * (k + n) * r + 64 * r
                nops += r * (k + n)
        return fp32 / max(bits, 1), nops, dense_nops


def csv_row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def timed(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters, out
