"""Roofline analysis over the dry-run cache (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
  compute    = HLO_FLOPs / peak            (per-chip numbers from the
  memory     = HLO_bytes / HBM_bw           post-SPMD HLO — already /chip)
  collective = collective_bytes / (links x link_bw)
dominant term = the bottleneck; MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (inference fwd) + useful-compute ratio.
"""
import glob
import json
import os

from common import RESULTS, csv_row

PEAK_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ICI_LINKS = 4


def roofline_row(rec):
    hlo = rec["hlo_analysis"]
    spec = rec["workload"]
    chips = rec["n_chips"]
    flops = hlo["flops_per_device"]
    mem = hlo["mem_bytes_per_device"]
    coll = hlo["collective_bytes_per_device"]

    t_comp = flops / PEAK_BF16
    t_mem = mem / HBM_BW
    t_coll = coll / (ICI_BW * ICI_LINKS)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)

    tokens = spec["global_batch"] * (spec["seq_len"]
                                     if spec["kind"] != "decode" else 1)
    n_active = spec["active_params"]
    mult = 6 if spec["kind"] == "train" else 2
    model_flops = mult * n_active * tokens / chips  # per chip
    useful = model_flops / max(flops, 1)
    # roofline fraction: useful model FLOPs per second achievable vs peak
    step_time = max(terms.values())
    mfu = model_flops / step_time / PEAK_BF16 if step_time else 0.0
    return {
        "cell": rec["cell"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_chip": model_flops,
        "useful_ratio": useful,
        "roofline_mfu": mfu,
        "peak_gib": rec["memory_analysis"]["peak_bytes_per_device"] / 2**30,
        "fits_16g": rec["memory_analysis"]["peak_bytes_per_device"]
        < 16 * 2**30,
    }


def load_cells(out_dir=None, pattern="*.json"):
    out_dir = out_dir or os.path.join(RESULTS, "dryrun")
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, pattern))):
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("status") == "ok":
            recs.append(rec)
    return recs


def main():
    recs = load_cells()
    if not recs:
        csv_row("roofline_no_dryrun_cache", 0.0,
                "run launch/dryrun.py --all first")
        return
    for rec in recs:
        r = roofline_row(rec)
        csv_row(
            f"roofline_{r['cell']}", max(r["t_compute_s"], r["t_memory_s"],
                                         r["t_collective_s"]) * 1e6,
            f"compute_s={r['t_compute_s']:.4g};memory_s={r['t_memory_s']:.4g};"
            f"collective_s={r['t_collective_s']:.4g};dominant={r['dominant']};"
            f"useful={r['useful_ratio']:.3f};mfu={r['roofline_mfu']:.3f};"
            f"peak_gib={r['peak_gib']:.2f}")


if __name__ == "__main__":
    main()
