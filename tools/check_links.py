#!/usr/bin/env python
"""Check that intra-repo markdown links resolve.

Scans the given markdown files / directories (default: README.md and
docs/) for inline links `[text](target)`. External targets (http/https/
mailto) are skipped; everything else must exist on disk relative to the
file containing the link. Anchors (`file.md#heading` or `#heading`) are
verified against GitHub-style heading slugs of the target file.

Usage (what the CI docs job runs):
    python tools/check_links.py README.md docs
Exit status 0 when every link resolves, 1 otherwise.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub anchor slug: lowercase, drop punctuation, spaces->hyphens."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def heading_slugs(md_path: pathlib.Path) -> set[str]:
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    return {slugify(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(md_path: pathlib.Path) -> list[str]:
    errors = []
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        path_part, _, anchor = target.partition("#")
        dest = (md_path.parent / path_part).resolve() if path_part \
            else md_path.resolve()
        if not dest.exists():
            errors.append(f"{md_path}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            if anchor not in heading_slugs(dest):
                errors.append(f"{md_path}: missing anchor -> {target}")
    return errors


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:]) or ["README.md",
                                                            "docs"]
    files: list[pathlib.Path] = []
    for a in args:
        p = pathlib.Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"check_links: no such path {a}", file=sys.stderr)
            return 1
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{'OK' if not errors else f'{len(errors)} broken'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
