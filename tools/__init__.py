"""Repo tooling namespace (perf_compare, check_links, iteralint)."""
