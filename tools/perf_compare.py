"""Print before/after roofline comparisons for the §Perf hillclimbs,
and diff kernel / serving benchmark runs:

    python tools/perf_compare.py                         # roofline tables
    python tools/perf_compare.py --kernels BENCH_kernels.json
    python tools/perf_compare.py --kernels old.json new.json   # delta %
    python tools/perf_compare.py --serving BENCH_serving.json
    python tools/perf_compare.py --serving old.json new.json   # delta %
"""
import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))


def _roofline_row(rec):
    # Lazy: roofline -> common -> jax. Keeps `--validate` (the CI lint
    # job's schema guard) and the BENCH diff modes importable on a bare
    # python without the benchmark stack installed.
    from roofline import roofline_row
    return roofline_row(rec)


def load(cell, out="results/dryrun"):
    for d in (out, "results/dryrun_perf"):
        p = os.path.join(d, cell + ".json")
        if os.path.exists(p):
            rec = json.load(open(p))
            if rec.get("status") == "ok":
                return _roofline_row(rec)
    return None


def row(label, cell):
    r = load(cell)
    if r is None:
        print(f"| {label} | - | - | - | - | - | - |")
        return None
    dom = max(("compute", "memory", "collective"),
              key=lambda k: r[f"t_{k}_s" if k != "collective" else
                             "t_collective_s"])
    print(f"| {label} | {r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} "
          f"| {r['t_collective_s']:.4g} | {r['dominant']} "
          f"| {r['roofline_mfu']:.4f} | {r['peak_gib']:.2f} |")
    return r


GROUPS = [
    ("H1: mixtral-8x22b long_500k (single) — paper technique on weight-bound decode", [
        ("baseline (dense bf16)", "mixtral-8x22b__long_500k__single"),
        ("quant-only W4 (paper baseline)", "mixtral-8x22b__long_500k__single__quant"),
        ("ITERA W4 r=0.35 (paper method)", "mixtral-8x22b__long_500k__single__itera"),
        ("+ int8 KV cache (beyond-paper)", "mixtral-8x22b__long_500k__single__kv8"),
    ]),
    ("H2: decode_32k — cache-bound serving", [
        ("stablelm baseline", "stablelm-12b__decode_32k__single"),
        ("stablelm int8 KV", "stablelm-12b__decode_32k__single__kv8"),
        ("stablelm ITERA W4", "stablelm-12b__decode_32k__single__itera"),
        ("stablelm quant W4", "stablelm-12b__decode_32k__single__quant"),
        ("nemotron baseline", "nemotron-4-340b__decode_32k__single"),
        ("nemotron int8 KV", "nemotron-4-340b__decode_32k__single__kv8"),
        ("nemotron int8 KV multi-pod", "nemotron-4-340b__decode_32k__multi__kv8"),
    ]),
    ("H3: zamba2-2.7b train_4k (single) — SSM scan engine", [
        ("baseline (sequential scan)", "zamba2-2.7b__train_4k__single"),
        ("falcon-mamba baseline (sequential)", "falcon-mamba-7b__train_4k__single"),
    ]),
    ("H4: stablelm-12b train_4k variants", [
        ("baseline (full remat)", "stablelm-12b__train_4k__single"),
        ("dots remat policy", "stablelm-12b__train_4k__single__dots"),
        ("loss chunk 4096", "stablelm-12b__train_4k__single__lchunk4k"),
    ]),
]


_V1_PREFIXES = ("kernel_qmm_interp_", "kernel_lrmm_interp_",
                "kernel_qmm_tpu_model_", "kernel_lrmm_tpu_model_")


def _v1_name(name):
    """Map a v1 row name onto its v2 equivalent. v1 rows carried no
    word-length tag and were all W8 (W4 rows are new in v2), so
    kernel_qmm_interp_paper512 -> kernel_qmm_interp_W8_paper512; without
    this the v1-vs-v2 diff would silently join nothing."""
    for p in _V1_PREFIXES:
        if name.startswith(p):
            return f"{p}W8_{name[len(p):]}"
    return name


def load_kernels(path):
    """{row name: (us_per_call, hbm_mb | None)} from a kernels_bench
    BENCH_kernels.json. v1 files (no bytes-moved column, untagged W8 row
    names) still load and diff against v2: names are normalized and
    hbm_mb prints as '-'."""
    rec = json.load(open(path))
    schema = rec.get("schema")
    if schema not in ("kernels_bench/v1", "kernels_bench/v2"):
        raise SystemExit(f"{path}: not a kernels_bench file "
                         f"(schema={schema!r})")
    rename = _v1_name if schema == "kernels_bench/v1" else (lambda n: n)
    return {rename(r["name"]): (float(r["us_per_call"]),
                                None if r.get("hbm_mb") is None
                                else float(r["hbm_mb"]))
            for r in rec["rows"]}


def _fmt(v, spec=".3f"):
    return "-" if v is None else format(v, spec)


def _delta(b, n):
    if b is None or n is None or b == 0:
        return "-"
    return f"{100 * (n - b) / b:+.1f}%"


def _pattn_delta(rows):
    """Pair each kernel_pattn_interp_* (streamed Pallas kernel) row with
    its kernel_pattn_gather_* (jnp oracle) sibling and print the
    bytes-moved ratio — the O(MB*bs) -> O(ctx) conversion the
    paged-attention kernel exists for. Short-context cases should show a
    much smaller ratio than long-context ones; >= 1.0 means the kernel
    stopped paying off."""
    pairs = []
    for name, (_, mb) in rows.items():
        if name.startswith("kernel_pattn_interp_"):
            suffix = name[len("kernel_pattn_interp_"):]
            gather = rows.get("kernel_pattn_gather_" + suffix)
            if gather is not None:
                pairs.append((suffix, mb, gather[1]))
    if not pairs:
        return
    print()
    print("paged attention: KV bytes streamed (kernel) vs gathered "
          "(jnp oracle)")
    print("| case | stream MiB | gather MiB | stream/gather |")
    print("|---|--:|--:|--:|")
    for suffix, smb, gmb in sorted(pairs):
        ratio = "-" if not smb or not gmb else f"{smb / gmb:.2f}x"
        print(f"| {suffix} | {_fmt(smb)} | {_fmt(gmb)} | {ratio} |")


def kernels_table(base_path, new_path=None):
    base = load_kernels(base_path)
    new = load_kernels(new_path) if new_path else None
    if new is None:
        print("| kernel | us/call | HBM MiB/call |")
        print("|---|--:|--:|")
        for name, (us, mb) in base.items():
            print(f"| {name} | {us:.3f} | {_fmt(mb)} |")
        _pattn_delta(base)
        return
    print(f"| kernel | {os.path.basename(base_path)} us "
          f"| {os.path.basename(new_path)} us | us delta "
          f"| HBM MiB old | HBM MiB new | HBM delta |")
    print("|---|--:|--:|--:|--:|--:|--:|")
    for name in sorted(set(base) | set(new)):
        b_us, b_mb = base.get(name, (None, None))
        n_us, n_mb = new.get(name, (None, None))
        print(f"| {name} | {_fmt(b_us)} | {_fmt(n_us)} "
              f"| {_delta(b_us, n_us)} | {_fmt(b_mb)} | {_fmt(n_mb)} "
              f"| {_delta(b_mb, n_mb)} |")
    _pattn_delta(new)


# (metric label, path into BENCH_serving.json, unit scale)
SERVING_METRICS = [
    ("static tok/s", ("static", "tokens_per_second"), 1.0),
    ("continuous tok/s", ("continuous", "tokens_per_second"), 1.0),
    ("speedup (cont/static)", ("speedup",), 1.0),
    ("unified steps", ("continuous", "steps"), 1.0),
    ("mixed steps (chunk+decode)", ("continuous", "mixed_steps"), 1.0),
    ("prefill chunks", ("continuous", "prefill_chunks"), 1.0),
    ("TTFT p50 (ms)", ("continuous", "ttft_p50_s"), 1e3),
    ("TTFT p95 (ms)", ("continuous", "ttft_p95_s"), 1e3),
    ("TPOT p50 (ms)", ("continuous", "tpot_p50_s"), 1e3),
    ("TPOT p95 (ms)", ("continuous", "tpot_p95_s"), 1e3),
    # sampled-serving section (fig13 --sample; '-' without it)
    ("sampled tok/s", ("sampled", "tokens_per_second"), 1.0),
    ("sampled greedy tok/s", ("sampled", "greedy_tokens_per_second"), 1.0),
    ("sampled/greedy throughput", ("sampled", "throughput_vs_greedy"), 1.0),
    ("sampled queue p50 (ms)", ("sampled", "queue_p50_s"), 1e3),
    ("sampled goodput tok/s @2x-median",
     ("sampled", "goodput_tok_per_s_at_2x_median"), 1.0),
    # self-speculative decoding section (fig13 --speculate K; rows print
    # '-' for runs benchmarked without it)
    ("spec tok/s", ("speculation", "tokens_per_second"), 1.0),
    ("spec baseline tok/s",
     ("speculation", "baseline_tokens_per_second"), 1.0),
    ("spec speedup vs plain", ("speculation", "speedup_vs_plain"), 1.0),
    ("spec accept rate", ("speculation", "accept_rate"), 1.0),
    ("spec draft depth k", ("speculation", "k"), 1.0),
    # prefix-caching section (fig13 --shared-prefix; '-' without it)
    ("prefix-cache hit rate (tokens)", ("prefix_cache", "hit_rate"), 1.0),
    ("prefix-cache tok/s (on)",
     ("prefix_cache", "tokens_per_second_on"), 1.0),
    ("prefix-cache tok/s (off)",
     ("prefix_cache", "tokens_per_second_off"), 1.0),
    ("prefix-cache speedup vs off",
     ("prefix_cache", "speedup_vs_off"), 1.0),
    ("prefix-cache TTFT p50 on (ms)",
     ("prefix_cache", "ttft_p50_s_on"), 1e3),
    ("prefix-cache TTFT p50 off (ms)",
     ("prefix_cache", "ttft_p50_s_off"), 1e3),
    ("prefix-cache blocks saved", ("prefix_cache", "blocks_saved"), 1.0),
    ("prefix-cache COW blocks", ("prefix_cache", "cow_blocks"), 1.0),
    # tensor-parallel serving section (fig13 --mesh N; '-' without it)
    ("tp mesh (model axis)", ("tp", "mesh"), 1.0),
    ("tp tok/s", ("tp", "tokens_per_second"), 1.0),
    ("tp single-device tok/s", ("tp", "baseline_tokens_per_second"), 1.0),
    ("tp all-reduce KiB/chip/step", ("tp", "allreduce_bytes_per_step"),
     1 / 1024),
    ("tp all-reduce us/step (ICI)", ("tp", "allreduce_s_per_step"), 1e6),
]


def _serving_metric(rec, path, scale):
    v = rec
    for k in path:
        if not isinstance(v, dict) or k not in v:
            return None
        v = v[k]
    return float(v) * scale


def serving_table(base_path, new_path=None):
    """Serving throughput/latency from fig13's BENCH_serving.json — one
    file prints the run, two files print the before/after delta."""
    base = json.load(open(base_path))
    new = json.load(open(new_path)) if new_path else None
    wl = base.get("workload", {})
    print(f"serving workload: n={wl.get('n')} max_batch="
          f"{wl.get('max_batch')} block_size={wl.get('block_size')} "
          f"chunk_tokens={wl.get('chunk_tokens', '-')}")
    if new is None:
        print("| metric | value |")
        print("|---|--:|")
        for name, path, scale in SERVING_METRICS:
            v = _serving_metric(base, path, scale)
            print(f"| {name} | {'-' if v is None else f'{v:.2f}'} |")
        return
    print(f"| metric | {os.path.basename(base_path)} "
          f"| {os.path.basename(new_path)} | delta |")
    print("|---|--:|--:|--:|")
    for name, path, scale in SERVING_METRICS:
        b = _serving_metric(base, path, scale)
        n = _serving_metric(new, path, scale)
        if b is None or n is None or b == 0:
            bs = "-" if b is None else f"{b:.2f}"
            ns = "-" if n is None else f"{n:.2f}"
            print(f"| {name} | {bs} | {ns} | - |")
            continue
        print(f"| {name} | {b:.2f} | {n:.2f} | {100 * (n - b) / b:+.1f}% |")


def validate(kernels_path="BENCH_kernels.json",
             serving_path="BENCH_serving.json"):
    """Fast CI guard: check the committed benchmark JSONs still parse and
    carry the fields every table in this script joins on, without running
    any benchmark. Anyone regenerating BENCH_*.json with a changed schema
    finds out in the <1 min lint job, not in a broken perf-review diff.
    """
    problems = []
    if os.path.exists(kernels_path):
        try:
            rows = load_kernels(kernels_path)
            if not rows:
                problems.append(f"{kernels_path}: no rows")
            for name, (us, _hbm) in rows.items():
                if us <= 0:
                    problems.append(
                        f"{kernels_path}: {name}: us_per_call={us}")
        except (SystemExit, KeyError, TypeError, ValueError,
                json.JSONDecodeError) as e:
            problems.append(f"{kernels_path}: {e}")
    else:
        problems.append(f"{kernels_path}: missing")
    if os.path.exists(serving_path):
        try:
            rec = json.load(open(serving_path))
            if "workload" not in rec:
                problems.append(f"{serving_path}: no 'workload' section")
            resolved = sum(
                1 for _n, path, scale in SERVING_METRICS
                if _serving_metric(rec, path, scale) is not None)
            if not resolved:
                problems.append(
                    f"{serving_path}: none of the {len(SERVING_METRICS)} "
                    "serving metrics resolve — schema drifted?")
        except (TypeError, ValueError, json.JSONDecodeError) as e:
            problems.append(f"{serving_path}: {e}")
    else:
        problems.append(f"{serving_path}: missing")
    if problems:
        for p in problems:
            print(f"perf_compare --validate: {p}", file=sys.stderr)
        raise SystemExit(1)
    print(f"perf_compare --validate: ok ({kernels_path}, {serving_path})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", nargs="+", metavar="BENCH_kernels.json",
                    help="one file: print table; two files: before/after")
    ap.add_argument("--serving", nargs="+", metavar="BENCH_serving.json",
                    help="one file: print table; two files: before/after")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check committed BENCH_*.json and exit "
                         "(fast CI guard; runs no benchmarks)")
    args = ap.parse_args()
    if args.validate:
        validate()
        return
    if args.kernels:
        if len(args.kernels) > 2:
            raise SystemExit("--kernels takes one or two files")
        kernels_table(*args.kernels)
    if args.serving:
        if len(args.serving) > 2:
            raise SystemExit("--serving takes one or two files")
        serving_table(*args.serving)
    if args.kernels or args.serving:
        return
    roofline_report()


def roofline_report():
    for title, rows in GROUPS:
        print(f"\n#### {title}\n")
        print("| config | compute s | memory s | collective s | dominant "
              "| roofline-MFU | peak GiB/dev |")
        print("|---|--:|--:|--:|---|--:|--:|")
        for label, cell in rows:
            row(label, cell)
    # perf-dir cells (chunked engines)
    print("\n#### H3 chunked-scan measurements (results/dryrun_perf)\n")
    print("| config | compute s | memory s | collective s | dominant "
          "| roofline-MFU | peak GiB/dev |")
    print("|---|--:|--:|--:|---|--:|--:|")
    for f in sorted(glob.glob("results/dryrun_perf/*.json")):
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        r = _roofline_row(rec)
        print(f"| {r['cell']} | {r['t_compute_s']:.4g} "
              f"| {r['t_memory_s']:.4g} | {r['t_collective_s']:.4g} "
              f"| {r['dominant']} | {r['roofline_mfu']:.4f} "
              f"| {r['peak_gib']:.2f} |")


if __name__ == "__main__":
    main()
