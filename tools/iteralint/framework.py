"""Shared infrastructure for the iteralint analyzers.

Everything here is plain-stdlib `ast` work: a `SourceFile` wraps one
parsed module (with its suppression comments and magic markers), a
`Project` owns every parsed file plus the cross-module call graph, and a
`Finding` is the unit every analyzer emits. No jax import anywhere —
the linter must run on a box that cannot even install the runtime deps.

Suppression syntax (checked per finding line, same line or the line
directly above):

    x = compute()  # iteralint: disable=trace-safety
    # iteralint: disable=tp-boundary,host-purity
    y = other()

File-wide:

    # iteralint: disable-file=recompile-hazard

Magic markers used by individual analyzers:

    # iteralint: host-pure-module      (file-wide host-purity strictness)
    # iteralint: tp-root               (next `def` seeds TP reachability)
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

DISABLE_RE = re.compile(r"#\s*iteralint:\s*disable=([\w\-,\s]+)")
DISABLE_FILE_RE = re.compile(r"#\s*iteralint:\s*disable-file=([\w\-,\s]+)")
MARKER_RE = re.compile(r"#\s*iteralint:\s*([\w\-]+)\s*$")

# Paths (repo-relative, posix) skipped when walking directories. The lint
# fixtures are deliberate rule violations; CI must not trip over them.
DEFAULT_EXCLUDES = ("tests/fixtures/lint",)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit. `line`/`col` are 1-based / 0-based (ast style).

    Baseline matching deliberately ignores line/col (they drift with
    unrelated edits): the identity of a finding is (rule, path, message),
    so messages must not embed line numbers.
    """
    rule: str
    path: str
    line: int
    col: int
    message: str

    @property
    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.message)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: " \
               f"[{self.rule}] {self.message}"


def _split_rules(blob: str) -> set[str]:
    return {r.strip() for r in blob.split(",") if r.strip()}


class SourceFile:
    """One parsed Python file plus its comment-level lint directives."""

    def __init__(self, path: pathlib.Path, rel: str, module: str,
                 text: str):
        self.path = path
        self.rel = rel                      # repo-relative posix string
        self.module = module                # dotted module name
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=rel)
        self.suppressed: dict[int, set[str]] = {}
        self.file_suppressed: set[str] = set()
        self.markers: dict[int, str] = {}   # line -> marker name
        self.file_markers: set[str] = set()
        for i, raw in enumerate(self.lines, start=1):
            if "#" not in raw:
                continue
            m = DISABLE_FILE_RE.search(raw)
            if m:
                self.file_suppressed |= _split_rules(m.group(1))
                continue
            m = DISABLE_RE.search(raw)
            if m:
                self.suppressed[i] = _split_rules(m.group(1))
                continue
            m = MARKER_RE.search(raw)
            if m and m.group(1).startswith(("host-", "tp-")):
                self.markers[i] = m.group(1)
                self.file_markers.add(m.group(1))

    def is_suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_suppressed or "all" in self.file_suppressed:
            return True
        for ln in (line, line - 1):
            rules = self.suppressed.get(ln)
            if rules and (rule in rules or "all" in rules):
                return True
        return False

    def marker_near(self, marker: str, line: int) -> bool:
        """Marker on `line` or the line directly above (decorator style)."""
        return self.markers.get(line) == marker \
            or self.markers.get(line - 1) == marker


def module_name_for(rel: str) -> str:
    """Dotted module name for a repo-relative path. Files under src/ drop
    the prefix (the repo runs with PYTHONPATH=src), everything else keeps
    its full path so test/tool modules cannot collide with repro.*."""
    p = pathlib.PurePosixPath(rel)
    parts = list(p.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class Project:
    """All parsed files for one lint run.

    `analysis_files` are the files findings may be reported against
    (the CLI paths). The project additionally parses everything under
    `src/` so cross-module analyses (call graph, transitive jax imports)
    see the whole runtime even when only a subset is being linted.
    """

    def __init__(self, root: pathlib.Path, paths: list[pathlib.Path],
                 use_default_excludes: bool = True):
        self.root = root
        self.files: dict[str, SourceFile] = {}        # rel -> SourceFile
        self.by_module: dict[str, SourceFile] = {}
        self.analysis_rels: list[str] = []
        self.errors: list[str] = []
        seen: set[str] = set()
        for p in paths:
            for f in self._walk(p, use_default_excludes):
                rel = self._rel(f)
                if rel in seen:
                    continue
                seen.add(rel)
                if self._load(f, rel) is not None:
                    self.analysis_rels.append(rel)
        src = root / "src"
        if src.is_dir():
            for f in self._walk(src, use_default_excludes):
                rel = self._rel(f)
                if rel not in seen:
                    seen.add(rel)
                    self._load(f, rel)
        self._graph = None

    def _rel(self, f: pathlib.Path) -> str:
        try:
            return f.resolve().relative_to(self.root.resolve()).as_posix()
        except ValueError:
            return f.as_posix()

    def _walk(self, p: pathlib.Path, use_default_excludes: bool):
        if p.is_file():
            if p.suffix == ".py":
                yield p
            return
        for f in sorted(p.rglob("*.py")):
            rel = self._rel(f)
            if use_default_excludes and any(
                    rel == ex or rel.startswith(ex + "/")
                    for ex in DEFAULT_EXCLUDES):
                continue
            yield f

    def _load(self, f: pathlib.Path, rel: str):
        try:
            sf = SourceFile(f, rel, module_name_for(rel),
                            f.read_text(encoding="utf-8"))
        except (OSError, SyntaxError) as e:
            self.errors.append(f"{rel}: unparseable ({e})")
            return None
        self.files[rel] = sf
        self.by_module[sf.module] = sf
        return sf

    @property
    def analysis_files(self) -> list[SourceFile]:
        return [self.files[r] for r in self.analysis_rels]

    def callgraph(self):
        if self._graph is None:
            from tools.iteralint.callgraph import CallGraph
            self._graph = CallGraph(self)
        return self._graph


class Analyzer:
    """Base class: subclasses set `name` and implement `run`."""

    name = "base"
    description = ""

    def run(self, project: Project) -> list[Finding]:
        raise NotImplementedError

    def finding(self, sf: SourceFile, node: ast.AST, message: str):
        return Finding(self.name, sf.rel, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


def run_analyzers(project: Project, analyzers) -> list[Finding]:
    """Run analyzers, drop suppressed findings, sort stably."""
    out = []
    for a in analyzers:
        for f in a.run(project):
            sf = project.files.get(f.path)
            if sf is not None and sf.is_suppressed(f.rule, f.line):
                continue
            out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule, f.message))
    return out


# ---------------------------------------------------------------------------
# Small ast helpers shared by several analyzers.

def dotted_name(node: ast.AST) -> str | None:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_table(tree: ast.Module) -> dict[str, str]:
    """alias -> fully qualified target for module-level imports.

    `import a.b as c`      -> {'c': 'a.b'}
    `import a.b`           -> {'a': 'a'}          (only the root binds)
    `from a.b import c`    -> {'c': 'a.b.c'}
    `from a.b import c as d` -> {'d': 'a.b.c'}
    """
    table: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    table[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    table[root] = root
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                table[a.asname or a.name] = f"{node.module}.{a.name}"
    return table


def resolves_to(table: dict[str, str], node: ast.AST,
                prefix: str) -> bool:
    """True when the Name/Attribute chain resolves under `prefix` (a
    module path like 'jax' or 'jax.numpy') through the import table."""
    dn = dotted_name(node)
    if dn is None:
        return False
    head, _, rest = dn.partition(".")
    target = table.get(head)
    if target is None:
        return False
    full = target + ("." + rest if rest else "")
    return full == prefix or full.startswith(prefix + ".")
