"""Cross-module call graph + trace-root detection over the project.

Functions are keyed `module:Qual.Name` (methods include the class,
lambdas get synthetic `<lambda L<line>>` names under their enclosing
function). Edges are best-effort static resolution of call sites:

  * bare names -> sibling/module-level defs, or `from repro.x import y`
    imports;
  * `alias.attr(...)` -> first-party module functions via the import
    table (`from repro.models import transformer` -> transformer.prefill);
  * `self.attr(...)` -> methods of the enclosing class.

Trace roots are functions handed to jax tracing machinery: `jax.jit` /
`jax.pmap` (kind "jit", with any static_argnums/static_argnames
captured for the recompile analyzer), `shard_map` / `tp_shard_map`
(kind "shard_map"), `pl.pallas_call` kernels (kind "pallas"), and the
`jax.lax` control-flow / `jax.vmap`-family combinators whose function
arguments are always traced (kind "trace"). Decorator and call-site
forms both count, including `partial(jax.jit, ...)`.

Nested defs and lambdas are conservatively assumed to execute when
their enclosing function does (they are closure helpers in this
codebase), so tracing propagates into them.
"""
from __future__ import annotations

import ast
import dataclasses

from tools.iteralint.framework import dotted_name, import_table

JIT_TARGETS = {"jax.jit", "jax.pmap"}
SHARD_TARGETS = {
    "jax.experimental.shard_map.shard_map",
    "jax.sharding.shard_map",
    "repro.runtime.shardctx.tp_shard_map",
}
PALLAS_TARGETS = {"jax.experimental.pallas.pallas_call"}
TRACE_TARGETS = {
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.cond",
    "jax.lax.fori_loop", "jax.lax.switch", "jax.lax.map",
    "jax.lax.associative_scan", "jax.vmap", "jax.grad",
    "jax.value_and_grad", "jax.checkpoint", "jax.remat",
}
PARTIAL_TARGETS = {"functools.partial"}


@dataclasses.dataclass
class FuncInfo:
    qual: str                   # module:Qual.Name
    sf: object                  # SourceFile
    node: ast.AST               # FunctionDef / Lambda
    cls: str | None             # enclosing class name, if a method
    parent: str | None          # enclosing function qual, if nested


@dataclasses.dataclass
class JitSite:
    sf: object
    call: ast.AST               # the jax.jit(...) call or decorated def
    wrapped_qual: str | None    # graph node for the wrapped function
    wrapped_ast: ast.AST | None  # Lambda / FunctionDef when in-file
    static_argnums: list[int]
    static_argnames: list[str]
    enclosing: str | None       # qual of the function containing the site


def _const_list(node, typ):
    if node is None:
        return []
    if isinstance(node, ast.Constant) and isinstance(node.value, typ):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, typ)]
    return []


class CallGraph:

    def __init__(self, project):
        self.project = project
        self.functions: dict[str, FuncInfo] = {}
        self.edges: dict[str, set[str]] = {}
        self.roots: dict[str, set[str]] = {}    # qual -> wrapper kinds
        self.jit_sites: list[JitSite] = []
        for sf in project.files.values():
            self._index(sf)
        for sf in project.files.values():
            self._scan(sf)
        self._traced = None

    # -- indexing ----------------------------------------------------------

    def _index(self, sf):
        mod = sf.module

        def visit(node, quals, cls, parent):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.ClassDef):
                    visit(child, quals + [child.name], child.name, parent)
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    q = f"{mod}:" + ".".join(quals + [child.name])
                    self.functions[q] = FuncInfo(q, sf, child, cls, parent)
                    if parent is not None:      # nested def runs w/ parent
                        self._edge(parent, q)
                    visit(child, quals + [child.name], cls, q)
                elif isinstance(child, ast.Lambda):
                    q = (f"{mod}:" + ".".join(
                        quals + [f"<lambda L{child.lineno}>"]))
                    self.functions[q] = FuncInfo(q, sf, child, cls, parent)
                    if parent is not None:
                        self._edge(parent, q)
                    visit(child, quals + [f"<lambda L{child.lineno}>"],
                          cls, q)
                else:
                    visit(child, quals, cls, parent)

        visit(sf.tree, [], None, None)

    def _edge(self, a, b):
        self.edges.setdefault(a, set()).add(b)

    # -- call resolution ---------------------------------------------------

    def _resolve(self, sf, caller: FuncInfo | None, func: ast.AST):
        mod = sf.module
        table = sf.imports
        if isinstance(func, ast.Name):
            n = func.id
            if caller is not None:              # sibling nested def
                q = f"{caller.qual}.{n}"
                if q in self.functions:
                    return q
            if f"{mod}:{n}" in self.functions:
                return f"{mod}:{n}"
            tgt = table.get(n)
            if tgt and tgt.startswith("repro."):
                m, _, sym = tgt.rpartition(".")
                if f"{m}:{sym}" in self.functions:
                    return f"{m}:{sym}"
            return None
        if isinstance(func, ast.Attribute):
            if isinstance(func.value, ast.Name) and func.value.id == "self" \
                    and caller is not None and caller.cls:
                q = f"{mod}:{caller.cls}.{func.attr}"
                if q in self.functions:
                    return q
            dn = dotted_name(func)
            if dn is None:
                return None
            head, _, rest = dn.partition(".")
            tgt = table.get(head)
            if tgt and rest:
                full = f"{tgt}.{rest}"
                if full.startswith("repro."):
                    m, _, sym = full.rpartition(".")
                    if f"{m}:{sym}" in self.functions:
                        return f"{m}:{sym}"
        return None

    def resolve_target(self, sf, node: ast.AST) -> str | None:
        """Fully-qualified dotted target of a Name/Attribute through the
        module's import table ('jax.jit' for `jit` imported from jax)."""
        dn = dotted_name(node)
        if dn is None:
            return None
        head, _, rest = dn.partition(".")
        tgt = sf.imports.get(head)
        if tgt is None:
            return None
        return tgt + ("." + rest if rest else "")

    # -- scanning ----------------------------------------------------------

    def _scan(self, sf):
        if not hasattr(sf, "imports"):
            sf.imports = import_table(sf.tree)
        by_node = {id(fi.node): fi for fi in self.functions.values()
                   if fi.sf is sf}

        def enclosing(stack):
            for n in reversed(stack):
                fi = by_node.get(id(n))
                if fi is not None:
                    return fi
            return None

        stack = []

        def walk(node):
            stack.append(node)
            caller = enclosing(stack)
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.Call):
                    self._handle_call(sf, caller, child)
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    self._handle_decorators(sf, caller, child)
                walk(child)
            stack.pop()

        # `sf.imports` must exist before resolve calls below
        walk(sf.tree)

    def _mark_root(self, qual, kind):
        if qual is not None:
            self.roots.setdefault(qual, set()).add(kind)

    def _fn_arg_qual(self, sf, caller, arg):
        """Graph node for a function-valued argument (Name or Lambda)."""
        if isinstance(arg, ast.Lambda):
            fi = next((f for f in self.functions.values()
                       if f.sf is sf and f.node is arg), None)
            return fi.qual if fi else None
        if isinstance(arg, (ast.Name, ast.Attribute)):
            return self._resolve(sf, caller, arg)
        if isinstance(arg, ast.Call):        # partial(f, ...) etc.
            tgt = self.resolve_target(sf, arg.func)
            if tgt in PARTIAL_TARGETS and arg.args:
                return self._fn_arg_qual(sf, caller, arg.args[0])
        return None

    def _handle_call(self, sf, caller, call: ast.Call):
        tgt = self.resolve_target(sf, call.func)
        if tgt in JIT_TARGETS:
            self._record_jit(sf, caller, call, call.args[0]
                             if call.args else None, call.keywords)
            return
        if tgt in PARTIAL_TARGETS and call.args:
            inner = self.resolve_target(sf, call.args[0])
            if inner in JIT_TARGETS:
                # partial(jax.jit, static_argnames=...) used as decorator
                # or wrapper factory; statics come from the partial.
                self._record_jit(sf, caller, call,
                                 call.args[1] if len(call.args) > 1
                                 else None, call.keywords)
                return
        if tgt in SHARD_TARGETS or (tgt is None and isinstance(
                call.func, ast.Name) and call.func.id == "shard_map"):
            for a in list(call.args[:1]) + [k.value for k in call.keywords
                                            if k.arg == "f"]:
                self._mark_root(self._fn_arg_qual(sf, caller, a),
                                "shard_map")
            return
        if tgt in PALLAS_TARGETS:
            if call.args:
                self._mark_root(self._fn_arg_qual(sf, caller,
                                                  call.args[0]), "pallas")
            return
        if tgt in TRACE_TARGETS:
            for a in call.args:
                q = self._fn_arg_qual(sf, caller, a)
                if q is not None:
                    self._mark_root(q, "trace")
            return
        if caller is not None:
            q = self._resolve(sf, caller, call.func)
            if q is not None:
                self._edge(caller.qual, q)

    def _record_jit(self, sf, caller, call, fn_arg, keywords):
        nums = names = None
        for kw in keywords:
            if kw.arg == "static_argnums":
                nums = _const_list(kw.value, int)
            elif kw.arg == "static_argnames":
                names = _const_list(kw.value, str)
        qual = self._fn_arg_qual(sf, caller, fn_arg) \
            if fn_arg is not None else None
        wrapped_ast = None
        if isinstance(fn_arg, ast.Lambda):
            wrapped_ast = fn_arg
        elif qual in self.functions:
            wrapped_ast = self.functions[qual].node
        self._mark_root(qual, "jit")
        self.jit_sites.append(JitSite(
            sf, call, qual, wrapped_ast, nums or [], names or [],
            caller.qual if caller else None))

    def _handle_decorators(self, sf, caller, fn: ast.FunctionDef):
        fi = next((f for f in self.functions.values()
                   if f.sf is sf and f.node is fn), None)
        if fi is None:
            return
        for dec in fn.decorator_list:
            if isinstance(dec, ast.Call):
                tgt = self.resolve_target(sf, dec.func)
                if tgt in JIT_TARGETS:
                    self._mark_root(fi.qual, "jit")
                    self.jit_sites.append(JitSite(
                        sf, fn, fi.qual, fn,
                        *self._statics(dec.keywords), fi.qual))
                elif tgt in PARTIAL_TARGETS and dec.args and \
                        self.resolve_target(sf, dec.args[0]) in JIT_TARGETS:
                    self._mark_root(fi.qual, "jit")
                    self.jit_sites.append(JitSite(
                        sf, fn, fi.qual, fn,
                        *self._statics(dec.keywords), fi.qual))
                elif tgt in SHARD_TARGETS:
                    self._mark_root(fi.qual, "shard_map")
            else:
                tgt = self.resolve_target(sf, dec)
                if tgt in JIT_TARGETS:
                    self._mark_root(fi.qual, "jit")
                    self.jit_sites.append(JitSite(
                        sf, fn, fi.qual, fn, [], [], fi.qual))

    @staticmethod
    def _statics(keywords):
        nums = names = None
        for kw in keywords:
            if kw.arg == "static_argnums":
                nums = _const_list(kw.value, int)
            elif kw.arg == "static_argnames":
                names = _const_list(kw.value, str)
        return (nums or [], names or [])

    # -- reachability ------------------------------------------------------

    def reachable_from(self, seeds) -> set[str]:
        seen = set()
        work = [s for s in seeds if s in self.functions]
        while work:
            q = work.pop()
            if q in seen:
                continue
            seen.add(q)
            work.extend(self.edges.get(q, ()))
        return seen

    def traced(self) -> set[str]:
        """Functions reachable from any trace root (jit / shard_map /
        pallas / lax combinators)."""
        if self._traced is None:
            self._traced = self.reachable_from(self.roots)
        return self._traced

    def roots_of_kind(self, kind: str) -> set[str]:
        return {q for q, kinds in self.roots.items() if kind in kinds}
