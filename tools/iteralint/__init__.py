"""iteralint: repo-aware static analysis for the ITERA serving stack.

Six analyzers over a shared `ast` framework enforce the invariants the
runtime tests only catch after the fact: trace-safety, recompile
hazards, Pallas launch contracts, pytree aux staticness, the
one-all-reduce TP boundary, and scheduler host-purity. Stdlib only —
the linter runs where jax cannot.

    python -m tools.iteralint src tests --fail-on-new

See docs/static_analysis.md for the rule catalog.
"""
__version__ = "1.0"
