"""Command line driver: `python -m tools.iteralint [paths...]`.

Exit codes: 0 clean (with --fail-on-new: no *new* findings beyond the
baseline), 1 findings (or new findings), 2 usage / internal error.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from tools.iteralint import baseline as baseline_mod
from tools.iteralint.analyzers import ALL, BY_NAME
from tools.iteralint.framework import Project, run_analyzers


def build_parser():
    ap = argparse.ArgumentParser(
        prog="python -m tools.iteralint",
        description="Repo-aware static analysis for the ITERA serving "
                    "stack (jit / Pallas / TP invariants).")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to lint "
                         "(default: src tests)")
    ap.add_argument("--rules", metavar="R1,R2",
                    help="comma-separated subset of rules to run")
    ap.add_argument("--baseline", default=str(baseline_mod.DEFAULT_PATH),
                    help="baseline JSON path")
    ap.add_argument("--fail-on-new", action="store_true",
                    help="exit 0 unless a finding is NOT in the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable JSON findings on stdout")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--no-default-excludes", action="store_true",
                    help="also lint the deliberate-violation fixture "
                         "tree (tests/fixtures/lint)")
    ap.add_argument("--root", default=".",
                    help="repo root (default: cwd)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for a in ALL:
            print(f"{a.name:18s} {a.description}")
        return 0

    analyzers = ALL
    if args.rules:
        wanted = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in wanted if r not in BY_NAME]
        if unknown:
            print(f"iteralint: unknown rules {unknown}; known: "
                  f"{sorted(BY_NAME)}", file=sys.stderr)
            return 2
        analyzers = [BY_NAME[r] for r in wanted]

    root = pathlib.Path(args.root)
    paths = []
    for p in args.paths:
        pp = pathlib.Path(p)
        if not pp.exists():
            print(f"iteralint: no such path {p}", file=sys.stderr)
            return 2
        paths.append(pp)

    project = Project(root, paths,
                      use_default_excludes=not args.no_default_excludes)
    for e in project.errors:
        print(f"iteralint: {e}", file=sys.stderr)

    findings = run_analyzers(project, analyzers)

    if args.update_baseline:
        n = baseline_mod.save(findings, args.baseline)
        print(f"iteralint: baseline rewritten with {n} entries "
              f"({args.baseline}); fill in the justifications")
        return 0

    base_keys, base_errors = baseline_mod.load(args.baseline)
    for e in base_errors:
        print(f"iteralint: {e}", file=sys.stderr)
    new = [f for f in findings if f.key not in base_keys]
    stale = base_keys - {f.key for f in findings}

    if args.as_json:
        print(json.dumps({
            "version": 1,
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "col": f.col + 1, "message": f.message,
                 "baselined": f.key in base_keys}
                for f in findings],
            "summary": {"total": len(findings), "new": len(new),
                        "baselined": len(findings) - len(new),
                        "stale_baseline_entries": len(stale),
                        "files_analyzed": len(project.analysis_rels)},
        }, indent=2))
    else:
        for f in findings:
            tag = "" if f.key not in base_keys else "  (baselined)"
            print(f.render() + tag)
        if stale:
            print(f"iteralint: note: {len(stale)} baseline entrie(s) no "
                  "longer match any finding — prune the baseline")
        print(f"iteralint: {len(project.analysis_rels)} files, "
              f"{len(findings)} finding(s), {len(new)} new")

    if base_errors:
        return 1
    if args.fail_on_new:
        return 1 if new else 0
    return 1 if findings else 0
