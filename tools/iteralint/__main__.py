import sys

from tools.iteralint.cli import main

if __name__ == "__main__":
    sys.exit(main())
