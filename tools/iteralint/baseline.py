"""Baseline file: known findings the CI gate tolerates.

JSON, committed next to this module. Every entry must carry a
`justification` — an entry without one is itself an error, so the
baseline cannot silently absorb new debt. Matching ignores line/col
(they drift with unrelated edits): identity is (rule, path, message).

Regenerate after an intentional change with:

    python -m tools.iteralint src tests --update-baseline

then hand-edit the justifications before committing.
"""
from __future__ import annotations

import json
import pathlib

VERSION = 1
DEFAULT_PATH = pathlib.Path(__file__).parent / "baseline.json"


def load(path=DEFAULT_PATH):
    """-> (set of (rule, path, message) keys, list of format errors)."""
    p = pathlib.Path(path)
    if not p.exists():
        return set(), []
    try:
        data = json.loads(p.read_text(encoding="utf-8"))
    except json.JSONDecodeError as e:
        return set(), [f"{p}: invalid JSON ({e})"]
    errors = []
    if data.get("version") != VERSION:
        errors.append(f"{p}: unknown baseline version "
                      f"{data.get('version')!r}")
    keys = set()
    for i, e in enumerate(data.get("entries", [])):
        missing = [k for k in ("rule", "path", "message") if k not in e]
        if missing:
            errors.append(f"{p}: entry {i} missing {missing}")
            continue
        if not e.get("justification", "").strip():
            errors.append(f"{p}: entry {i} ({e['rule']} @ {e['path']}) "
                          "has no justification — baselined findings "
                          "must say why")
        keys.add((e["rule"], e["path"], e["message"]))
    return keys, errors


def save(findings, path=DEFAULT_PATH):
    entries = [{"rule": f.rule, "path": f.path, "message": f.message,
                "justification": "TODO: justify or fix"}
               for f in findings]
    data = {"version": VERSION, "entries": entries}
    pathlib.Path(path).write_text(
        json.dumps(data, indent=2, sort_keys=True) + "\n",
        encoding="utf-8")
    return len(entries)
