"""The iteralint rule set. Each analyzer is independent; `ALL` is the
registry the CLI iterates (rule name -> analyzer instance)."""
from tools.iteralint.analyzers.host_purity import HostPurityAnalyzer
from tools.iteralint.analyzers.pallas_contract import PallasContractAnalyzer
from tools.iteralint.analyzers.pytree_aux import PytreeAuxAnalyzer
from tools.iteralint.analyzers.recompile import RecompileHazardAnalyzer
from tools.iteralint.analyzers.serve_rng import ServeRngAnalyzer
from tools.iteralint.analyzers.tp_boundary import TPBoundaryAnalyzer
from tools.iteralint.analyzers.trace_safety import TraceSafetyAnalyzer

ALL = [
    TraceSafetyAnalyzer(),
    RecompileHazardAnalyzer(),
    PallasContractAnalyzer(),
    PytreeAuxAnalyzer(),
    TPBoundaryAnalyzer(),
    HostPurityAnalyzer(),
    ServeRngAnalyzer(),
]

BY_NAME = {a.name: a for a in ALL}
