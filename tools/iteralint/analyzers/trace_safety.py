"""trace-safety: host-Python operations on traced values.

Within functions reachable from a trace root (jit / shard_map / pallas
kernel / lax combinator — see callgraph), flag:

  * Python `if` / `while` / `assert` whose test involves a traced value
    (tracing either fails with a ConcretizationTypeError or, worse,
    silently specializes on one branch);
  * host syncs: `.item()` / `.tolist()` on anything, `float()` / `int()`
    / `bool()` / `len()` of a traced value, and any `np.*` call — numpy
    materializes its argument on the host, which blocks the dispatch
    pipeline mid-step (the exact bug class the serve loop's
    count-based readback was built to avoid).

"Traced value" is a deliberately conservative taint: only values
produced by `jnp.*` / `jax.lax.*` / `jax.nn.*` / `jax.random.*` calls
(and arithmetic / indexing / method chains on them) are tainted.
Function parameters are NOT assumed traced — this codebase routinely
threads static Python ints (verify_width, block factors, speculation
k) through jitted functions, and flagging `if verify_width:` would bury
the real findings. `.shape` / `.ndim` / `.dtype` / `.size` reads are
untainted: they are static under tracing and branching on them is the
sanctioned pattern.
"""
from __future__ import annotations

import ast

from tools.iteralint.framework import Analyzer, import_table

DEVICE_PREFIXES = ("jax.numpy", "jax.lax", "jax.nn", "jax.random",
                   "jax.scipy")
STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
# jax.numpy calls that yield static (non-array) values.
STATIC_FNS = {"dtype", "issubdtype", "ShapeDtypeStruct", "result_type"}
HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}


def _device_module_aliases(table):
    """Module import aliases that resolve under jax (np stays separate)."""
    dev, np_alias = set(), set()
    for alias, tgt in table.items():
        if tgt == "numpy" or tgt.startswith("numpy."):
            np_alias.add(alias)
        elif any(tgt == p or tgt.startswith(p + ".")
                 for p in DEVICE_PREFIXES) or tgt == "jax":
            dev.add(alias)
    return dev, np_alias


class _FnChecker(ast.NodeVisitor):

    def __init__(self, analyzer, sf, fn_node, dev_aliases, np_aliases):
        self.a = analyzer
        self.sf = sf
        self.dev = dev_aliases
        self.np = np_aliases
        self.taint: set[str] = set()
        self.findings = []
        body = fn_node.body
        for stmt in (body if isinstance(body, list) else [body]):
            self.visit(stmt)

    # -- taint -------------------------------------------------------------

    def _root_alias(self, node):
        while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
            node = node.func if isinstance(node, ast.Call) else node.value
        return node.id if isinstance(node, ast.Name) else None

    def is_device_call(self, call: ast.Call) -> bool:
        f = call.func
        if isinstance(f, ast.Attribute):
            root = self._root_alias(f)
            if root in self.dev and f.attr not in STATIC_FNS:
                return True
            # method chain on a tainted value: x.astype(...), x.at[i].set()
            if self.tainted(f.value):
                return True
        return False

    def tainted(self, node) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.taint
        if isinstance(node, ast.Call):
            return self.is_device_call(node)
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.tainted(node.value)
        if isinstance(node, ast.BinOp):
            return self.tainted(node.left) or self.tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return self.tainted(node.left) or any(
                self.tainted(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return self.tainted(node.body) or self.tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.tainted(node.value)
        return False

    def _mark(self, target, is_tainted):
        if isinstance(target, ast.Name):
            if is_tainted:
                self.taint.add(target.id)
            else:
                self.taint.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._mark(e, is_tainted)

    # -- statements --------------------------------------------------------

    def visit_Assign(self, node):
        t = self.tainted(node.value)
        for tgt in node.targets:
            self._mark(tgt, t)
        self.generic_visit(node)

    def visit_AnnAssign(self, node):
        if node.value is not None:
            self._mark(node.target, self.tainted(node.value))
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        if self.tainted(node.value):
            self._mark(node.target, True)
        self.generic_visit(node)

    def visit_For(self, node):
        if self.tainted(node.iter):
            self._mark(node.target, True)
            self.findings.append(self.a.finding(
                self.sf, node,
                "python `for` over a traced value in a traced function "
                "(use lax.scan / lax.fori_loop)"))
        self.generic_visit(node)

    def visit_If(self, node):
        if self.tainted(node.test):
            self.findings.append(self.a.finding(
                self.sf, node,
                "python `if` on a traced value in a traced function "
                "(use jnp.where / lax.cond)"))
        self.generic_visit(node)

    def visit_While(self, node):
        if self.tainted(node.test):
            self.findings.append(self.a.finding(
                self.sf, node,
                "python `while` on a traced value in a traced function "
                "(use lax.while_loop)"))
        self.generic_visit(node)

    def visit_Assert(self, node):
        if self.tainted(node.test):
            self.findings.append(self.a.finding(
                self.sf, node,
                "`assert` on a traced value in a traced function "
                "(assert on .shape/static config instead, or use "
                "checkify)"))
        self.generic_visit(node)

    # Nested defs/lambdas are separate graph nodes; don't double-visit.
    def visit_FunctionDef(self, node):
        pass

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        pass

    # -- host syncs --------------------------------------------------------

    def visit_Call(self, node):
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr in HOST_SYNC_METHODS:
                self.findings.append(self.a.finding(
                    self.sf, node,
                    f"`.{f.attr}()` host sync inside a traced function"))
            root = self._root_alias(f)
            if root in self.np:
                self.findings.append(self.a.finding(
                    self.sf, node,
                    f"numpy call `{ast.unparse(f)}` inside a traced "
                    "function materializes on host (use jnp)"))
        elif isinstance(f, ast.Name):
            if f.id in ("float", "int", "bool") and node.args \
                    and self.tainted(node.args[0]):
                self.findings.append(self.a.finding(
                    self.sf, node,
                    f"`{f.id}()` of a traced value forces a host sync "
                    "inside a traced function"))
            elif f.id == "len" and node.args \
                    and self.tainted(node.args[0]):
                self.findings.append(self.a.finding(
                    self.sf, node,
                    "`len()` of a traced array inside a traced function "
                    "(read .shape instead)"))
        self.generic_visit(node)


class TraceSafetyAnalyzer(Analyzer):

    name = "trace-safety"
    description = ("host control flow / host syncs on traced values in "
                   "jit- or shard_map-reachable functions")

    def run(self, project):
        graph = project.callgraph()
        traced = graph.traced()
        findings = []
        analysis = set(project.analysis_rels)
        for qual in sorted(traced):
            fi = graph.functions[qual]
            if fi.sf.rel not in analysis:
                continue
            table = getattr(fi.sf, "imports", None)
            if table is None:
                table = fi.sf.imports = import_table(fi.sf.tree)
            dev, np_alias = _device_module_aliases(table)
            chk = _FnChecker(self, fi.sf, fi.node, dev, np_alias)
            findings.extend(chk.findings)
        return findings
