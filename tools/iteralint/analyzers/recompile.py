"""recompile-hazard: jit signatures that silently retrace.

Two sub-rules:

  1. A jit-wrapped function whose parameter is used in a *static-only*
     position — `range()` bound, shape tuple of jnp.zeros/ones/full/
     reshape/broadcast_to/arange, bare `if`/`while` test, f-string —
     must have that parameter covered by static_argnums /
     static_argnames. Passing it traced fails; passing it as a Python
     scalar retraces on every new value.

  2. A call to a known jit-bound callable inside a `for`/`while` loop
     that passes a freshly computed Python scalar (`len(...)`,
     `int(...)`, `x.shape[i]`) as an argument: every distinct value is
     a new trace. The serving stack's contract (PR 3) is to bucket such
     scalars (pow2) or hoist them to static config before the loop.
"""
from __future__ import annotations

import ast

from tools.iteralint.framework import Analyzer, dotted_name

SHAPE_FNS = {"zeros", "ones", "full", "empty", "arange", "reshape",
             "broadcast_to", "tile", "eye", "linspace"}


def _param_names(fn) -> list[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args]
    return names


def _static_positions(fn, param: str):
    """Yield nodes where `param` appears in a static-only position."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            fname = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None)
            if fname == "range":
                for arg in node.args:
                    if isinstance(arg, ast.Name) and arg.id == param:
                        yield node, "a `range()` bound"
            elif fname in SHAPE_FNS and node.args:
                cands = node.args if fname in ("reshape", "broadcast_to",
                                               "tile") else [node.args[0]]
                for arg in cands:
                    elts = arg.elts if isinstance(
                        arg, (ast.Tuple, ast.List)) else [arg]
                    for e in elts:
                        if isinstance(e, ast.Name) and e.id == param:
                            yield node, f"a `{fname}` shape"
        elif isinstance(node, (ast.If, ast.While)):
            t = node.test
            if isinstance(t, ast.Name) and t.id == param:
                yield node, "a python branch test"
        elif isinstance(node, ast.FormattedValue):
            if isinstance(node.value, ast.Name) and node.value.id == param:
                yield node, "an f-string"


def _is_step_varying_scalar(arg) -> str | None:
    if isinstance(arg, ast.Call) and isinstance(arg.func, ast.Name) \
            and arg.func.id in ("len", "int") and arg.args:
        return f"{arg.func.id}(...)"
    if isinstance(arg, ast.Subscript):
        dn = dotted_name(arg.value)
        if dn and dn.endswith(".shape"):
            return f"{dn}[...]"
    return None


class RecompileHazardAnalyzer(Analyzer):

    name = "recompile-hazard"
    description = ("jitted callees with unmarked static params; per-step "
                   "python scalars flowing into jitted calls")

    def run(self, project):
        graph = project.callgraph()
        findings = []
        analysis = set(project.analysis_rels)

        seen_sites = set()
        for site in graph.jit_sites:
            if site.wrapped_ast is None or site.sf.rel not in analysis:
                continue
            key = (site.sf.rel, site.wrapped_ast.lineno,
                   site.wrapped_ast.col_offset)
            if key in seen_sites:
                continue
            seen_sites.add(key)
            params = _param_names(site.wrapped_ast)
            for i, p in enumerate(params):
                if p in ("self", "cls") or i in site.static_argnums \
                        or p in site.static_argnames:
                    continue
                for node, where in _static_positions(site.wrapped_ast, p):
                    findings.append(self.finding(
                        site.sf, node,
                        f"jitted function uses param `{p}` in {where} "
                        "but it is not in static_argnums/static_argnames "
                        "— traced values fail here, python scalars "
                        "retrace per value"))
                    break       # one finding per (site, param)

        # sub-rule 2: jit-bound attributes called in loops with fresh
        # python scalars. "jit-bound" = assigned from a jax.jit(...) call
        # anywhere in the same file (self._step = jax.jit(...)).
        for sf in project.analysis_files:
            bound = self._jit_bound_names(sf)
            if not bound:
                continue
            for loop in ast.walk(sf.tree):
                if not isinstance(loop, (ast.For, ast.While)):
                    continue
                for call in ast.walk(loop):
                    if not isinstance(call, ast.Call):
                        continue
                    dn = dotted_name(call.func)
                    if dn is None or dn.split(".")[-1] not in bound:
                        continue
                    for arg in list(call.args) + [k.value for k in
                                                  call.keywords]:
                        what = _is_step_varying_scalar(arg)
                        if what:
                            findings.append(self.finding(
                                sf, arg,
                                f"per-step python scalar `{what}` passed "
                                f"to jitted `{dn}` inside a loop — every "
                                "new value retraces; bucket it (pow2) or "
                                "mark it static"))
        return findings

    @staticmethod
    def _jit_bound_names(sf) -> set[str]:
        graph_names = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                dn = dotted_name(node.value.func)
                if dn and dn.split(".")[-1] in ("jit", "pmap"):
                    for tgt in node.targets:
                        tdn = dotted_name(tgt)
                        if tdn:
                            graph_names.add(tdn.split(".")[-1])
        return graph_names
